//! Scale-harness runner: prints the N-client sharded-vs-single-lock
//! dispatch table AND the N-connection reactor-vs-thread-per-connection
//! table, regenerates `BENCH_scale.json` at the repo root — the cross-PR
//! record of server-side concurrency (DESIGN.md §2.6, §2.9) — and
//! ENFORCES the acceptance criteria:
//!
//! * dispatch: >= 3x aggregate ops/s at 8 clients for the sharded core
//!   over the `shards = 1` ablation;
//! * connections: >= 2x aggregate ops/s at 256 live connections for the
//!   reactor over the thread-per-connection ablation (when the sweep
//!   includes that point), and no p99 regression at <= 16 connections.
//!
//! `QUICK=1` shrinks the per-point measurement windows for smoke runs;
//! `CONN_CLIENTS=16,256` pins the connection sweep (CI runners cap open
//! fds near 1024 — the full 1024-connection point needs `ulimit -n 4096`).

use xufs::bench::scale::{
    conn_p99_at, conn_speedup_at, speedup_at_8, ACCEPT_CONN_SPEEDUP_AT_256, ACCEPT_SPEEDUP_AT_8,
};
use xufs::bench::{run_conn_scale, run_scale};
use xufs::config::XufsConfig;
use xufs::util::Json;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let window = if quick { 0.15 } else { 0.6 };
    let conn_window = if quick { 0.5 } else { 1.5 };
    let cfg = XufsConfig::default();

    let dispatch = run_scale(&cfg, window);
    dispatch.print();
    let conns = run_conn_scale(&cfg, conn_window);
    conns.print();

    let combined = Json::obj()
        .set("dispatch", dispatch.to_json())
        .set("connections", conns.to_json());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_scale.json");
    std::fs::write(&path, format!("{combined}\n")).expect("write BENCH_scale.json");
    println!("wrote {}", path.display());

    let speedup = speedup_at_8(&dispatch).expect("table has an 8-client sharded row");
    assert!(
        speedup >= ACCEPT_SPEEDUP_AT_8,
        "sharded server speedup at 8 clients is {speedup:.2}x, below the \
         {ACCEPT_SPEEDUP_AT_8}x acceptance bar — the concurrent core has re-serialized"
    );
    println!("acceptance: {speedup:.2}x at 8 clients (>= {ACCEPT_SPEEDUP_AT_8}x) OK");

    if let Some(cs) = conn_speedup_at(&conns, 256) {
        assert!(
            cs >= ACCEPT_CONN_SPEEDUP_AT_256,
            "reactor speedup at 256 connections is {cs:.2}x, below the \
             {ACCEPT_CONN_SPEEDUP_AT_256}x acceptance bar — the accept path has stopped scaling"
        );
        println!(
            "acceptance: {cs:.2}x at 256 connections (>= {ACCEPT_CONN_SPEEDUP_AT_256}x) OK"
        );
    }
    // the reactor must not buy scale by taxing small deployments: p99 at
    // <= 16 connections stays within 1.5x of the thread-per-connection core
    if let (Some(rp), Some(tp)) = (conn_p99_at(&conns, 16, "reactor"), conn_p99_at(&conns, 16, "threads"))
    {
        assert!(
            rp <= tp * 1.5,
            "reactor p99 at 16 connections is {rp:.2}ms vs {tp:.2}ms on the ablation — \
             small-deployment latency regressed"
        );
        println!("acceptance: p99 at 16 conns {rp:.2}ms (threads {tp:.2}ms, cap 1.5x) OK");
    }
}
