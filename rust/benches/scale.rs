//! Scale-harness runner: prints the N-client sharded-vs-single-lock
//! table, regenerates `BENCH_scale.json` at the repo root — the
//! cross-PR record of server-side concurrency (DESIGN.md §2.6) — and
//! ENFORCES the acceptance criterion (>= 3x aggregate ops/s at
//! 8 clients for the sharded core over the `shards = 1` ablation), so a
//! regression that re-serializes the server fails this run instead of
//! silently recording a flat table.
//!
//! `QUICK=1` shrinks the per-point measurement window for smoke runs.

use xufs::bench::scale::{speedup_at_8, ACCEPT_SPEEDUP_AT_8};
use xufs::bench::run_scale;
use xufs::config::XufsConfig;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let window = if quick { 0.15 } else { 0.6 };
    let cfg = XufsConfig::default();
    let t = run_scale(&cfg, window);
    t.print();
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_scale.json");
    std::fs::write(&path, format!("{}\n", t.to_json())).expect("write BENCH_scale.json");
    println!("wrote {}", path.display());
    let speedup = speedup_at_8(&t).expect("table has an 8-client sharded row");
    assert!(
        speedup >= ACCEPT_SPEEDUP_AT_8,
        "sharded server speedup at 8 clients is {speedup:.2}x, below the \
         {ACCEPT_SPEEDUP_AT_8}x acceptance bar — the concurrent core has re-serialized"
    );
    println!("acceptance: {speedup:.2}x at 8 clients (>= {ACCEPT_SPEEDUP_AT_8}x) OK");
}
