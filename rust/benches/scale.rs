//! Scale-harness runner: prints the N-client sharded-vs-single-lock
//! dispatch table AND the N-connection reactor table, regenerates
//! `BENCH_scale.json` at the repo root — the cross-PR record of
//! server-side concurrency (DESIGN.md §2.6, §2.9) — and ENFORCES the
//! acceptance criteria:
//!
//! * dispatch: >= 3x aggregate ops/s at 8 clients for the sharded core
//!   over the `shards = 1` ablation;
//! * connections: flat scaling — aggregate ops/s at 256 live
//!   connections stays at or above half the 16-connection point (when
//!   the sweep includes both), so throughput must not collapse as
//!   connections multiply.
//!
//! `QUICK=1` shrinks the per-point measurement windows for smoke runs;
//! `CONN_CLIENTS=16,256` pins the connection sweep (CI runners cap open
//! fds near 1024 — the full 1024-connection point needs `ulimit -n 4096`).

use xufs::bench::scale::{conn_ops_at, speedup_at_8, ACCEPT_CONN_FLAT_AT_256, ACCEPT_SPEEDUP_AT_8};
use xufs::bench::{run_conn_scale, run_scale};
use xufs::config::XufsConfig;
use xufs::util::Json;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let window = if quick { 0.15 } else { 0.6 };
    let conn_window = if quick { 0.5 } else { 1.5 };
    let cfg = XufsConfig::default();

    let dispatch = run_scale(&cfg, window);
    dispatch.print();
    let conns = run_conn_scale(&cfg, conn_window);
    conns.print();

    let combined = Json::obj()
        .set("dispatch", dispatch.to_json())
        .set("connections", conns.to_json());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_scale.json");
    std::fs::write(&path, format!("{combined}\n")).expect("write BENCH_scale.json");
    println!("wrote {}", path.display());

    let speedup = speedup_at_8(&dispatch).expect("table has an 8-client sharded row");
    assert!(
        speedup >= ACCEPT_SPEEDUP_AT_8,
        "sharded server speedup at 8 clients is {speedup:.2}x, below the \
         {ACCEPT_SPEEDUP_AT_8}x acceptance bar — the concurrent core has re-serialized"
    );
    println!("acceptance: {speedup:.2}x at 8 clients (>= {ACCEPT_SPEEDUP_AT_8}x) OK");

    // flat scaling: with the thread-per-connection ablation removed the
    // bar is absolute — 256 live connections must hold at least half the
    // 16-connection throughput, or the accept path has stopped scaling
    if let (Some(at16), Some(at256)) = (conn_ops_at(&conns, 16), conn_ops_at(&conns, 256)) {
        let ratio = at256 / at16.max(1e-9);
        assert!(
            ratio >= ACCEPT_CONN_FLAT_AT_256,
            "reactor throughput at 256 connections is {at256:.0} ops/s, {ratio:.2}x the \
             16-connection point — below the {ACCEPT_CONN_FLAT_AT_256}x flat-scaling bar"
        );
        println!(
            "acceptance: {ratio:.2}x of 16-conn throughput at 256 connections \
             (>= {ACCEPT_CONN_FLAT_AT_256}x) OK"
        );
    }
}
