//! Read-fanout bench runner: prints the read-scaling table (3 WAN sites
//! vs 0/1/2/3 serving secondaries), regenerates `BENCH_fanout.json` at
//! the repo root, and ENFORCES the acceptance criterion (>= 1.8x
//! aggregate cold-read throughput at 3 serving replicas). Deterministic
//! virtual-clock model — a single iteration IS the run (the nightly CI
//! smoke invokes exactly this binary).

use xufs::bench::read_fanout::speedups;
use xufs::bench::run_read_fanout;
use xufs::config::XufsConfig;

fn main() {
    let cfg = XufsConfig::default();
    let t = run_read_fanout(&cfg);
    t.print();
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_fanout.json");
    std::fs::write(&path, format!("{}\n", t.to_json())).expect("write BENCH_fanout.json");
    println!("wrote {}", path.display());
    let s = speedups(&t).expect("table parses");
    let at3 = *s.last().expect("3-replica row");
    assert!(
        at3 >= 1.8,
        "read fan-out must deliver >= 1.8x aggregate throughput at 3 serving replicas, got {at3}x"
    );
    println!("acceptance: {at3}x >= 1.8x at 3 serving replicas OK");
}
