//! Regenerates Figure 5 (five consecutive `wc -l` runs on a 1 GiB file)
//! and Table 2 (XUFS access vs TGCP / SCP copies). `QUICK=1` shrinks the
//! file to 256 MiB.

use xufs::bench::run_fig5_table2;
use xufs::config::XufsConfig;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let gib: u64 = if quick { 256 << 20 } else { 1 << 30 };
    let cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    let (fig5, table2) = run_fig5_table2(&cfg, 5, gib);
    fig5.print();
    table2.print();
}
