//! Ablations over the design choices DESIGN.md §3 calls out: stripe
//! count, parallel pre-fetch, digest delta writeback, callback vs
//! check-on-open consistency, sync vs async writeback, compound vs
//! per-op meta-queue flushing, and demand paging vs whole-file fetch.

use xufs::bench::{
    run_ablation_compound, run_ablation_consistency, run_ablation_delta, run_ablation_paging,
    run_ablation_prefetch, run_ablation_stripes, run_ablation_writeback,
};
use xufs::config::XufsConfig;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    let gib: u64 = if quick { 128 << 20 } else { 1 << 30 };
    run_ablation_stripes(&cfg, gib).print();
    run_ablation_prefetch(&cfg).print();
    run_ablation_delta(&cfg, if quick { 16 } else { 64 }).print();
    run_ablation_consistency(&cfg, 3).print();
    run_ablation_writeback(&cfg).print();
    run_ablation_compound(&cfg).print();
    run_ablation_paging(&cfg, gib).print();
}
