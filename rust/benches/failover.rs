//! Failover-bench runner: prints the replicated-takeover vs cold-restart
//! table, regenerates `BENCH_failover.json` at the repo root, and
//! ENFORCES the acceptance criterion (failover time-to-first-op beats
//! the cold crontab restart). Deterministic virtual-clock model — a
//! single iteration IS the run (the nightly CI smoke invokes exactly
//! this binary).

use xufs::bench::failover::totals;
use xufs::bench::run_failover;
use xufs::config::XufsConfig;

fn main() {
    let cfg = XufsConfig::default();
    let t = run_failover(&cfg);
    t.print();
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_failover.json");
    std::fs::write(&path, format!("{}\n", t.to_json())).expect("write BENCH_failover.json");
    println!("wrote {}", path.display());
    let (fo, cold) = totals(&t).expect("table has both recovery modes");
    assert!(
        fo < cold,
        "replicated failover ({fo}s) must beat the cold crontab restart ({cold}s)"
    );
    println!("acceptance: failover {fo}s < cold restart {cold}s OK");
}
