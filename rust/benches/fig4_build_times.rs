//! Regenerates Figure 4: clean-make times of the 24-file / ~12 kLoC /
//! 5-subdir C tree over 5 consecutive runs on XUFS, GPFS-WAN and the
//! local parallel FS.

use xufs::bench::run_fig4;
use xufs::config::XufsConfig;

fn main() {
    let cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    run_fig4(&cfg, 5).print();
}
