//! Regenerates Table 1: the cumulative file-size distribution of the TACC
//! scratch census (143,190 files / 864 GB) from the calibrated mixture.

use xufs::bench::run_table1;

fn main() {
    run_table1(1).print();
}
