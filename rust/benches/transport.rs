//! WAN transport v2 bench runner: prints the 4-profile x 4-mode sweep
//! (static/adaptive striping x fault-on-miss/pipelined readahead),
//! regenerates `BENCH_transport.json` at the repo root, and ENFORCES
//! the acceptance criterion (adaptive+pipelined >= 1.3x the static
//! fault-on-miss goodput on the lossy AND asymmetric profiles, with
//! nonzero sub-second op-latency quantiles). Deterministic
//! virtual-clock model — a single iteration IS the run (the nightly CI
//! smoke invokes exactly this binary).

use xufs::bench::run_transport;
use xufs::bench::transport::{speedup, worst_op_p99};
use xufs::config::XufsConfig;

fn main() {
    let cfg = XufsConfig::default();
    let t = run_transport(&cfg);
    t.print();
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_transport.json");
    std::fs::write(&path, format!("{}\n", t.to_json())).expect("write BENCH_transport.json");
    println!("wrote {}", path.display());
    for profile in ["lossy", "asymmetric"] {
        let s = speedup(&t, profile).expect("adaptive+pipelined row");
        assert!(
            s >= 1.3,
            "{profile}: adaptive+pipelined must reach 1.3x static fault-on-miss, got {s}x"
        );
        println!("acceptance: {profile} {s}x >= 1.3x OK");
    }
    let p99 = worst_op_p99(&t).expect("op-latency column");
    assert!(p99 > 0.0 && p99 < 1.0, "op latency must be nonzero sub-second, p99={p99}");
    println!("acceptance: op-latency p99 {p99}s nonzero sub-second OK");
}
