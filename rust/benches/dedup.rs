//! Dedup-bench runner: prints the cross-user dedup table (logical vs
//! physical bytes with shared software stacks), regenerates
//! `BENCH_dedup.json` at the repo root, and ENFORCES the acceptance
//! criterion (dedup ratio > 1.5x). Deterministic virtual-clock model — a
//! single iteration IS the run (the nightly CI smoke invokes exactly
//! this binary).

use xufs::bench::dedup::ratio;
use xufs::bench::run_dedup;
use xufs::config::XufsConfig;

fn main() {
    let cfg = XufsConfig::default();
    let t = run_dedup(&cfg);
    t.print();
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_dedup.json");
    std::fs::write(&path, format!("{}\n", t.to_json())).expect("write BENCH_dedup.json");
    println!("wrote {}", path.display());
    let r = ratio(&t).expect("table has a dedup ratio column");
    assert!(r > 1.5, "cross-user dedup ratio ({r:.2}x) must exceed 1.5x");
    println!("acceptance: dedup ratio {r:.2}x > 1.5x OK");
}
