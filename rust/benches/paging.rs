//! Paging ablation runner: prints the demand-paging vs whole-file table
//! and regenerates `BENCH_paging.json` at the repo root — the cross-PR
//! perf-trajectory record for the block-granular data plane.

use xufs::bench::run_ablation_paging;
use xufs::config::XufsConfig;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let gib: u64 = if quick { 128 << 20 } else { 1 << 30 };
    let cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    let t = run_ablation_paging(&cfg, gib);
    t.print();
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_paging.json");
    std::fs::write(&path, format!("{}\n", t.to_json())).expect("write BENCH_paging.json");
    println!("wrote {}", path.display());
}
