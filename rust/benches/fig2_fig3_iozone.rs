//! Regenerates Figures 2 and 3: IOzone write/read throughput (1 MiB to
//! 1 GiB, close included) on XUFS vs GPFS-WAN vs local GPFS over the
//! calibrated WAN model. `QUICK=1` limits the size sweep.

use xufs::bench::run_fig2_fig3;
use xufs::config::XufsConfig;

fn main() {
    let quick = std::env::var("QUICK").is_ok();
    let cfg = XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() };
    let (write_t, read_t) = run_fig2_fig3(&cfg, quick);
    write_t.print();
    read_t.print();
}
