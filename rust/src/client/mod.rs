//! The client side of XUFS: the [`Vfs`] v2 interface (stand-in for the
//! `libxufs.so` libc interposition — every interposed call has a 1:1
//! method here), the [`ServerLink`] transport abstraction, and the
//! [`XufsClient`] implementation.

mod vfs;
mod xufs;

pub use vfs::{Fd, MetaBatchOp, MetaResult, OpenFlags, Vfs};
pub use xufs::{WritebackMode, XufsClient};

use crate::homefs::FsError;
use crate::proto::{FileImage, MetaOp, NotifyEvent, RangeImage, Request, Response};

/// Typed transport-layer failure for the striped data plane. A stripe
/// connection that dies mid-transfer is not the same as a server error:
/// part of the range may already have landed, and the fetch can RESUME
/// from the first missing block instead of restarting — which is what
/// both the fault plane's torn transfers and real WAN hiccups need.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LinkError {
    /// The peer reset mid-transfer; everything already delivered (other
    /// stripes, earlier extents) is kept, and the retry resumes at
    /// `resumed_from_block`.
    Interrupted { resumed_from_block: u64 },
    /// Any other transport/server failure.
    Fs(FsError),
}

impl From<FsError> for LinkError {
    fn from(e: FsError) -> Self {
        LinkError::Fs(e)
    }
}

impl From<LinkError> for FsError {
    fn from(e: LinkError) -> Self {
        match e {
            LinkError::Interrupted { resumed_from_block } => {
                FsError::Interrupted { resumed_from_block }
            }
            LinkError::Fs(e) => e,
        }
    }
}

/// Transport to the user's file server. Two implementations:
/// `coordinator::sim::SimLink` (modeled WAN, virtual clock) and
/// `coordinator::net::TcpLink` (real sockets, USSH handshake).
pub trait ServerLink {
    /// One request/response RPC on the control connection.
    fn rpc(&mut self, req: Request) -> Result<Response, FsError>;

    /// Striped fetch of one byte range at a pinned version (the demand-
    /// paging fault path, DESIGN.md §2.4; a whole file is the degenerate
    /// full range). Returns the covering blocks with per-block digests;
    /// fails `Stale` if the home copy moved past `expect_version`.
    /// Stripes across data connections exactly like a whole-file
    /// transfer of the same payload.
    fn fetch_range(
        &mut self,
        path: &str,
        offset: u64,
        len: u64,
        expect_version: u64,
    ) -> Result<RangeImage, FsError>;

    /// Advisory pipelined-readahead hint (transport v2, DESIGN.md
    /// §2.12): the client expects to `fetch_range` these exact
    /// coordinates soon, so the link may start the transfer now and
    /// overlap it with the application's compute. Purely an
    /// optimization — links are free to ignore it (the default), and a
    /// later `fetch_range` must return identical bytes whether or not a
    /// hint preceded it.
    fn pipeline_hint(&mut self, path: &str, offset: u64, len: u64, expect_version: u64) {
        let _ = (path, offset, len, expect_version);
    }

    /// Parallel pre-fetch of small files (paths + sizes). Accounts the
    /// batched transfer time; files that failed are simply absent.
    fn prefetch(&mut self, files: &[(String, u64)]) -> Vec<FileImage>;

    /// Ship one meta-op (striped when the payload is large).
    fn ship(&mut self, seq: u64, op: &MetaOp) -> Result<Response, FsError>;

    /// Ship a batch of queued meta-ops as ONE compound round trip
    /// (`Request::Compound`, DESIGN.md §2.3). Returns one [`Response`]
    /// per op, in order. `Err(Disconnected)` means nothing in the batch
    /// was acknowledged — the caller restores the whole batch and
    /// replays after reconnect (server-side idempotence makes the replay
    /// safe even when the loss was reply-side).
    fn ship_compound(&mut self, ops: &[(u64, MetaOp)]) -> Result<Vec<Response>, FsError>;

    /// Drain pending change notifications from the callback channel.
    fn drain_notifications(&mut self) -> Vec<NotifyEvent>;

    /// Callback-channel generation: bumps on every reconnect, telling the
    /// client that callbacks may have been missed.
    fn channel_generation(&self) -> u64;

    fn is_connected(&self) -> bool;

    /// Re-establish the connection + callback channel; returns the new
    /// channel generation.
    fn reconnect(&mut self) -> Result<u64, FsError>;

    /// Stable client identity (used for lock ownership + idempotent replay).
    fn client_id(&self) -> u64;
}

#[cfg(test)]
mod link_error_tests {
    use super::*;

    #[test]
    fn interrupted_context_survives_the_fs_error_surface() {
        let e = LinkError::Interrupted { resumed_from_block: 7 };
        match FsError::from(e) {
            FsError::Interrupted { resumed_from_block } => assert_eq!(resumed_from_block, 7),
            other => panic!("{other:?}"),
        }
        let back = LinkError::from(FsError::Disconnected);
        assert_eq!(back, LinkError::Fs(FsError::Disconnected));
    }
}
