//! The XUFS client: block-granular demand-paged caching, sparse shadow-
//! file writes, meta-op queue, callback consistency, lock leases, striped
//! range fetch + parallel pre-fetch. This is `libxufs.so` + sync manager
//! + notification callback manager + lease manager of Figure 1, over a
//! pluggable [`ServerLink`].
//!
//! Data plane (DESIGN.md §2.4): `open` moves METADATA only (one
//! `FetchMeta` round trip); `pread` faults just the missing blocks of the
//! requested range (plus a readahead window) with `fetch_range`; `pwrite`
//! dirties blocks in a sparse shadow without fetching what it overwrites;
//! `close` merges the dirtied blocks back and queues a block-granular
//! writeback against the residency map. Whole-file-on-open (the paper's
//! §3.1 behaviour) survives as the degenerate case behind
//! `XufsClient::paging = false` for the paging ablation.

use std::collections::{BTreeSet, HashMap};
use std::sync::Arc;

use crate::cache::{CacheSpace, EntryState, Residency};
use crate::client::vfs::{Fd, MetaBatchOp, MetaResult, OpenFlags, Vfs};
use crate::client::ServerLink;
use crate::config::XufsConfig;
use crate::homefs::{FsError, NodeKind};
use crate::lease::LeaseManager;
use crate::metaq::{MetaQueue, SPILL_THRESHOLD};
use crate::metrics::{names, Metrics};
use crate::proto::{CompoundOp, LockKind, MetaOp, NotifyEvent, Request, Response, WireAttr};
use crate::runtime::DigestEngine;
use crate::simnet::{Clock, VirtualTime};
use crate::transfer;
use crate::util::path as vpath;
use crate::vdisk::DiskModel;

/// When queued meta-ops are shipped to the server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WritebackMode {
    /// Ship on every close (the measured behaviour in §4.1, where close
    /// cost includes the cache flush).
    SyncOnClose,
    /// Accumulate; ship on `fsync`/unmount or when the queue grows past a
    /// threshold. (Paper's "no file operation blocks on a remote call";
    /// ablation `writeback_mode`.)
    Async,
}

/// Sparse per-fd shadow (paper §3.1, block-granular since DESIGN.md
/// §2.4): writes land in a hidden shadow file, but only the blocks a
/// write touches are materialized — everything else reads through to the
/// (possibly non-resident) base content, so a small write to a huge file
/// never fetches the whole file.
#[derive(Debug)]
struct ShadowState {
    /// Shadow file path in the cache store.
    path: String,
    /// Blocks materialized (merged base + writes) in the shadow file;
    /// exactly the blocks this fd has dirtied.
    blocks: BTreeSet<u64>,
    /// Logical file size as seen through this fd.
    size: u64,
    /// Base content size at open (0 for O_TRUNC and brand-new files).
    base_size: u64,
}

#[derive(Debug)]
struct OpenFile {
    path: String,
    /// Sequential cursor backing the `read`/`write` default methods;
    /// `pread`/`pwrite` never touch it.
    pos: u64,
    flags: OpenFlags,
    /// Sparse shadow, present for write handles.
    shadow: Option<ShadowState>,
    wrote: bool,
    localized: bool,
}

/// Upper bound on one compound frame's meta-op payload: stays well under
/// the TCP transport's `MAX_FRAME` and keeps the WAN stripe model honest
/// for bulk write-backs. A single oversized op still gets its own frame.
const COMPOUND_MAX_BYTES: u64 = 32 * 1024 * 1024;

/// Outcome of settling one compound reply against the queue.
enum Settle {
    /// Applied at the server; queue entry retired.
    Acked,
    /// Dropped (semantic server error — the cache keeps the local truth).
    Dropped,
    /// Stale delta demoted to a full write and re-queued under a fresh
    /// sequence number; the next compound round ships it.
    Requeued,
}

/// The XUFS client. One per mount (paper: a private user-space server and
/// name space per user).
pub struct XufsClient<L: ServerLink> {
    link: L,
    cache: CacheSpace,
    queue: MetaQueue,
    lease: LeaseManager,
    engine: Arc<DigestEngine>,
    clock: Arc<dyn Clock>,
    cache_disk: DiskModel,
    cfg: XufsConfig,
    fds: HashMap<u64, OpenFile>,
    fd_locks: HashMap<u64, u64>, // fd -> lease token (remote locks)
    local_locks: HashMap<String, (u64, LockKind)>, // localized-dir locks (fd, kind)
    next_fd: u64,
    cwd: String,
    mount_root: String,
    metrics: Metrics,
    last_gen: u64,
    /// Per-path observed-version floors (DESIGN.md §2.11): the highest
    /// version this session has seen for each path, from flush acks,
    /// metadata fetches, and invalidation callbacks. Sent as the
    /// bounded-staleness token (`min_version`) with replica-eligible
    /// reads so a lagging secondary can never serve this client a
    /// version regression. Session-scoped on purpose: monotonic reads
    /// are a session property, and versions restart at 1 when a path is
    /// unlinked and recreated, so known removals clear the entry.
    observed_floor: HashMap<String, u64>,
    pub writeback: WritebackMode,
    /// Async mode ships the queue once this many ops accumulate.
    pub async_flush_threshold: usize,
    /// Ship queue flushes as compound RPCs (N ops per WAN round trip,
    /// DESIGN.md §2.3). Off = one `Request::Apply` round trip per op
    /// (the pre-v2 behaviour, kept for the ablation bench).
    pub compound: bool,
    /// Block-granular demand paging (DESIGN.md §2.4): `open` moves only
    /// metadata and reads fault blocks on demand. Off = the paper's
    /// whole-file-on-open behaviour, kept for the `paging` ablation.
    pub paging: bool,
}

impl<L: ServerLink> XufsClient<L> {
    /// Build a client over an established (authenticated, callback-
    /// registered) link. `mount_root` is the home-space subtree imported.
    pub fn new(
        link: L,
        cfg: XufsConfig,
        engine: Arc<DigestEngine>,
        clock: Arc<dyn Clock>,
        mount_root: &str,
        metrics: Metrics,
    ) -> Self {
        let root = vpath::normalize(mount_root);
        let mut cache = CacheSpace::new(cfg.cache.capacity, cfg.cache.localized_dirs.clone());
        cache.set_paging(cfg.stripe.min_block, cfg.cache.budget_bytes);
        let lease = LeaseManager::new(cfg.lease.duration_s, cfg.lease.renew_fraction);
        let cache_disk = DiskModel::new(cfg.disk.cache_bps, cfg.disk.cache_op_s);
        let gen = link.channel_generation();
        XufsClient {
            link,
            cache,
            queue: MetaQueue::new(),
            lease,
            engine,
            clock,
            cache_disk,
            cfg,
            fds: HashMap::new(),
            fd_locks: HashMap::new(),
            local_locks: HashMap::new(),
            next_fd: 3,
            cwd: root.clone(),
            mount_root: root,
            metrics,
            last_gen: gen,
            observed_floor: HashMap::new(),
            writeback: WritebackMode::SyncOnClose,
            async_flush_threshold: 64,
            compound: true,
            paging: true,
        }
    }

    /// Rebuild a client from a surviving cache space after a client crash
    /// (the `xufs sync` recovery tool): recovers the cache index from the
    /// hidden attribute files and the meta-op queue from its persisted
    /// entries, then replays the queue.
    pub fn recover(
        link: L,
        cfg: XufsConfig,
        engine: Arc<DigestEngine>,
        clock: Arc<dyn Clock>,
        mount_root: &str,
        cache_store: crate::homefs::FileStore,
        metrics: Metrics,
    ) -> (Self, usize) {
        let now = clock.now();
        let mut cache = CacheSpace::recover(
            cache_store,
            cfg.cache.capacity,
            cfg.cache.localized_dirs.clone(),
            now,
            &metrics,
        );
        cache.set_paging(cfg.stripe.min_block, cfg.cache.budget_bytes);
        // integrity pass (DESIGN.md §2.10): blocks that rotted on the
        // cache disk while the client was down are demoted to Absent
        // here — they re-fault from home instead of being served
        cache.verify_recovered(&engine, now, &metrics);
        let (queue, corrupt) = MetaQueue::recover(cache.store());
        // op-log records dropped for a bad HMAC or torn frame are
        // corruption detections, not silent truncation
        metrics.add(names::METAQ_CORRUPT_RECORDS, corrupt as u64);
        let mut c = Self::new(link, cfg, engine, clock, mount_root, metrics);
        c.cache = cache;
        c.queue = queue;
        c.metrics.add(names::METAQ_REPLAYS, c.queue.len() as u64);
        // replay what the crash left behind
        let _ = c.flush_queue();
        (c, corrupt)
    }

    pub fn cache(&self) -> &CacheSpace {
        &self.cache
    }

    /// The surviving on-disk cache state (for crash simulations: clone
    /// this, drop the client, then `recover`).
    pub fn cache_store_snapshot(&self) -> crate::homefs::FileStore {
        self.cache.store().clone()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn link(&self) -> &L {
        &self.link
    }

    pub fn link_mut(&mut self) -> &mut L {
        &mut self.link
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn mount_root(&self) -> &str {
        &self.mount_root
    }

    fn abs(&self, path: &str) -> String {
        vpath::join(&self.cwd, path)
    }

    /// The bounded-staleness token for `path` (DESIGN.md §2.11): the
    /// highest version this session has observed, 0 if none.
    pub fn observed_floor(&self, path: &str) -> u64 {
        self.observed_floor.get(path).copied().unwrap_or(0)
    }

    fn observe_version(&mut self, path: &str, version: u64) {
        let e = self.observed_floor.entry(path.to_string()).or_insert(0);
        if version > *e {
            *e = version;
        }
    }

    /// Settle the floor map after the server applied one of OUR
    /// mutations: writes raise the target's floor to the acked version;
    /// removals CLEAR it (a recreated path restarts at version 1, and a
    /// floor surviving its file would wrongly refuse every replica until
    /// the recreation outran the old version).
    fn note_floor_applied(&mut self, op: &MetaOp, new_version: u64) {
        match op {
            MetaOp::Unlink { path } | MetaOp::Rmdir { path } => {
                self.observed_floor.remove(path);
            }
            MetaOp::Rename { from, to } => {
                self.observed_floor.remove(from);
                self.observe_version(to, new_version);
            }
            MetaOp::Mkdir { path }
            | MetaOp::Create { path }
            | MetaOp::Truncate { path, .. }
            | MetaOp::SetMode { path, .. }
            | MetaOp::WriteFull { path, .. }
            | MetaOp::WriteDelta { path, .. }
            | MetaOp::WriteRef { path, .. } => self.observe_version(path, new_version),
        }
    }

    // ---------------------------------------------------------------
    // consistency: notifications, reconnect, lease housekeeping
    // ---------------------------------------------------------------

    /// Process callback notifications + lease renewals. Called at every
    /// op boundary (the interposed calls are the poll points) and by the
    /// coordinator's background tick.
    pub fn tick(&mut self) {
        let now = self.clock.now();
        // reconnect detection: a new channel generation means callbacks
        // may have been lost while we were away -> distrust clean entries
        let gen = self.link.channel_generation();
        if gen != self.last_gen {
            self.last_gen = gen;
            let n = self.cache.suspect_all_clean(now);
            self.metrics.add(names::CACHE_INVALIDATIONS, n as u64);
            let _ = self.link.rpc(Request::RegisterCallback {
                root: self.mount_root.clone(),
                client_id: self.link.client_id(),
            });
            // re-acquire held locks under FRESH tokens: the server we
            // reconnected to (a restarted primary, or the promoted
            // secondary after a failover — DESIGN.md §2.7) lost or never
            // had our lock table. Already-lapsed leases are dropped
            // first, not resurrected. Only a DEFINITIVE server answer
            // (denied/refused) forfeits a lease — a transient transport
            // failure keeps it, and the generation stays bumped on a
            // failed reconnect so the next successful one retries here.
            self.lease.drop_expired(now);
            if self.link.is_connected() {
                for held in self.lease.held_leases() {
                    match self.link.rpc(Request::LockAcquire {
                        path: held.path.clone(),
                        kind: held.kind,
                        owner: self.link.client_id(),
                    }) {
                        Ok(Response::LockGranted { token, lease_ns }) => {
                            let now = self.clock.now();
                            self.lease.released(held.token);
                            self.lease.granted(
                                token,
                                &held.path,
                                held.kind,
                                now.add_secs(lease_ns as f64 / 1e9),
                            );
                            for t in self.fd_locks.values_mut() {
                                if *t == held.token {
                                    *t = token;
                                }
                            }
                        }
                        Ok(_) => {
                            // denied (another client legitimately took
                            // the lock while we were away) or refused:
                            // the lock is lost for real — like expiry
                            self.lease.released(held.token);
                            self.fd_locks.retain(|_, t| *t != held.token);
                        }
                        Err(_) => {
                            // transient transport failure: keep the
                            // lease; the renewal path below retries or
                            // expires it honestly
                        }
                    }
                }
            }
            // push any queued (possibly disconnected-time) mutations
            let _ = self.flush_queue();
        }
        for ev in self.link.drain_notifications() {
            match ev {
                NotifyEvent::Invalidate { path, new_version } => {
                    // the callback is an observation: raise the
                    // staleness floor so no replica read can regress
                    // behind what the server just announced
                    self.observe_version(&path, new_version);
                    let stale = self
                        .cache
                        .entry(&path)
                        .map(|e| e.version < new_version)
                        .unwrap_or(false);
                    if stale && self.cache.invalidate(&path, now) {
                        self.metrics.incr(names::CACHE_INVALIDATIONS);
                    }
                }
                NotifyEvent::Removed { path } => {
                    // versions restart at 1 on recreate: clear the floor
                    self.observed_floor.remove(&path);
                    self.cache.remove(&path, now);
                    self.metrics.incr(names::CACHE_INVALIDATIONS);
                }
                NotifyEvent::ServerRestart => {
                    let n = self.cache.suspect_all_clean(now);
                    self.metrics.add(names::CACHE_INVALIDATIONS, n as u64);
                    let _ = self.link.rpc(Request::RegisterCallback {
                        root: self.mount_root.clone(),
                        client_id: self.link.client_id(),
                    });
                }
            }
        }
        // lease renewals due
        self.lease.drop_expired(now);
        for token in self.lease.due_for_renewal(now) {
            match self.link.rpc(Request::LockRenew { token, owner: self.link.client_id() }) {
                Ok(Response::LockGranted { lease_ns, .. }) => {
                    self.metrics.incr(names::LEASE_RENEWALS);
                    self.lease.renewed(token, now.add_secs(lease_ns as f64 / 1e9));
                }
                _ => self.lease.released(token),
            }
        }
    }

    /// Ship the pending meta-op queue to the server. With compound RPC
    /// enabled (the default) the WHOLE queue travels as one
    /// `Request::Compound` round trip (chunked only past a frame budget)
    /// with per-op status; otherwise one round trip per op. Stops
    /// (keeping the remainder queued) on disconnection. Returns ops
    /// shipped.
    pub fn flush_queue(&mut self) -> Result<usize, FsError> {
        if !self.compound {
            return self.flush_queue_per_op();
        }
        let mut shipped = 0usize;
        loop {
            // ops are MOVED out for shipping (no payload clone — §Perf L3
            // #3) and restored on failure; the persisted entry stays on
            // disk until the server acknowledges.
            let pending = self.queue.take_all();
            if pending.is_empty() {
                return Ok(shipped);
            }
            // split off a frame-budget prefix; the remainder goes straight
            // back (order preserved) for the next round
            let mut batch: Vec<(u64, MetaOp)> = Vec::new();
            let mut rest: Vec<(u64, MetaOp)> = Vec::new();
            let mut bytes = 0u64;
            for (seq, op) in pending {
                let b = op.wire_bytes();
                if batch.is_empty() || (rest.is_empty() && bytes + b <= COMPOUND_MAX_BYTES) {
                    bytes += b;
                    batch.push((seq, op));
                } else {
                    rest.push((seq, op));
                }
            }
            self.queue.push_front_all(rest);

            let replies = match self.link.ship_compound(&batch) {
                Ok(r) => r,
                Err(e) => {
                    // nothing acknowledged: the whole batch replays later
                    // (idempotent per-op seqs make that safe even when
                    // only the reply was lost)
                    self.queue.push_front_all(batch);
                    return if matches!(e, FsError::Disconnected) { Ok(shipped) } else { Err(e) };
                }
            };
            if replies.len() != batch.len() {
                let got = replies.len();
                let want = batch.len();
                self.queue.push_front_all(batch);
                return Err(FsError::Protocol(format!(
                    "compound reply carries {got} results for {want} ops"
                )));
            }
            let mut error: Option<FsError> = None;
            let mut leftovers: Vec<(u64, MetaOp)> = Vec::new();
            for ((seq, op), reply) in batch.into_iter().zip(replies) {
                if error.is_some() {
                    // a local settle already failed: everything later is
                    // unsettled and goes back on the queue, in order
                    leftovers.push((seq, op));
                    continue;
                }
                match self.settle_compound_op(seq, &op, reply) {
                    Ok(Settle::Acked) => shipped += 1,
                    Ok(Settle::Dropped | Settle::Requeued) => {}
                    Err(e) => {
                        error = Some(e);
                        leftovers.push((seq, op));
                    }
                }
            }
            if let Some(e) = error {
                self.queue.push_front_all(leftovers);
                return Err(e);
            }
        }
    }

    /// Settle one compound reply against the queue/cache. `Requeued` ops
    /// (stale deltas demoted to full writes) carry a FRESH sequence
    /// number: later ops in the same compound may already have advanced
    /// the server's idempotence watermark past the failed seq, which
    /// would swallow a same-seq retry as a duplicate.
    fn settle_compound_op(&mut self, seq: u64, op: &MetaOp, reply: Response) -> Result<Settle, FsError> {
        let now = self.clock.now();
        match reply {
            Response::Applied { new_version, .. } => {
                match op {
                    MetaOp::WriteFull { path, .. } | MetaOp::WriteDelta { path, .. } => {
                        self.cache.mark_flushed(path, new_version, now)?;
                    }
                    MetaOp::Create { path } | MetaOp::Truncate { path, .. } => {
                        let _ = self.cache.mark_flushed(path, new_version, now);
                    }
                    _ => {}
                }
                if matches!(op, MetaOp::WriteFull { .. } | MetaOp::WriteDelta { .. }) {
                    self.metrics.incr(names::WRITEBACK_FILES);
                    self.metrics.add(names::WRITEBACK_BYTES, op.wire_bytes());
                }
                self.note_floor_applied(op, new_version);
                self.queue.ack(self.cache.store_mut(), seq, now)?;
                Ok(Settle::Acked)
            }
            Response::Err { code: 116, .. } => {
                let MetaOp::WriteDelta { path, .. } = op else {
                    return Err(FsError::Protocol("stale non-delta op".into()));
                };
                match self.demoted_full_write(path) {
                    Ok(full) => {
                        // re-queue the demoted full write (latest cache
                        // content — last-close-wins) under a fresh seq,
                        // PERSISTING IT BEFORE retiring the stale delta's
                        // entry: a crash in between must leave at least
                        // one shippable entry on disk (replaying both is
                        // idempotent — the delta just demotes again)
                        self.queue.append(self.cache.store_mut(), full, now)?;
                        self.queue.ack(self.cache.store_mut(), seq, now)?;
                        Ok(Settle::Requeued)
                    }
                    Err(FsError::NotFound(_)) => {
                        // the cached copy vanished beneath the queued delta
                        // (an unlink/rename is queued behind it): drop the
                        // delta — the later op carries the final truth
                        self.metrics.incr("metaq.apply_errors");
                        self.queue.ack(self.cache.store_mut(), seq, now)?;
                        Ok(Settle::Dropped)
                    }
                    Err(e) => Err(e),
                }
            }
            Response::Err { code: 2, msg } => {
                // replay-on-ghost: the op's target was unlinked (at home,
                // or by a later queued op) while this one sat queued.
                // Skip JUST this op — the rest of the queue must drain;
                // erroring here would wedge every later op behind a ghost.
                self.metrics.incr(names::METAQ_REPLAY_SKIPPED);
                let _ = msg;
                self.queue.ack(self.cache.store_mut(), seq, now)?;
                Ok(Settle::Dropped)
            }
            Response::Err { code, msg } => {
                // the home-space op failed semantically (e.g. the user
                // removed the parent dir at home). Drop the op — the
                // cache keeps the local truth; surfaced via metrics.
                self.metrics.incr("metaq.apply_errors");
                let _ = (code, msg);
                self.queue.ack(self.cache.store_mut(), seq, now)?;
                Ok(Settle::Dropped)
            }
            r => Err(FsError::Protocol(format!("unexpected compound op reply {r:?}"))),
        }
    }

    /// Pre-v2 flush path: one `Request::Apply` round trip per queued op.
    /// Kept behind [`Self::compound`] = false so the `compound_rpc`
    /// ablation can quantify what batching saves.
    fn flush_queue_per_op(&mut self) -> Result<usize, FsError> {
        let now = self.clock.now();
        let mut shipped = 0;
        while let Some((seq, op)) = self.queue.take_front() {
            match self.link.ship(seq, &op) {
                Ok(Response::Applied { new_version, .. }) => {
                    match &op {
                        MetaOp::WriteFull { path, .. } | MetaOp::WriteDelta { path, .. } => {
                            self.cache.mark_flushed(path, new_version, now)?;
                        }
                        MetaOp::Create { path } | MetaOp::Truncate { path, .. } => {
                            let _ = self.cache.mark_flushed(path, new_version, now);
                        }
                        _ => {}
                    }
                    if matches!(op, MetaOp::WriteFull { .. } | MetaOp::WriteDelta { .. }) {
                        self.metrics.incr(names::WRITEBACK_FILES);
                        self.metrics.add(names::WRITEBACK_BYTES, op.wire_bytes());
                    }
                    self.note_floor_applied(&op, new_version);
                    self.queue.ack(self.cache.store_mut(), seq, now)?;
                    shipped += 1;
                }
                Ok(Response::Err { code: 116, .. }) => {
                    // stale delta base: demote to a full write and retry
                    if let MetaOp::WriteDelta { path, .. } = &op {
                        match self.demoted_full_write(path) {
                            Ok(full) => {
                                self.queue.push_front(seq, full.clone());
                                self.queue.replace(self.cache.store_mut(), seq, full, now)?;
                                continue;
                            }
                            Err(FsError::NotFound(_)) => {
                                // cached copy vanished beneath the queued
                                // delta (an unlink/rename is queued behind
                                // it): drop the delta, like the compound
                                // path — the later op carries the truth
                                self.metrics.incr("metaq.apply_errors");
                                self.queue.ack(self.cache.store_mut(), seq, now)?;
                                continue;
                            }
                            Err(e) => {
                                self.queue.push_front(seq, op);
                                return Err(e);
                            }
                        }
                    }
                    self.queue.push_front(seq, op);
                    return Err(FsError::Protocol("stale non-delta op".into()));
                }
                Ok(Response::Err { code: 2, .. }) => {
                    // replay-on-ghost: target unlinked while the op sat
                    // queued — skip it, keep draining (see the compound
                    // settle path)
                    self.metrics.incr(names::METAQ_REPLAY_SKIPPED);
                    self.queue.ack(self.cache.store_mut(), seq, now)?;
                }
                Ok(Response::Err { code, msg }) => {
                    // the home-space op failed semantically (e.g. the user
                    // removed the parent dir at home). Drop the op — the
                    // cache keeps the local truth; surfaced via metrics.
                    self.metrics.incr("metaq.apply_errors");
                    let _ = (code, msg);
                    self.queue.ack(self.cache.store_mut(), seq, now)?;
                }
                Ok(_) => {
                    self.queue.push_front(seq, op);
                    return Err(FsError::Protocol("unexpected apply response".into()));
                }
                Err(FsError::Disconnected) => {
                    self.queue.push_front(seq, op);
                    return Ok(shipped);
                }
                Err(e) => {
                    self.queue.push_front(seq, op);
                    return Err(e);
                }
            }
        }
        Ok(shipped)
    }

    fn enqueue(&mut self, op: MetaOp) -> Result<(), FsError> {
        let now = self.clock.now();
        self.queue.append(self.cache.store_mut(), op, now)?;
        self.metrics.incr(names::METAQ_APPENDS);
        match self.writeback {
            WritebackMode::SyncOnClose => {
                let _ = self.flush_queue()?;
            }
            WritebackMode::Async => {
                if self.queue.len() >= self.async_flush_threshold {
                    let _ = self.flush_queue()?;
                }
            }
        }
        Ok(())
    }

    // ---------------------------------------------------------------
    // namespace materialization + prefetch
    // ---------------------------------------------------------------

    /// Ensure a directory's entries are materialized in cache space
    /// (paper: first `opendir()` downloads the entries + attributes).
    fn ensure_dir(&mut self, dir: &str) -> Result<(), FsError> {
        let now = self.clock.now();
        if self.cache.is_localized(dir) {
            self.cache.store_mut().mkdir_p(dir, now)?;
            return Ok(());
        }
        if self.cache.dir_state(dir).map(|d| d.complete).unwrap_or(false) {
            self.cache_disk.op(self.clock.as_ref());
            return Ok(());
        }
        match self.link.rpc(Request::ReadDir { path: dir.to_string() })? {
            Response::Dir { entries } => {
                let pairs: Vec<(String, WireAttr)> =
                    entries.into_iter().map(|e| (e.name, e.attr)).collect();
                let now = self.clock.now();
                self.cache.materialize_dir(dir, &pairs, now)?;
                // writing the placeholder + attr files costs cache-disk ops
                self.cache_disk.op(self.clock.as_ref());
                Ok(())
            }
            Response::Err { code: 2, msg } => Err(FsError::NotFound(msg)),
            Response::Err { code: 20, msg } => Err(FsError::NotADir(msg)),
            r => Err(FsError::Protocol(format!("unexpected readdir response {r:?}"))),
        }
    }

    /// Parallel pre-fetch of small files in `dir` (paper §3.3: every time
    /// the user or application first changes into a mounted directory).
    fn prefetch_dir(&mut self, dir: &str) -> Result<(), FsError> {
        if !self.cfg.stripe.prefetch_enabled
            || self.cache.dir_state(dir).map(|d| d.prefetched).unwrap_or(false)
        {
            return Ok(());
        }
        let limit = self.cfg.stripe.prefetch_max_size;
        let mut want: Vec<(String, u64)> = Vec::new();
        for (name, attr) in self.cache.readdir(dir)? {
            if attr.kind != NodeKind::File || attr.size > limit {
                continue;
            }
            let p = vpath::join(dir, &name);
            if matches!(
                self.cache.entry(&p).map(|e| e.state),
                Some(EntryState::AttrOnly) | Some(EntryState::Invalid)
            ) {
                want.push((p, attr.size));
            }
        }
        if !want.is_empty() {
            let images = self.link.prefetch(&want);
            let now = self.clock.now();
            let mut bytes = 0u64;
            for image in images {
                transfer::verify_image(&self.engine, &image, self.cfg.stripe.min_block as usize, &self.metrics)?;
                bytes += image.data.len() as u64;
                let attr = WireAttr {
                    kind: NodeKind::File,
                    size: image.data.len() as u64,
                    mtime_ns: now.0,
                    mode: 0o600,
                    version: image.version,
                };
                self.metrics.incr(names::PREFETCH_FILES);
                self.cache.install(&image.path, &image.data, image.version, image.digests.clone(), attr, now)?;
            }
            // writing the prefetched files into cache space
            self.cache_disk.io(self.clock.as_ref(), bytes);
            self.enforce_cache_budget();
        }
        self.cache.set_dir_prefetched(dir);
        Ok(())
    }

    /// The file's logical size: entry attributes when indexed (content
    /// may be only partially resident), store size otherwise (localized
    /// files live purely in the cache store).
    fn logical_size(&self, path: &str) -> u64 {
        match self.cache.entry(path) {
            Some(e) => e.attr.size,
            None => self.cache.store().stat(path).map(|a| a.size).unwrap_or(0),
        }
    }

    /// Make sure `path` has a trusted entry for paged access: a cache hit
    /// if the content state is usable, otherwise one `FetchMeta` round
    /// trip — no content moves here; reads fault blocks on demand.
    fn ensure_entry(&mut self, path: &str) -> Result<(), FsError> {
        if self.content_usable(path) {
            return Ok(());
        }
        self.metrics.incr(names::CACHE_MISSES);
        self.refresh_meta(path)
    }

    /// Fetch authoritative metadata (version/size/digests) and
    /// (re)initialize the entry's block grid. Resident blocks survive
    /// when the version is unchanged (revalidation).
    fn refresh_meta(&mut self, path: &str) -> Result<(), FsError> {
        // the bounded-staleness token rides every metadata fetch: a
        // read-serving replica behind this floor answers 119 and the
        // link retries toward the primary (DESIGN.md §2.11)
        let min_version = self.observed_floor(path);
        match self.link.rpc(Request::FetchMeta { path: path.to_string(), min_version }) {
            Ok(Response::FileMeta { version, size, digests }) => {
                let now = self.clock.now();
                self.observe_version(path, version);
                self.cache.begin_paged(path, version, size, digests, now)?;
                Ok(())
            }
            Ok(Response::Err { code: 2, msg }) => Err(FsError::NotFound(msg)),
            Ok(Response::Err { code: 21, msg }) => Err(FsError::IsADir(msg)),
            Ok(Response::Err { code: 111, .. }) => Err(FsError::Disconnected),
            // 119: every replica in reach (and the fallback) refused the
            // staleness floor — transient by construction (shipping
            // catches the replica up); surface as a disconnect so the
            // op-boundary retry loop re-runs the fetch
            Ok(Response::Err { code: 119, .. }) => Err(FsError::Disconnected),
            // 118: the server refused the digest pass over rotted bytes
            // (DESIGN.md §2.10) — surface the typed refusal, never data
            Ok(Response::Err { code: 118, msg }) => Err(FsError::Corrupted(msg)),
            Ok(r) => Err(FsError::Protocol(format!("unexpected fetch-meta response {r:?}"))),
            Err(e) => Err(e),
        }
    }

    /// Fault the missing blocks of `[off, off+len)` into the cache (plus
    /// the configured readahead window), verifying every received block
    /// against the entry's digest vector. Retries once through a
    /// metadata refresh when the home copy moved mid-fetch (torn-fetch
    /// protection); locally-dirty blocks always survive the refresh
    /// (last-close-wins).
    fn fault_range(&mut self, path: &str, off: u64, len: u64) -> Result<(), FsError> {
        if self.cache.is_localized(path) {
            return Ok(());
        }
        let bb = self.cfg.stripe.min_block.max(1);
        for attempt in 0..2 {
            let Some(e) = self.cache.entry(path) else { return Ok(()) };
            let size = e.attr.size;
            let version = e.version;
            if size == 0 || off >= size || len == 0 {
                return Ok(());
            }
            let end = off.saturating_add(len).min(size);
            let ra_end = end.saturating_add(self.cfg.cache.readahead_blocks * bb).min(size);
            let missing = e.residency.missing_extents(off / bb, ra_end.div_ceil(bb));
            if missing.is_empty() {
                return Ok(());
            }
            if version == 0 {
                // never at home (local creation): nothing to fault from
                return Ok(());
            }
            let expected = self.cache.entry(path).map(|e| e.digests.clone()).unwrap_or_default();
            let mut stale = false;
            'extents: for (first_block, count) in missing {
                let foff = first_block * bb;
                let flen = (count * bb).min(size - foff);
                // a torn transfer (`Interrupted`) is transient, not
                // fatal: blocks the link already delivered are installed,
                // so re-requesting the extent naturally resumes from the
                // missing remainder
                let mut resumes = 0u32;
                loop {
                    match self.link.fetch_range(path, foff, flen, version) {
                        Ok(image) => {
                            transfer::verify_extents(
                                &self.engine,
                                path,
                                &image.extents,
                                bb as usize,
                                &self.metrics,
                            )?;
                            if image
                                .extents
                                .iter()
                                .any(|x| expected.get(x.index as usize) != Some(&x.digest))
                            {
                                // the digest grid moved: the version changed
                                // between our FetchMeta and this range
                                stale = true;
                                break 'extents;
                            }
                            let bytes = image.bytes();
                            // integrity verification is client CPU on the
                            // transfer path
                            self.clock.advance_secs(bytes as f64 / self.cfg.disk.digest_cpu_bps);
                            // the faulted blocks land on the cache-space FS
                            self.cache_disk.io(self.clock.as_ref(), bytes);
                            let now = self.clock.now();
                            self.cache.install_blocks(path, &image.extents, now)?;
                            self.metrics.add(names::FETCH_BYTES, bytes);
                            // transport v2 (DESIGN.md §2.12): a sequential
                            // scan will fault the NEXT same-sized extent
                            // next — let the link start that transfer now
                            // and overlap it with the app's compute. Pure
                            // advisory: a wrong guess is dropped by the
                            // link and the demand fault re-fetches.
                            if self.cfg.transfer.pipeline {
                                let next = foff + flen;
                                let hlen = flen.min(size.saturating_sub(next));
                                if hlen > 0 {
                                    self.link.pipeline_hint(path, next, hlen, version);
                                }
                            }
                            break;
                        }
                        Err(FsError::Stale(_)) => {
                            stale = true;
                            break 'extents;
                        }
                        Err(FsError::Interrupted { .. }) if resumes < 2 => {
                            resumes += 1;
                            self.metrics.incr(names::RESUMED_FETCHES);
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
            // re-stamp the whole faulted window at the current instant so
            // the budget enforcement below cannot evict blocks the caller
            // is about to consume (the clock advanced between extents)
            let now = self.clock.now();
            self.cache.touch_blocks(path, off / bb, ra_end.div_ceil(bb), now);
            self.enforce_cache_budget();
            if !stale {
                return Ok(());
            }
            if attempt == 0 {
                self.metrics.incr(names::CACHE_INVALIDATIONS);
                if let Some(e) = self.cache.entry_mut(path) {
                    // Dirty stays Dirty: begin_paged preserves the dirty
                    // blocks across the version refresh (last-close-wins)
                    if e.state != EntryState::Dirty {
                        e.state = EntryState::Invalid;
                    }
                }
                self.refresh_meta(path)?;
            }
        }
        Err(FsError::Stale(format!("{path} kept changing during paged fetch")))
    }

    /// Fault a file's entire content in — the degenerate whole-range
    /// fault, used by whole-file mode, `truncate`, and the full-write
    /// fallbacks.
    fn ensure_full(&mut self, path: &str) -> Result<(), FsError> {
        let size = self.logical_size(path);
        self.fault_range(path, 0, size.max(1))
    }

    /// Fetch a file whole into cache — the paper's first-`open()`
    /// behaviour, now a thin "fault the whole range" wrapper kept for
    /// whole-file mode (`paging = false`) and full-content paths.
    fn fetch_file(&mut self, path: &str) -> Result<(), FsError> {
        self.ensure_entry(path)?;
        self.metrics.incr(names::FETCH_FILES);
        self.ensure_full(path)
    }

    /// Build the full-write demotion of a stale delta: the entire cache
    /// copy of `path`, faulting any non-resident clean blocks in first
    /// (the paged plane may hold only the dirtied ones).
    fn demoted_full_write(&mut self, path: &str) -> Result<MetaOp, FsError> {
        self.ensure_full(path)?;
        let data = self.cache.store().read(path)?.to_vec();
        let digests = self.engine.digests(&data, self.cfg.stripe.min_block as usize);
        // base_version 0: the faulting refresh above already folded the
        // current home base under our dirty blocks, so the demoted write
        // is an informed overwrite, not a blind disconnected one
        Ok(MetaOp::WriteFull { path: path.to_string(), data, digests, base_version: 0 })
    }

    /// Apply the `cache.budget_bytes` LRU block eviction and surface the
    /// evicted volume in metrics.
    fn enforce_cache_budget(&mut self) {
        let now = self.clock.now();
        let (blocks, bytes) = self.cache.enforce_budget(now);
        if blocks > 0 {
            self.metrics.add(names::CACHE_EVICTIONS, blocks);
            self.metrics.add(names::CACHE_EVICTED_BYTES, bytes);
        }
    }

    /// Merge a written sparse shadow back into the cache copy at close:
    /// copy the dirtied blocks, mark them in the residency map, patch the
    /// per-block digest vector (identical to re-digesting the whole file
    /// — digests are per block), and queue the block-granular writeback.
    /// This is the paper's aggregate-on-close, re-planned against the
    /// residency map instead of a whole-file digest compare.
    fn merge_shadow(&mut self, path: &str, sh: &ShadowState, localized: bool) -> Result<(), FsError> {
        let bb = self.cfg.stripe.min_block.max(1);
        let new_size = sh.size;
        let base_blocks = sh.base_size.div_ceil(bb);
        let total_blocks = new_size.div_ceil(bb);
        // dirty set: every block the fd wrote, plus any wholly-new hole
        // blocks beyond the base (their content is zeros)
        let mut dirty: Vec<u64> = sh.blocks.iter().copied().filter(|&b| b * bb < new_size).collect();
        for b in base_blocks..total_blocks {
            if !sh.blocks.contains(&b) {
                dirty.push(b);
            }
        }
        dirty.sort_unstable();
        dirty.dedup();

        // decide full-vs-delta BEFORE merging, so a full write can fault
        // the non-resident base blocks first
        let (base_version, old_digests) = match self.cache.entry(path) {
            Some(e) if !localized => (e.version, e.digests.clone()),
            _ => (0, Vec::new()),
        };
        let dirty_bytes: u64 =
            dirty.iter().map(|&b| Residency::block_len(b as usize, new_size, bb)).sum();
        let connected = self.link.is_connected();
        // would the full-write fallback need the WAN? (any base block
        // neither resident nor overwritten by this close)
        let missing_base = self
            .cache
            .entry(path)
            .map(|e| {
                (0..base_blocks as usize).any(|b| {
                    !e.residency.is_present(b) && dirty.binary_search(&(b as u64)).is_err()
                })
            })
            .unwrap_or(false);
        // OFFLINE with non-resident base blocks, a delta of the dirtied
        // blocks is the only shippable form — a full write would have to
        // fault the base over a dead link, and "no mutating op blocks on
        // a remote call" (paper §3.1) outranks the stale-base risk (a
        // stale delta demotes after reconnect, against a fresh base).
        // CONNECTED closes use deltas as the payload optimization they
        // are; a disconnected close of a FULLY-resident file aggregates
        // the full content and carries the base version, so the replay
        // can detect a conflicting home-side edit (DESIGN.md §2.5).
        let offline_partial = !connected && missing_base;
        let use_delta = !localized
            && base_version > 0
            && !old_digests.is_empty()
            && (offline_partial
                || (self.cfg.stripe.delta_writeback
                    && connected
                    // a delta must actually save payload to be worth
                    // the stale-base risk
                    && dirty_bytes * 2 < new_size.max(1)));

        // the dirtied blocks become the cache copy (undirtied base
        // blocks are already there — or still non-resident, which the
        // residency map keeps honest)
        let mut copy_bytes = 0u64;
        let now = self.clock.now();
        for &b in &dirty {
            let bstart = b * bb;
            let blen = Residency::block_len(b as usize, new_size, bb) as usize;
            let mut block = if sh.blocks.contains(&b) {
                self.cache.store().read_at(&sh.path, bstart, blen)?.to_vec()
            } else {
                Vec::new()
            };
            block.resize(blen, 0); // hole tails within a block are zeros
            self.cache.store_mut().write_at(path, bstart, &block, now)?;
            copy_bytes += blen as u64;
        }
        self.cache_disk.io(self.clock.as_ref(), copy_bytes);

        if localized {
            // stays local; nothing queued (paper: localized dirs)
            return Ok(());
        }

        // record the merged blocks in the residency map BEFORE any
        // full-write faulting, so the fallback faults only the UNDIRTIED
        // base blocks — never content this fd just overwrote. One
        // fault_range call covers every gap, so its end-of-fault restamp
        // protects the whole window from the budget eviction.
        self.cache.mark_dirty_blocks(path, &dirty, old_digests.clone(), new_size, now)?;
        let (op, digests) = if use_delta {
            // patch the digest vector at the dirty indices (identical to
            // re-digesting the whole file — digests are per block);
            // digest planning is client CPU on the close path
            self.clock.advance_secs(copy_bytes as f64 / self.cfg.disk.digest_cpu_bps);
            let mut digests = old_digests;
            digests.resize(total_blocks as usize, 0);
            let mut blocks: Vec<(u32, Vec<u8>)> = Vec::with_capacity(dirty.len());
            for &b in &dirty {
                let bstart = b * bb;
                let blen = Residency::block_len(b as usize, new_size, bb) as usize;
                let data = self.cache.store().read_at(path, bstart, blen)?.to_vec();
                digests[b as usize] = self.engine.digests(&data, bb as usize)[0];
                blocks.push((b as u32, data));
            }
            self.metrics.add(names::WRITEBACK_BYTES_SAVED, new_size.saturating_sub(dirty_bytes));
            let mut op = MetaOp::WriteDelta {
                path: path.to_string(),
                total_size: new_size,
                base_version,
                blocks,
                digests: digests.clone(),
            };
            if self.cfg.transfer.compress {
                transfer::compress::compress_delta_op(&mut op, &self.metrics);
            }
            (op, digests)
        } else {
            // full write: fault the undirtied base blocks in, then digest
            // the shipped content whole — a faulting refresh may have
            // mixed in a newer base, so patching the old vector would
            // poison the server's digest cache
            self.fault_range(path, 0, sh.base_size)?;
            let data = self.cache.store().read(path)?.to_vec();
            self.clock.advance_secs(data.len() as f64 / self.cfg.disk.digest_cpu_bps);
            let digests = self.engine.digests(&data, bb as usize);
            // a DISCONNECTED close records which home version this
            // content was derived from: if the home copy moves past it
            // before the replay lands, the server preserves its copy as
            // a `.xufs-conflict-<client>-<seq>` file instead of silently losing
            // it. Connected closes keep plain last-close-wins (the
            // callback channel already told us about concurrent writers).
            // Only the FIRST write of a disconnected chain carries the
            // base: a later close for the same path supersedes our own
            // earlier queued write — same client, totally ordered, not a
            // conflict (and digest-equal replays never conflict anyway).
            let chain_pending = self.queue.pending().iter().any(|(_, op)| {
                matches!(op, MetaOp::WriteFull { .. } | MetaOp::WriteDelta { .. })
                    && op.path() == path
            });
            let conflict_base = if connected || chain_pending { 0 } else { base_version };
            let op = MetaOp::WriteFull {
                path: path.to_string(),
                data,
                digests: digests.clone(),
                base_version: conflict_base,
            };
            (op, digests)
        };
        let now = self.clock.now();
        self.cache.mark_dirty_blocks(path, &dirty, digests, new_size, now)?;
        self.enqueue(op)?;
        self.enforce_cache_budget();
        Ok(())
    }

    /// Re-queue a renamed dirty entry's content under its NEW name,
    /// behind the rename op (see the rename path): the fully-resident
    /// case ships the whole file; a partially-resident entry (a delta
    /// close) ships exactly its dirty blocks as a delta. Either way, if
    /// the base later proves stale the demotion now runs against `t` —
    /// where the entry and cache copy actually live — so the dirty
    /// blocks survive (last-close-wins) instead of ghosting.
    fn requeue_dirty_under_new_name(
        &mut self,
        t: &str,
        e: &crate::cache::CacheEntry,
    ) -> Result<(), FsError> {
        let bb = self.cfg.stripe.min_block.max(1);
        let fully = e.residency.blocks() == 0
            || e.residency.present_blocks() == e.residency.blocks();
        if fully {
            let data = self.cache.store().read(t)?.to_vec();
            let digests = self.engine.digests(&data, bb as usize);
            return self.enqueue(MetaOp::WriteFull {
                path: t.to_string(),
                data,
                digests,
                base_version: 0,
            });
        }
        if e.version == 0 {
            // never at home and not fully resident: nothing shippable
            return Ok(());
        }
        let size = e.attr.size;
        let mut blocks: Vec<(u32, Vec<u8>)> = Vec::new();
        for b in 0..e.residency.blocks() {
            if e.residency.is_dirty(b) {
                let bstart = b as u64 * bb;
                let blen = Residency::block_len(b, size, bb) as usize;
                blocks.push((b as u32, self.cache.store().read_at(t, bstart, blen)?.to_vec()));
            }
        }
        if blocks.is_empty() {
            return Ok(());
        }
        let mut op = MetaOp::WriteDelta {
            path: t.to_string(),
            total_size: size,
            base_version: e.version,
            blocks,
            digests: e.digests.clone(),
        };
        if self.cfg.transfer.compress {
            transfer::compress::compress_delta_op(&mut op, &self.metrics);
        }
        self.enqueue(op)
    }

    /// Is the cached copy usable for an open right now?
    fn content_usable(&self, path: &str) -> bool {
        match self.cache.entry(path) {
            Some(e) => match e.state {
                EntryState::Clean | EntryState::Dirty => true,
                EntryState::Invalid | EntryState::AttrOnly => false,
            },
            None => false,
        }
    }

    /// Serve a stat from local state if possible (paper: stat() reads the
    /// hidden attribute files). `None` means the server must be asked.
    fn stat_local(&mut self, abs_path: &str) -> Option<MetaResult> {
        if self.cache.is_localized(abs_path) {
            self.cache_disk.op(self.clock.as_ref());
            return Some(match self.cache.store().stat(abs_path) {
                Ok(a) => MetaResult::Attr(WireAttr::from_attr(&a)),
                Err(e) => MetaResult::Err(e),
            });
        }
        let cached = self.cache.entry(abs_path).and_then(|e| {
            if e.state != EntryState::Invalid { Some(e.attr.clone()) } else { None }
        });
        if let Some(attr) = cached {
            self.cache_disk.op(self.clock.as_ref());
            return Some(MetaResult::Attr(attr));
        }
        let parent = vpath::parent(abs_path);
        if self.cache.dir_state(&parent).map(|d| d.complete).unwrap_or(false)
            && self.cache.entry(abs_path).is_none()
        {
            // a complete parent listing makes absence a reliable negative
            return Some(MetaResult::Err(FsError::NotFound(abs_path.to_string())));
        }
        None
    }

    /// Resolve one buffered run of cache-missing [`Vfs::batch`] stats
    /// with a single `Request::Compound`. In sync-on-close mode the
    /// queued mutations that PRECEDED the run flush first, so each stat
    /// observes exactly the batch prefix before it — the sequential-
    /// lowering semantics the trait default defines. Transport failures
    /// fail the affected stats per-op; only protocol violations abort.
    fn resolve_batch_stats(
        &mut self,
        mode: WritebackMode,
        pending: &mut Vec<(usize, String)>,
        out: &mut [MetaResult],
    ) -> Result<(), FsError> {
        if pending.is_empty() {
            return Ok(());
        }
        if matches!(mode, WritebackMode::SyncOnClose) {
            let _ = self.flush_queue()?;
        }
        let req = Request::Compound {
            ops: pending.iter().map(|(_, p)| CompoundOp::Stat { path: p.clone() }).collect(),
        };
        match self.link.rpc(req) {
            Ok(Response::CompoundReply { replies }) if replies.len() == pending.len() => {
                for ((i, p), reply) in pending.drain(..).zip(replies) {
                    out[i] = match reply {
                        Response::Attr { attr } => {
                            // refresh the cached attributes
                            if let Some(e) = self.cache.entry_mut(&p) {
                                e.attr = attr.clone();
                            }
                            MetaResult::Attr(attr)
                        }
                        Response::Err { code: 2, msg } => MetaResult::Err(FsError::NotFound(msg)),
                        r => MetaResult::Err(FsError::Protocol(format!(
                            "unexpected stat reply {r:?}"
                        ))),
                    };
                }
                Ok(())
            }
            Ok(r) => Err(FsError::Protocol(format!("unexpected compound reply {r:?}"))),
            Err(e) => {
                // transport failure: the batched stats fail per-op so the
                // mutations (already shipped or queued) are not lost
                for (i, _) in pending.drain(..) {
                    out[i] = MetaResult::Err(e.clone());
                }
                Ok(())
            }
        }
    }
}

impl<L: ServerLink> Vfs for XufsClient<L> {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd, FsError> {
        // v2 contract: bad flag combinations die here, not deep in the
        // data path
        let flags = flags.validate()?;
        self.tick();
        let t0 = self.clock.now();
        let p = self.abs(path);
        let now = self.clock.now();
        let localized = self.cache.is_localized(&p);

        if localized {
            // localized files live purely in cache space
            if !self.cache.store().exists(&p) {
                if !flags.is_create() {
                    return Err(FsError::NotFound(p));
                }
                self.cache.store_mut().mkdir_p(&vpath::parent(&p), now)?;
                self.cache.store_mut().create(&p, now)?;
            } else if flags.is_truncate() {
                self.cache.store_mut().truncate(&p, 0, now)?;
            }
            self.cache_disk.op(self.clock.as_ref());
        } else if self.content_usable(&p) {
            // a disconnected open must stay readable to EOF: a partially-
            // resident entry cannot promise that offline (unless O_TRUNC
            // makes the old content irrelevant), so fail at open rather
            // than Disconnected mid-scan on the first missing block
            if !flags.is_truncate() && !self.link.is_connected() {
                let fully = self
                    .cache
                    .entry(&p)
                    .map(|e| {
                        e.attr.size == 0
                            || e.residency.present_blocks() == e.residency.blocks()
                    })
                    .unwrap_or(false);
                if !fully {
                    return Err(FsError::Disconnected);
                }
            }
            self.metrics.incr(names::CACHE_HITS);
            self.cache.touch(&p, now);
            if flags.is_truncate() {
                self.cache.store_mut().truncate(&p, 0, now)?;
            }
            self.cache_disk.op(self.clock.as_ref());
        } else if flags.is_write() && flags.is_truncate() {
            // O_TRUNC write: the old content is irrelevant (last-close-
            // wins), so no WAN round trip is needed — the file starts
            // empty locally and a Create (idempotent at the server) is
            // queued so the entry exists at home even before the close
            // flush. This is also what lets disconnected creation work.
            self.cache.store_mut().mkdir_p(&vpath::parent(&p), now)?;
            self.cache.store_mut().write(&p, &[], now)?;
            self.cache.mark_dirty(&p, Vec::new(), now)?;
            self.enqueue(MetaOp::Create { path: p.clone() })?;
            self.cache_disk.op(self.clock.as_ref());
        } else {
            // need the authoritative copy (or to create one)
            let exists_remotely = match self.cache.entry(&p) {
                Some(_) => true,
                None => {
                    // unknown: if the parent listing is complete, absence
                    // is a reliable negative; otherwise ask the server
                    let parent = vpath::parent(&p);
                    if self.cache.dir_state(&parent).map(|d| d.complete).unwrap_or(false) {
                        false
                    } else {
                        match self.link.rpc(Request::Stat { path: p.clone() }) {
                            Ok(Response::Attr { .. }) => true,
                            Ok(Response::Err { code: 2, .. }) => false,
                            Ok(r) => {
                                return Err(FsError::Protocol(format!("unexpected stat response {r:?}")))
                            }
                            // offline with nothing cached and creation not
                            // requested: fail disconnected; with O_CREAT we
                            // can proceed optimistically (queued Create)
                            Err(FsError::Disconnected) if flags.is_create() => false,
                            Err(e) => return Err(e),
                        }
                    }
                }
            };
            if exists_remotely {
                // paged: one FetchMeta round trip, content faults on
                // demand. Whole-file mode (the ablation baseline) pulls
                // everything here, like the paper's first open()
                let r = if self.paging { self.ensure_entry(&p) } else { self.fetch_file(&p) };
                match r {
                    Ok(()) => {}
                    Err(FsError::Disconnected) => {
                        // disconnected operation: serve the stale cached
                        // copy, but only when EVERY block survives
                        // locally — a successful open must stay readable
                        // to EOF, not fail Disconnected mid-scan on the
                        // first non-resident block
                        let has_content = self
                            .cache
                            .entry(&p)
                            .map(|e| {
                                e.attr.size == 0
                                    || (e.residency.blocks() > 0
                                        && e.residency.present_blocks() == e.residency.blocks())
                            })
                            .unwrap_or(false);
                        if !has_content {
                            return Err(FsError::Disconnected);
                        }
                    }
                    Err(e) => return Err(e),
                }
            } else {
                if !flags.is_create() {
                    return Err(FsError::NotFound(p));
                }
                // brand-new file: created locally, Create queued
                self.cache.store_mut().mkdir_p(&vpath::parent(&p), now)?;
                if !self.cache.store().exists(&p) {
                    self.cache.store_mut().create(&p, now)?;
                }
                self.cache.mark_dirty(&p, Vec::new(), now)?;
                self.enqueue(MetaOp::Create { path: p.clone() })?;
            }
            self.cache_disk.op(self.clock.as_ref());
        }

        let shadow = if flags.is_write() {
            // writes land in a SPARSE shadow (paper §3.1, block-granular
            // since DESIGN.md §2.4): it starts empty and materializes
            // only the blocks writes touch — reads through the fd fall
            // back to the base content, so read-after-write stays
            // coherent without copying (or even fetching) the base
            let name = vpath::shadow_file_name(&vpath::basename(&p), self.next_fd);
            let spath = vpath::join(&vpath::parent(&p), &name);
            let now = self.clock.now();
            self.cache.store_mut().write(&spath, &[], now)?;
            let base_size = if flags.is_truncate() { 0 } else { self.logical_size(&p) };
            Some(ShadowState { path: spath, blocks: BTreeSet::new(), size: base_size, base_size })
        } else {
            None
        };

        let fd = self.next_fd;
        self.next_fd += 1;
        let pos = if flags.is_append() { self.logical_size(&p) } else { 0 };
        self.fds.insert(fd, OpenFile { path: p, pos, flags, shadow, wrote: false, localized });
        self.metrics.observe(names::OP_LATENCY, self.clock.now().saturating_sub(t0).as_secs_f64());
        Ok(Fd(fd))
    }

    fn pread(&mut self, fd: Fd, buf: &mut [u8], off: u64) -> Result<usize, FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        let path = f.path.clone();
        let localized = f.localized;
        let bb = self.cfg.stripe.min_block.max(1);
        // write fds read through their sparse shadow (read-your-writes
        // coherence within the fd); read fds page the base in on demand.
        // Snapshot only the dirty blocks overlapping this read, not the
        // whole set.
        let shadow = f.shadow.as_ref().map(|s| {
            let first = off / bb;
            let last = off.saturating_add(buf.len() as u64).div_ceil(bb) + 1;
            let blocks: Vec<u64> = s.blocks.range(first..last).copied().collect();
            (s.path.clone(), blocks, s.size, s.base_size)
        });
        let n = match shadow {
            None => {
                let size = self.logical_size(&path);
                if off >= size || buf.is_empty() {
                    0
                } else {
                    let n = (size - off).min(buf.len() as u64) as usize;
                    self.fault_range(&path, off, n as u64)?;
                    let got = {
                        let data = self.cache.store().read_at(&path, off, n)?;
                        buf[..data.len()].copy_from_slice(&data);
                        data.len()
                    };
                    if !localized {
                        let now = self.clock.now();
                        let last = off.saturating_add(got as u64).div_ceil(bb);
                        self.cache.touch_blocks(&path, off / bb, last, now);
                    }
                    got
                }
            }
            Some((spath, sblocks, ssize, base_size)) => {
                if off >= ssize || buf.is_empty() {
                    0
                } else {
                    // assemble per block: dirtied blocks from the shadow,
                    // the rest from the (faulted-on-demand) base; holes
                    // beyond the base read as zeros
                    let n = (ssize - off).min(buf.len() as u64) as usize;
                    buf[..n].fill(0);
                    let mut done = 0usize;
                    while done < n {
                        let cur = off + done as u64;
                        let b = cur / bb;
                        let seg_end = ((b + 1) * bb).min(off + n as u64);
                        let seg = (seg_end - cur) as usize;
                        if sblocks.binary_search(&b).is_ok() {
                            let data = self.cache.store().read_at(&spath, cur, seg)?;
                            buf[done..done + data.len()].copy_from_slice(&data);
                        } else if cur < base_size {
                            let blen = seg.min((base_size - cur) as usize);
                            self.fault_range(&path, cur, blen as u64)?;
                            let data = self.cache.store().read_at(&path, cur, blen)?;
                            buf[done..done + data.len()].copy_from_slice(&data);
                        }
                        done += seg;
                    }
                    n
                }
            }
        };
        self.cache_disk.io(self.clock.as_ref(), n as u64);
        Ok(n)
    }

    fn pwrite(&mut self, fd: Fd, buf: &[u8], off: u64) -> Result<usize, FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        if !f.flags.is_write() {
            return Err(FsError::Perm("fd not open for writing".into()));
        }
        let Some(sh) = f.shadow.as_ref() else { return Err(FsError::BadHandle) };
        if buf.is_empty() {
            return Ok(0);
        }
        let path = f.path.clone();
        let localized = f.localized;
        let spath = sh.path.clone();
        let base_size = sh.base_size;
        let bb = self.cfg.stripe.min_block.max(1);
        let first = off / bb;
        let write_end = off + buf.len() as u64;
        let last = write_end.div_ceil(bb);
        // a block the write only PARTIALLY covers must merge the base
        // content in before the write lands (the dirtied block ships
        // whole at close); fully-covered blocks fetch nothing
        let mut need_base: Vec<(u64, u64)> = Vec::new();
        for b in [first, last - 1] {
            if sh.blocks.contains(&b) {
                continue;
            }
            let bstart = b * bb;
            if bstart >= base_size {
                continue;
            }
            let base_end = (bstart + bb).min(base_size);
            if !(off <= bstart && write_end >= base_end) {
                need_base.push((bstart, base_end - bstart));
            }
        }
        need_base.dedup();
        let now = self.clock.now();
        for (bstart, blen) in need_base {
            if !localized {
                self.fault_range(&path, bstart, blen)?;
            }
            let data = self.cache.store().read_at(&path, bstart, blen as usize)?.to_vec();
            self.cache.store_mut().write_at(&spath, bstart, &data, now)?;
        }
        self.cache.store_mut().write_at(&spath, off, buf, now)?;
        self.cache_disk.io(self.clock.as_ref(), buf.len() as u64);
        let f = self.fds.get_mut(&fd.0).ok_or(FsError::BadHandle)?;
        let sh = f.shadow.as_mut().expect("write fd keeps its shadow");
        for b in first..last {
            sh.blocks.insert(b);
        }
        sh.size = sh.size.max(write_end);
        f.wrote = true;
        Ok(buf.len())
    }

    fn seek(&mut self, fd: Fd, pos: u64) -> Result<(), FsError> {
        let f = self.fds.get_mut(&fd.0).ok_or(FsError::BadHandle)?;
        f.pos = pos;
        Ok(())
    }

    fn tell(&self, fd: Fd) -> Result<u64, FsError> {
        self.fds.get(&fd.0).map(|f| f.pos).ok_or(FsError::BadHandle)
    }

    fn close(&mut self, fd: Fd) -> Result<(), FsError> {
        let t0 = self.clock.now();
        let f = self.fds.remove(&fd.0).ok_or(FsError::BadHandle)?;
        // release any lock held through this fd
        if let Some(token) = self.fd_locks.remove(&fd.0) {
            let _ = self.link.rpc(Request::LockRelease { token, owner: self.link.client_id() });
            self.lease.released(token);
        }
        self.local_locks.retain(|_, (lfd, _)| *lfd != fd.0);

        if let Some(sh) = f.shadow {
            if f.wrote {
                self.merge_shadow(&f.path, &sh, f.localized)?;
            }
            let now = self.clock.now();
            let _ = self.cache.store_mut().unlink(&sh.path, now);
        }
        self.metrics.observe(names::OP_LATENCY, self.clock.now().saturating_sub(t0).as_secs_f64());
        Ok(())
    }

    fn stat(&mut self, path: &str) -> Result<WireAttr, FsError> {
        self.tick();
        let p = self.abs(path);
        // paper: stat() is served from the hidden attribute files
        match self.stat_local(&p) {
            Some(MetaResult::Attr(a)) => return Ok(a),
            Some(MetaResult::Err(e)) => return Err(e),
            Some(MetaResult::Done) | None => {}
        }
        match self.link.rpc(Request::Stat { path: p.clone() })? {
            Response::Attr { attr } => {
                // refresh the cached attributes
                if let Some(e) = self.cache.entry_mut(&p) {
                    e.attr = attr.clone();
                }
                Ok(attr)
            }
            Response::Err { code: 2, msg } => Err(FsError::NotFound(msg)),
            r => Err(FsError::Protocol(format!("unexpected stat response {r:?}"))),
        }
    }

    fn readdir(&mut self, path: &str) -> Result<Vec<(String, WireAttr)>, FsError> {
        self.tick();
        let p = self.abs(path);
        if self.cache.is_localized(&p) {
            self.cache_disk.op(self.clock.as_ref());
            return self.cache.readdir(&p);
        }
        self.ensure_dir(&p)?;
        self.cache_disk.op(self.clock.as_ref());
        self.cache.readdir(&p)
    }

    fn chdir(&mut self, path: &str) -> Result<(), FsError> {
        self.tick();
        let p = self.abs(path);
        if !self.cache.is_localized(&p) {
            self.ensure_dir(&p)?;
            // paper §3.3: pre-fetch small files on first chdir
            self.prefetch_dir(&p)?;
        } else {
            let now = self.clock.now();
            self.cache.store_mut().mkdir_p(&p, now)?;
        }
        self.cwd = p;
        Ok(())
    }

    fn mkdir(&mut self, path: &str) -> Result<(), FsError> {
        self.tick();
        let p = self.abs(path);
        let now = self.clock.now();
        self.cache.store_mut().mkdir_p(&p, now)?;
        self.cache_disk.op(self.clock.as_ref());
        if !self.cache.is_localized(&p) {
            self.enqueue(MetaOp::Mkdir { path: p })?;
        }
        Ok(())
    }

    fn unlink(&mut self, path: &str) -> Result<(), FsError> {
        self.tick();
        let p = self.abs(path);
        let now = self.clock.now();
        self.cache.remove(&p, now);
        self.cache_disk.op(self.clock.as_ref());
        if !self.cache.is_localized(&p) {
            self.enqueue(MetaOp::Unlink { path: p })?;
        }
        Ok(())
    }

    fn rename(&mut self, from: &str, to: &str) -> Result<(), FsError> {
        self.tick();
        let f = self.abs(from);
        let t = self.abs(to);
        let now = self.clock.now();
        // move the cached copy (content + index) locally
        if self.cache.store().exists(&f) {
            let _ = self.cache.store_mut().rename(&f, &t, now);
        }
        let entry = self.cache.entry(&f).cloned();
        self.cache.remove(&f, now);
        if let Some(e) = entry {
            if e.state == EntryState::Clean || e.state == EntryState::Dirty {
                // keep content state — including the residency map —
                // under the new name (re-installing would mistake
                // zero-filled non-resident holes for cached content)
                self.cache.adopt(&t, e, now)?;
            }
        }
        self.cache_disk.op(self.clock.as_ref());
        match (self.cache.is_localized(&f), self.cache.is_localized(&t)) {
            (false, false) => {
                // a queued write targeting the OLD name can lose its
                // dirty bytes across the rename: a stale delta's
                // demotion ghosts (nothing lives under `f` any more),
                // and a spilled by-reference WriteFull record can no
                // longer be rebuilt from the moved cache copy after a
                // crash. Inline full writes are self-contained and
                // replay fine before the rename — no re-queue needed.
                let needs_requeue = self.queue.pending().iter().any(|(_, op)| match op {
                    MetaOp::WriteDelta { path, .. } => *path == f,
                    MetaOp::WriteFull { path, data, .. } => {
                        *path == f && data.len() >= SPILL_THRESHOLD
                    }
                    _ => false,
                });
                self.enqueue(MetaOp::Rename { from: f, to: t.clone() })?;
                if needs_requeue {
                    if let Some(e) = self.cache.entry(&t).cloned() {
                        if e.state == EntryState::Dirty {
                            self.requeue_dirty_under_new_name(&t, &e)?;
                        }
                    }
                }
            }
            (true, true) => {}
            // crossing the localized boundary: materialize as unlink+write
            (false, true) => self.enqueue(MetaOp::Unlink { path: f })?,
            (true, false) => {
                let data = self.cache.store().read(&t).map(|d| d.to_vec()).unwrap_or_default();
                let digests = self.engine.digests(&data, self.cfg.stripe.min_block as usize);
                self.cache.mark_dirty(&t, digests.clone(), now)?;
                self.enqueue(MetaOp::WriteFull { path: t, data, digests, base_version: 0 })?;
            }
        }
        Ok(())
    }

    fn truncate(&mut self, path: &str, size: u64) -> Result<(), FsError> {
        self.tick();
        let p = self.abs(path);
        let now = self.clock.now();
        if !self.cache.is_localized(&p) && size > 0 {
            // the surviving prefix becomes locally-authoritative dirty
            // content: it must be resident before it is re-digested
            if !self.content_usable(&p) {
                self.ensure_entry(&p)?;
            }
            self.fault_range(&p, 0, size)?;
        }
        if !self.cache.store().exists(&p) {
            self.cache.store_mut().mkdir_p(&vpath::parent(&p), now)?;
            self.cache.store_mut().create(&p, now)?;
        }
        self.cache.store_mut().truncate(&p, size, now)?;
        self.cache_disk.op(self.clock.as_ref());
        if !self.cache.is_localized(&p) {
            let data = self.cache.store().read(&p)?.to_vec();
            let digests = self.engine.digests(&data, self.cfg.stripe.min_block as usize);
            self.cache.mark_dirty(&p, digests, now)?;
            self.enqueue(MetaOp::Truncate { path: p, size })?;
        }
        Ok(())
    }

    fn lock(&mut self, fd: Fd, kind: LockKind) -> Result<(), FsError> {
        self.tick();
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        let path = f.path.clone();
        if f.localized {
            // paper: localized directories use the cache-space FS locks
            let conflicting = self.local_locks.get(&path).map(|(ofd, okind)| {
                *ofd != fd.0 && !(matches!(okind, LockKind::Shared) && matches!(kind, LockKind::Shared))
            });
            if conflicting == Some(true) {
                return Err(FsError::LockConflict(path));
            }
            self.local_locks.insert(path, (fd.0, kind));
            return Ok(());
        }
        match self.link.rpc(Request::LockAcquire { path: path.clone(), kind, owner: self.link.client_id() })? {
            Response::LockGranted { token, lease_ns } => {
                let now = self.clock.now();
                self.lease.granted(token, &path, kind, now.add_secs(lease_ns as f64 / 1e9));
                self.fd_locks.insert(fd.0, token);
                Ok(())
            }
            Response::LockDenied { holder } => {
                Err(FsError::LockConflict(format!("{path} held by client {holder}")))
            }
            r => Err(FsError::Protocol(format!("unexpected lock response {r:?}"))),
        }
    }

    fn unlock(&mut self, fd: Fd) -> Result<(), FsError> {
        if let Some(token) = self.fd_locks.remove(&fd.0) {
            let _ = self.link.rpc(Request::LockRelease { token, owner: self.link.client_id() })?;
            self.lease.released(token);
        }
        self.local_locks.retain(|_, (lfd, _)| *lfd != fd.0);
        Ok(())
    }

    /// Compound-capable batch with sequential-lowering semantics:
    /// mutations update the cache immediately and queue their meta-ops;
    /// each run of consecutive cache-miss stats is resolved with ONE
    /// `Request::Compound`, after flushing exactly the mutations that
    /// preceded it (sync-on-close mode) — so a stat observes earlier
    /// mutations in the batch and never later ones, just like calling
    /// the single-op methods in order, but in O(runs) round trips
    /// instead of O(ops).
    fn batch(&mut self, ops: &[MetaBatchOp]) -> Result<Vec<MetaResult>, FsError> {
        self.tick();
        // suppress per-op flushing while the batch accumulates
        let saved_mode = self.writeback;
        let saved_threshold = self.async_flush_threshold;
        self.writeback = WritebackMode::Async;
        self.async_flush_threshold = usize::MAX;

        let mut out: Vec<MetaResult> = Vec::with_capacity(ops.len());
        // (result index, absolute path) of the current run of stats the
        // cache cannot answer
        let mut pending_stats: Vec<(usize, String)> = Vec::new();
        let mut result: Result<(), FsError> = Ok(());
        for (i, op) in ops.iter().enumerate() {
            if !matches!(op, MetaBatchOp::Stat { .. }) && !pending_stats.is_empty() {
                // the buffered stats precede this mutation and must not
                // observe it: resolve them now
                if let Err(e) = self.resolve_batch_stats(saved_mode, &mut pending_stats, &mut out) {
                    result = Err(e);
                    break;
                }
            }
            let r = match op {
                MetaBatchOp::Mkdir { path } => self.mkdir(path).into(),
                MetaBatchOp::Unlink { path } => self.unlink(path).into(),
                MetaBatchOp::Rename { from, to } => self.rename(from, to).into(),
                MetaBatchOp::Truncate { path, size } => self.truncate(path, *size).into(),
                MetaBatchOp::Stat { path } => {
                    let p = self.abs(path);
                    match self.stat_local(&p) {
                        Some(r) => r,
                        None => {
                            pending_stats.push((i, p));
                            MetaResult::Done // placeholder, filled on resolve
                        }
                    }
                }
            };
            out.push(r);
        }
        if result.is_ok() {
            result = self.resolve_batch_stats(saved_mode, &mut pending_stats, &mut out);
        }
        self.writeback = saved_mode;
        self.async_flush_threshold = saved_threshold;
        result?;

        // mutations after the last stat still ship (one compound)
        match saved_mode {
            WritebackMode::SyncOnClose => {
                let _ = self.flush_queue()?;
            }
            WritebackMode::Async => {
                if self.queue.len() >= saved_threshold {
                    let _ = self.flush_queue()?;
                }
            }
        }
        Ok(out)
    }

    fn fsync(&mut self) -> Result<(), FsError> {
        self.tick();
        self.flush_queue()?;
        Ok(())
    }

    fn now(&self) -> VirtualTime {
        self.clock.now()
    }

    fn think(&mut self, secs: f64) {
        self.clock.advance_secs(secs);
    }
}
