//! The virtual file-system interface.
//!
//! In the paper this surface is `libxufs.so`: interposed libc calls
//! (`open`, `read`, `write`, `close`, `stat`, `opendir`, …) redirected to
//! cache-space copies. Applications in this reproduction (workloads,
//! examples, baselines) are written against this trait instead — the
//! paper's contribution is what happens *behind* the interposition, and
//! each interposed call maps 1:1 onto a method here (DESIGN.md §2).

use crate::homefs::FsError;
use crate::proto::{LockKind, WireAttr};
use crate::simnet::VirtualTime;

/// File descriptor handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fd(pub u64);

/// Open flags (the subset the workloads exercise).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct OpenFlags {
    pub read: bool,
    pub write: bool,
    pub create: bool,
    pub truncate: bool,
    pub append: bool,
}

impl OpenFlags {
    /// `O_RDONLY`
    pub fn rdonly() -> Self {
        OpenFlags { read: true, ..Default::default() }
    }

    /// `O_WRONLY | O_CREAT | O_TRUNC`
    pub fn wronly_create() -> Self {
        OpenFlags { write: true, create: true, truncate: true, ..Default::default() }
    }

    /// `O_RDWR`
    pub fn rdwr() -> Self {
        OpenFlags { read: true, write: true, ..Default::default() }
    }

    /// `O_WRONLY | O_APPEND`
    pub fn append() -> Self {
        OpenFlags { write: true, append: true, ..Default::default() }
    }
}

/// The interposed file-system interface.
pub trait Vfs {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd, FsError>;
    /// Sequential read at the fd's position; returns <= `len` bytes
    /// (empty at EOF).
    fn read(&mut self, fd: Fd, len: usize) -> Result<Vec<u8>, FsError>;
    /// Sequential write at the fd's position.
    fn write(&mut self, fd: Fd, data: &[u8]) -> Result<usize, FsError>;
    fn seek(&mut self, fd: Fd, pos: u64) -> Result<(), FsError>;
    fn close(&mut self, fd: Fd) -> Result<(), FsError>;

    fn stat(&mut self, path: &str) -> Result<WireAttr, FsError>;
    fn readdir(&mut self, path: &str) -> Result<Vec<(String, WireAttr)>, FsError>;
    fn chdir(&mut self, path: &str) -> Result<(), FsError>;
    fn mkdir(&mut self, path: &str) -> Result<(), FsError>;
    fn unlink(&mut self, path: &str) -> Result<(), FsError>;
    fn rename(&mut self, from: &str, to: &str) -> Result<(), FsError>;
    fn truncate(&mut self, path: &str, size: u64) -> Result<(), FsError>;

    fn lock(&mut self, fd: Fd, kind: LockKind) -> Result<(), FsError>;
    fn unlock(&mut self, fd: Fd) -> Result<(), FsError>;

    /// Force pending write-backs to the authoritative store.
    fn fsync(&mut self) -> Result<(), FsError>;

    /// Current (virtual) time — workloads measure durations with this.
    fn now(&self) -> VirtualTime;

    /// Application CPU time passing on the same clock (e.g. compile time
    /// in the build workload). Simulated clocks jump; real clocks sleep.
    fn think(&mut self, _secs: f64) {}

    /// Convenience: read a whole file sequentially in `chunk`-byte reads
    /// (the `wc -l` access pattern of §4.3). Returns total bytes read.
    fn scan_file(&mut self, path: &str, chunk: usize) -> Result<u64, FsError> {
        let fd = self.open(path, OpenFlags::rdonly())?;
        let mut total = 0u64;
        loop {
            let buf = self.read(fd, chunk)?;
            if buf.is_empty() {
                break;
            }
            total += buf.len() as u64;
        }
        self.close(fd)?;
        Ok(total)
    }

    /// Convenience: create/replace a file with `data` (open-write-close,
    /// the IOzone write pattern — close cost included).
    fn write_file(&mut self, path: &str, data: &[u8], chunk: usize) -> Result<(), FsError> {
        let fd = self.open(path, OpenFlags::wronly_create())?;
        for c in data.chunks(chunk.max(1)) {
            self.write(fd, c)?;
        }
        self.close(fd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_constructors() {
        assert!(OpenFlags::rdonly().read && !OpenFlags::rdonly().write);
        let w = OpenFlags::wronly_create();
        assert!(w.write && w.create && w.truncate && !w.read);
        assert!(OpenFlags::rdwr().read && OpenFlags::rdwr().write);
        assert!(OpenFlags::append().append);
    }
}
