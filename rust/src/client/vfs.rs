//! The virtual file-system interface (v2).
//!
//! In the paper this surface is `libxufs.so`: interposed libc calls
//! (`open`, `pread`, `pwrite`, `close`, `stat`, `opendir`, …) redirected
//! to cache-space copies. Applications in this reproduction (workloads,
//! examples, baselines) are written against this trait instead — the
//! paper's contribution is what happens *behind* the interposition, and
//! each interposed call maps 1:1 onto a method here (DESIGN.md §2).
//!
//! v2 surface (DESIGN.md §2.1):
//! * the data-path primitives are **buffer-based positional I/O** —
//!   [`Vfs::pread`]/[`Vfs::pwrite`] fill/drain caller-owned `&[u8]`
//!   buffers at explicit offsets, so the hot path never allocates a
//!   `Vec` per call and striped/zero-copy transfers stay local changes;
//! * sequential [`Vfs::read`]/[`Vfs::write`] are **default methods** over
//!   the per-fd cursor ([`Vfs::tell`]/[`Vfs::seek`]);
//! * [`OpenFlags`] is a validated bitflags type — nonsensical
//!   combinations (write-intent flags on a read-only open) are rejected
//!   at `open`, not deep inside a client;
//! * [`Vfs::batch`] submits a group of metadata operations with per-op
//!   results; compound-capable clients (XUFS) ship them in one WAN round
//!   trip (`Request::Compound`, DESIGN.md §2.3).

use crate::homefs::FsError;
use crate::proto::{LockKind, WireAttr};
use crate::simnet::VirtualTime;

/// File descriptor handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fd(pub u64);

/// Validated open flags: a bitflags set over the subset the workloads
/// exercise. Construct via the `O_*`-shaped constants and `|`, or the
/// libc-combination constructors; [`OpenFlags::validate`] (called by
/// every `open`) rejects nonsense combinations up front.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct OpenFlags(u8);

impl OpenFlags {
    /// Open for reading.
    pub const READ: OpenFlags = OpenFlags(1 << 0);
    /// Open for writing.
    pub const WRITE: OpenFlags = OpenFlags(1 << 1);
    /// Create if absent (`O_CREAT`).
    pub const CREATE: OpenFlags = OpenFlags(1 << 2);
    /// Truncate to zero on open (`O_TRUNC`).
    pub const TRUNCATE: OpenFlags = OpenFlags(1 << 3);
    /// Cursor starts at EOF (`O_APPEND`).
    pub const APPEND: OpenFlags = OpenFlags(1 << 4);

    /// The empty set (invalid to open with; useful as a fold seed).
    pub fn empty() -> Self {
        OpenFlags(0)
    }

    /// `O_RDONLY`
    pub fn rdonly() -> Self {
        Self::READ
    }

    /// `O_WRONLY | O_CREAT | O_TRUNC`
    pub fn wronly_create() -> Self {
        Self::WRITE | Self::CREATE | Self::TRUNCATE
    }

    /// `O_RDWR`
    pub fn rdwr() -> Self {
        Self::READ | Self::WRITE
    }

    /// `O_WRONLY | O_APPEND`
    pub fn append() -> Self {
        Self::WRITE | Self::APPEND
    }

    pub fn contains(self, other: OpenFlags) -> bool {
        self.0 & other.0 == other.0
    }

    pub fn is_read(self) -> bool {
        self.contains(Self::READ)
    }

    pub fn is_write(self) -> bool {
        self.contains(Self::WRITE)
    }

    pub fn is_create(self) -> bool {
        self.contains(Self::CREATE)
    }

    pub fn is_truncate(self) -> bool {
        self.contains(Self::TRUNCATE)
    }

    pub fn is_append(self) -> bool {
        self.contains(Self::APPEND)
    }

    /// Reject invalid combinations at `open` time (the v2 contract: no
    /// implementor discovers bad flags deep inside its data path):
    /// * at least one of READ/WRITE must be set;
    /// * CREATE/TRUNCATE/APPEND are write intents — they require WRITE;
    /// * TRUNCATE and APPEND are mutually exclusive.
    pub fn validate(self) -> Result<OpenFlags, FsError> {
        if !self.is_read() && !self.is_write() {
            return Err(FsError::Invalid("open flags select neither read nor write".into()));
        }
        if (self.is_create() || self.is_truncate() || self.is_append()) && !self.is_write() {
            return Err(FsError::Invalid(
                "O_CREAT/O_TRUNC/O_APPEND require write access".into(),
            ));
        }
        if self.is_truncate() && self.is_append() {
            return Err(FsError::Invalid("O_TRUNC and O_APPEND are mutually exclusive".into()));
        }
        Ok(self)
    }
}

impl std::ops::BitOr for OpenFlags {
    type Output = OpenFlags;
    fn bitor(self, rhs: OpenFlags) -> OpenFlags {
        OpenFlags(self.0 | rhs.0)
    }
}

impl std::ops::BitOrAssign for OpenFlags {
    fn bitor_assign(&mut self, rhs: OpenFlags) {
        self.0 |= rhs.0;
    }
}

/// One metadata operation submitted through [`Vfs::batch`].
#[derive(Debug, Clone, PartialEq)]
pub enum MetaBatchOp {
    Mkdir { path: String },
    Unlink { path: String },
    Rename { from: String, to: String },
    Truncate { path: String, size: u64 },
    Stat { path: String },
}

/// Per-op outcome of a [`Vfs::batch`] call. A batch call only fails as a
/// whole on transport-level errors; semantic failures land here so the
/// caller can replay exactly the ops that failed.
#[derive(Debug, Clone, PartialEq)]
pub enum MetaResult {
    /// Mutation applied (or queued for write-back).
    Done,
    /// Stat result.
    Attr(WireAttr),
    /// This op failed; the rest of the batch still ran.
    Err(FsError),
}

impl MetaResult {
    pub fn is_err(&self) -> bool {
        matches!(self, MetaResult::Err(_))
    }

    pub fn attr(&self) -> Option<&WireAttr> {
        match self {
            MetaResult::Attr(a) => Some(a),
            _ => None,
        }
    }
}

impl From<Result<(), FsError>> for MetaResult {
    fn from(r: Result<(), FsError>) -> MetaResult {
        match r {
            Ok(()) => MetaResult::Done,
            Err(e) => MetaResult::Err(e),
        }
    }
}

/// The interposed file-system interface (v2).
///
/// Implementors provide the positional primitives and the per-fd cursor;
/// sequential I/O, whole-file conveniences and (for non-compound systems)
/// metadata batching are default methods on top.
pub trait Vfs {
    // ------------------------------------------------------------------
    // required primitives
    // ------------------------------------------------------------------

    /// Open `path`. Implementations must call [`OpenFlags::validate`]
    /// before any other work.
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd, FsError>;

    /// Positional read at `off` into `buf`; returns bytes filled
    /// (0 at/after EOF, short counts near EOF). Does not move the cursor.
    fn pread(&mut self, fd: Fd, buf: &mut [u8], off: u64) -> Result<usize, FsError>;

    /// Positional write of `buf` at `off`; returns bytes written (always
    /// `buf.len()` on success — holes zero-fill). Does not move the
    /// cursor.
    fn pwrite(&mut self, fd: Fd, buf: &[u8], off: u64) -> Result<usize, FsError>;

    /// Set the fd's sequential cursor.
    fn seek(&mut self, fd: Fd, pos: u64) -> Result<(), FsError>;

    /// Current sequential cursor.
    fn tell(&self, fd: Fd) -> Result<u64, FsError>;

    fn close(&mut self, fd: Fd) -> Result<(), FsError>;

    fn stat(&mut self, path: &str) -> Result<WireAttr, FsError>;
    fn readdir(&mut self, path: &str) -> Result<Vec<(String, WireAttr)>, FsError>;
    fn chdir(&mut self, path: &str) -> Result<(), FsError>;
    fn mkdir(&mut self, path: &str) -> Result<(), FsError>;
    fn unlink(&mut self, path: &str) -> Result<(), FsError>;
    fn rename(&mut self, from: &str, to: &str) -> Result<(), FsError>;
    fn truncate(&mut self, path: &str, size: u64) -> Result<(), FsError>;

    fn lock(&mut self, fd: Fd, kind: LockKind) -> Result<(), FsError>;
    fn unlock(&mut self, fd: Fd) -> Result<(), FsError>;

    /// Force pending write-backs to the authoritative store.
    fn fsync(&mut self) -> Result<(), FsError>;

    /// Current (virtual) time — workloads measure durations with this.
    fn now(&self) -> VirtualTime;

    /// Application CPU time passing on the same clock (e.g. compile time
    /// in the build workload). Simulated clocks jump; real clocks sleep.
    fn think(&mut self, _secs: f64) {}

    // ------------------------------------------------------------------
    // sequential I/O: defaults over the per-fd cursor
    // ------------------------------------------------------------------

    /// Sequential read at the fd's cursor into `buf`; advances the cursor
    /// by the bytes read. Returns 0 at EOF.
    fn read(&mut self, fd: Fd, buf: &mut [u8]) -> Result<usize, FsError> {
        let pos = self.tell(fd)?;
        let n = self.pread(fd, buf, pos)?;
        self.seek(fd, pos + n as u64)?;
        Ok(n)
    }

    /// Sequential write at the fd's cursor; advances the cursor by the
    /// bytes written.
    fn write(&mut self, fd: Fd, data: &[u8]) -> Result<usize, FsError> {
        let pos = self.tell(fd)?;
        let n = self.pwrite(fd, data, pos)?;
        self.seek(fd, pos + n as u64)?;
        Ok(n)
    }

    // ------------------------------------------------------------------
    // batched metadata
    // ------------------------------------------------------------------

    /// Run a group of metadata ops, returning one [`MetaResult`] per op
    /// in order. The default lowers each op onto the single-op methods
    /// (one round trip each on remote systems); compound-capable clients
    /// override this to ship the group in one `Request::Compound` WAN
    /// round trip.
    fn batch(&mut self, ops: &[MetaBatchOp]) -> Result<Vec<MetaResult>, FsError> {
        let mut out = Vec::with_capacity(ops.len());
        for op in ops {
            let r = match op {
                MetaBatchOp::Mkdir { path } => self.mkdir(path).into(),
                MetaBatchOp::Unlink { path } => self.unlink(path).into(),
                MetaBatchOp::Rename { from, to } => self.rename(from, to).into(),
                MetaBatchOp::Truncate { path, size } => self.truncate(path, *size).into(),
                MetaBatchOp::Stat { path } => match self.stat(path) {
                    Ok(a) => MetaResult::Attr(a),
                    Err(e) => MetaResult::Err(e),
                },
            };
            out.push(r);
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // whole-file conveniences
    // ------------------------------------------------------------------

    /// Convenience: read a whole file sequentially in `chunk`-byte reads
    /// (the `wc -l` access pattern of §4.3). Returns total bytes read.
    /// The fd is closed on every path, including read errors.
    fn scan_file(&mut self, path: &str, chunk: usize) -> Result<u64, FsError> {
        let fd = self.open(path, OpenFlags::rdonly())?;
        let mut buf = vec![0u8; chunk.max(1)];
        let mut total = 0u64;
        loop {
            match self.read(fd, &mut buf) {
                Ok(0) => break,
                Ok(n) => total += n as u64,
                Err(e) => {
                    let _ = self.close(fd);
                    return Err(e);
                }
            }
        }
        self.close(fd)?;
        Ok(total)
    }

    /// Convenience: create/replace a file with `data` (open-write-close,
    /// the IOzone write pattern — close cost included). The fd is closed
    /// on every path, including write errors.
    fn write_file(&mut self, path: &str, data: &[u8], chunk: usize) -> Result<(), FsError> {
        let fd = self.open(path, OpenFlags::wronly_create())?;
        for c in data.chunks(chunk.max(1)) {
            if let Err(e) = self.write(fd, c) {
                let _ = self.close(fd);
                return Err(e);
            }
        }
        self.close(fd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_constructors() {
        assert!(OpenFlags::rdonly().is_read() && !OpenFlags::rdonly().is_write());
        let w = OpenFlags::wronly_create();
        assert!(w.is_write() && w.is_create() && w.is_truncate() && !w.is_read());
        assert!(OpenFlags::rdwr().is_read() && OpenFlags::rdwr().is_write());
        assert!(OpenFlags::append().is_append() && OpenFlags::append().is_write());
    }

    #[test]
    fn valid_combinations_accepted() {
        for f in [
            OpenFlags::rdonly(),
            OpenFlags::wronly_create(),
            OpenFlags::rdwr(),
            OpenFlags::append(),
            OpenFlags::rdwr() | OpenFlags::CREATE,
        ] {
            assert_eq!(f.validate(), Ok(f));
        }
    }

    #[test]
    fn invalid_combinations_rejected() {
        for f in [
            OpenFlags::empty(),
            OpenFlags::CREATE,
            OpenFlags::READ | OpenFlags::TRUNCATE,
            OpenFlags::READ | OpenFlags::CREATE,
            OpenFlags::READ | OpenFlags::APPEND,
            OpenFlags::WRITE | OpenFlags::TRUNCATE | OpenFlags::APPEND,
        ] {
            assert!(
                matches!(f.validate(), Err(FsError::Invalid(_))),
                "{f:?} should be rejected"
            );
        }
    }

    #[test]
    fn bitor_accumulates() {
        let mut f = OpenFlags::empty();
        f |= OpenFlags::READ;
        f |= OpenFlags::WRITE;
        assert!(f.contains(OpenFlags::READ | OpenFlags::WRITE));
        assert!(!f.contains(OpenFlags::APPEND));
    }

    #[test]
    fn meta_result_from_result() {
        assert_eq!(MetaResult::from(Ok(())), MetaResult::Done);
        let e: MetaResult = Err::<(), _>(FsError::BadHandle).into();
        assert!(e.is_err());
        assert!(MetaResult::Attr(WireAttr {
            kind: crate::homefs::NodeKind::File,
            size: 1,
            mtime_ns: 0,
            mode: 0o600,
            version: 1,
        })
        .attr()
        .is_some());
    }
}
