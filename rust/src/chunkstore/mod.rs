//! Content-addressed chunk store (DESIGN.md §2.8).
//!
//! The meta/data split the ROADMAP calls for: file *content* lives here
//! as immutable chunks keyed by an in-tree HMAC-SHA256 digest, while the
//! namespace ([`crate::homefs::FileStore`]) keeps only per-inode ordered
//! chunk lists. Three payoffs fall out of the split:
//!
//! * **Cross-user dedup** — identical chunks (shared toolchains, copied
//!   datasets) are stored once; `put` of a known digest bumps a refcount
//!   instead of storing bytes (`chunkstore.dedup_hits` /
//!   `chunkstore.dedup_bytes_saved`).
//! * **O(1)-data CoW snapshots** — a snapshot pins every live chunk with
//!   one refcount increment each and clones only the inode table; no
//!   content is copied, and `rename` was already pure metadata.
//! * **Replication by reference** — the applied-op log can spill write
//!   payloads as digest lists ([`crate::proto::MetaOp::WriteRef`]); the
//!   secondary fetches only chunks it is missing.
//!
//! GC is deferred and refcount-driven: `decref` to zero moves a chunk to
//! the dead set (bytes retained), and a later [`ChunkStore::gc`] sweep
//! frees it — a `put`/`incref` in between resurrects it for free. Every
//! holder of a chunk reference (file node, snapshot manifest, un-shipped
//! replication record, staged replica push) owns exactly one refcount,
//! so "GC never collects a referenced chunk" is an arithmetic property,
//! not a scan.

use std::collections::{HashMap, HashSet};

use crate::metrics::{names, Metrics};
use crate::util::hmacsha;

/// Content digest of one chunk: HMAC-SHA256 under a versioned key, so a
/// digest collision attack needs the key AND chunk digests can never be
/// confused with the op-log or replication MACs.
pub type Digest = [u8; 32];

/// Domain-separation key for chunk digests.
const CHUNK_HMAC_KEY: &[u8] = b"xufs-chunk-v1";

/// Digest of one chunk's bytes.
pub fn chunk_digest(data: &[u8]) -> Digest {
    hmacsha::hmac_sha256(CHUNK_HMAC_KEY, &[data])
}

/// Render a digest as short hex (logs / error messages).
pub fn digest_hex(d: &Digest) -> String {
    d.iter().take(8).map(|b| format!("{b:02x}")).collect()
}

#[derive(Debug, Clone)]
struct Chunk {
    bytes: Vec<u8>,
    refs: u64,
}

/// The immutable, refcounted chunk store. Cloning deep-copies (a cloned
/// `FileStore` — e.g. the warm secondary seeded from the primary's image
/// — must own an independent chunk map so "secondary missing chunks"
/// is a real state, exactly as on separate hosts).
#[derive(Debug, Clone, Default)]
pub struct ChunkStore {
    chunks: HashMap<Digest, Chunk>,
    /// Digests whose refcount hit zero: bytes retained until [`Self::gc`]
    /// sweeps them, so an interleaved `put`/`incref` resurrects for free.
    dead: HashSet<Digest>,
    /// Physical bytes currently held (including dead, until swept).
    stored: u64,
    dedup_hits: u64,
    dedup_saved: u64,
    gc_chunks: u64,
    gc_bytes: u64,
    metrics: Metrics,
}

impl ChunkStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Point dedup/GC counters at a shared sink (they also stay readable
    /// through the accessors below).
    pub fn attach_metrics(&mut self, metrics: &Metrics) {
        self.metrics = metrics.clone();
    }

    /// Insert a chunk (or take a reference on an existing identical one).
    /// Returns its digest; the caller owns one reference.
    pub fn put(&mut self, data: &[u8]) -> Digest {
        let d = chunk_digest(data);
        match self.chunks.get_mut(&d) {
            Some(c) => {
                c.refs += 1;
                self.dead.remove(&d);
                self.dedup_hits += 1;
                self.dedup_saved += data.len() as u64;
                self.metrics.incr(names::CHUNK_DEDUP_HITS);
                self.metrics.add(names::CHUNK_DEDUP_BYTES_SAVED, data.len() as u64);
            }
            None => {
                self.stored += data.len() as u64;
                self.chunks.insert(d, Chunk { bytes: data.to_vec(), refs: 1 });
            }
        }
        d
    }

    /// Chunk bytes, if resident (dead-but-unswept chunks still resolve —
    /// a reader holding a stale manifest never sees a torn read).
    pub fn get(&self, d: &Digest) -> Option<&[u8]> {
        self.chunks.get(d).map(|c| c.bytes.as_slice())
    }

    pub fn contains(&self, d: &Digest) -> bool {
        self.chunks.contains_key(d)
    }

    /// Take an extra reference on an existing chunk. Returns `false` if
    /// the digest is unknown (caller decides whether that is fatal).
    pub fn incref(&mut self, d: &Digest) -> bool {
        match self.chunks.get_mut(d) {
            Some(c) => {
                c.refs += 1;
                self.dead.remove(d);
                true
            }
            None => false,
        }
    }

    /// Release one reference. At zero the chunk joins the dead set for a
    /// later [`Self::gc`]; unknown digests are ignored (idempotent
    /// release paths — e.g. a replayed truncation — stay safe).
    pub fn decref(&mut self, d: &Digest) {
        if let Some(c) = self.chunks.get_mut(d) {
            c.refs = c.refs.saturating_sub(1);
            if c.refs == 0 {
                self.dead.insert(*d);
            }
        }
    }

    /// Sweep the dead set: free every chunk whose refcount is still zero.
    /// Returns (chunks, bytes) collected.
    pub fn gc(&mut self) -> (u64, u64) {
        let mut n = 0u64;
        let mut bytes = 0u64;
        for d in std::mem::take(&mut self.dead) {
            match self.chunks.get(&d) {
                Some(c) if c.refs == 0 => {
                    bytes += c.bytes.len() as u64;
                    n += 1;
                    self.chunks.remove(&d);
                }
                _ => {} // resurrected (or already gone): not collectable
            }
        }
        self.stored -= bytes;
        self.gc_chunks += n;
        self.gc_bytes += bytes;
        if n > 0 {
            self.metrics.add(names::CHUNK_GC_COLLECTED, n);
        }
        (n, bytes)
    }

    /// Physical bytes currently held (dedup makes this <= logical bytes).
    pub fn stored_bytes(&self) -> u64 {
        self.stored
    }

    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    pub fn dead_count(&self) -> usize {
        self.dead.len()
    }

    pub fn dedup_hits(&self) -> u64 {
        self.dedup_hits
    }

    pub fn dedup_bytes_saved(&self) -> u64 {
        self.dedup_saved
    }

    pub fn gc_collected(&self) -> (u64, u64) {
        (self.gc_chunks, self.gc_bytes)
    }

    /// Current refcount of a chunk (tests / invariant checks).
    pub fn refs(&self, d: &Digest) -> u64 {
        self.chunks.get(d).map(|c| c.refs).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut cs = ChunkStore::new();
        let d = cs.put(b"hello chunk");
        assert_eq!(d, chunk_digest(b"hello chunk"));
        assert_eq!(cs.get(&d).unwrap(), b"hello chunk");
        assert_eq!(cs.stored_bytes(), 11);
        assert_eq!(cs.refs(&d), 1);
    }

    #[test]
    fn dedup_stores_once_and_counts() {
        let mut cs = ChunkStore::new();
        let a = cs.put(b"same bytes");
        let b = cs.put(b"same bytes");
        assert_eq!(a, b);
        assert_eq!(cs.chunk_count(), 1);
        assert_eq!(cs.refs(&a), 2);
        assert_eq!(cs.stored_bytes(), 10);
        assert_eq!(cs.dedup_hits(), 1);
        assert_eq!(cs.dedup_bytes_saved(), 10);
    }

    #[test]
    fn gc_only_collects_unreferenced() {
        let mut cs = ChunkStore::new();
        let keep = cs.put(b"keep");
        let drop_ = cs.put(b"drop");
        cs.decref(&drop_);
        assert!(cs.contains(&drop_), "dead bytes retained until sweep");
        let (n, bytes) = cs.gc();
        assert_eq!((n, bytes), (1, 4));
        assert!(!cs.contains(&drop_));
        assert!(cs.contains(&keep));
        assert_eq!(cs.stored_bytes(), 4);
    }

    #[test]
    fn dead_chunk_resurrects_on_put_or_incref() {
        let mut cs = ChunkStore::new();
        let d = cs.put(b"lazarus");
        cs.decref(&d);
        assert_eq!(cs.dead_count(), 1);
        // a re-put takes a fresh reference and cancels the death
        let d2 = cs.put(b"lazarus");
        assert_eq!(d, d2);
        assert_eq!(cs.gc(), (0, 0));
        assert!(cs.contains(&d));
        // same through incref
        cs.decref(&d);
        assert!(cs.incref(&d));
        assert_eq!(cs.gc(), (0, 0));
        assert!(cs.contains(&d));
    }

    #[test]
    fn decref_unknown_is_ignored_incref_reports() {
        let mut cs = ChunkStore::new();
        let ghost = chunk_digest(b"never stored");
        cs.decref(&ghost); // no panic
        assert!(!cs.incref(&ghost));
    }

    #[test]
    fn clone_is_deep() {
        let mut a = ChunkStore::new();
        let d = a.put(b"shared?");
        let mut b = a.clone();
        b.decref(&d);
        b.gc();
        assert!(!b.contains(&d));
        assert!(a.contains(&d), "clone must not share chunk state");
    }

    #[test]
    fn digests_are_domain_separated() {
        // a chunk digest is not a bare SHA-256 of the content
        assert_ne!(chunk_digest(b"abc").to_vec(), hmacsha::sha256(b"abc").to_vec());
        assert_eq!(digest_hex(&chunk_digest(b"abc")).len(), 16);
    }
}
