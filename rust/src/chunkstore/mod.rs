//! Content-addressed chunk store (DESIGN.md §2.8).
//!
//! The meta/data split the ROADMAP calls for: file *content* lives here
//! as immutable chunks keyed by an in-tree HMAC-SHA256 digest, while the
//! namespace ([`crate::homefs::FileStore`]) keeps only per-inode ordered
//! chunk lists. Three payoffs fall out of the split:
//!
//! * **Cross-user dedup** — identical chunks (shared toolchains, copied
//!   datasets) are stored once; `put` of a known digest bumps a refcount
//!   instead of storing bytes (`chunkstore.dedup_hits` /
//!   `chunkstore.dedup_bytes_saved`).
//! * **O(1)-data CoW snapshots** — a snapshot pins every live chunk with
//!   one refcount increment each and clones only the inode table; no
//!   content is copied, and `rename` was already pure metadata.
//! * **Replication by reference** — the applied-op log can spill write
//!   payloads as digest lists ([`crate::proto::MetaOp::WriteRef`]); the
//!   secondary fetches only chunks it is missing.
//!
//! GC is deferred and refcount-driven: `decref` to zero moves a chunk to
//! the dead set (bytes retained), and a later [`ChunkStore::gc`] sweep
//! frees it — a `put`/`incref` in between resurrects it for free. Every
//! holder of a chunk reference (file node, snapshot manifest, un-shipped
//! replication record, staged replica push) owns exactly one refcount,
//! so "GC never collects a referenced chunk" is an arithmetic property,
//! not a scan.
//!
//! **Integrity plane (DESIGN.md §2.10).** Stored bytes are NOT trusted:
//! every server-facing read goes through [`ChunkStore::get_verified`],
//! which recomputes the digest and refuses bytes that no longer match it
//! (bit rot, torn sectors) — never wrong data. Detected-corrupt chunks
//! are *quarantined* by the scrub sweep ([`ChunkStore::scrub_slice`],
//! driven on the server's op cadence): the rotted bytes stay resident
//! for forensics but are never served again, until
//! [`ChunkStore::repair`] re-installs a digest-verified replacement
//! fetched from a replica.

use std::collections::{HashMap, HashSet};

use crate::metrics::{names, Metrics};
use crate::util::hmacsha;

/// Content digest of one chunk: HMAC-SHA256 under a versioned key, so a
/// digest collision attack needs the key AND chunk digests can never be
/// confused with the op-log or replication MACs.
pub type Digest = [u8; 32];

/// Domain-separation key for chunk digests.
const CHUNK_HMAC_KEY: &[u8] = b"xufs-chunk-v1";

/// Digest of one chunk's bytes.
pub fn chunk_digest(data: &[u8]) -> Digest {
    hmacsha::hmac_sha256(CHUNK_HMAC_KEY, &[data])
}

/// Render a digest as short hex (logs / error messages).
pub fn digest_hex(d: &Digest) -> String {
    d.iter().take(8).map(|b| format!("{b:02x}")).collect()
}

/// Why a verified chunk read failed (mapped to typed [`crate::homefs::FsError`]s
/// by the namespace layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkGetError {
    /// The digest is not resident at all.
    Missing,
    /// The stored bytes no longer match their digest (or the chunk is
    /// already quarantined): refused, never served.
    Corrupt,
}

#[derive(Debug, Clone)]
struct Chunk {
    bytes: Vec<u8>,
    refs: u64,
}

/// The immutable, refcounted chunk store. Cloning deep-copies (a cloned
/// `FileStore` — e.g. the warm secondary seeded from the primary's image
/// — must own an independent chunk map so "secondary missing chunks"
/// is a real state, exactly as on separate hosts).
#[derive(Debug, Clone, Default)]
pub struct ChunkStore {
    chunks: HashMap<Digest, Chunk>,
    /// Digests whose refcount hit zero: bytes retained until [`Self::gc`]
    /// sweeps them, so an interleaved `put`/`incref` resurrects for free.
    dead: HashSet<Digest>,
    /// Digests detected corrupt (stored bytes no longer match): bytes
    /// retained for forensics, never served, awaiting [`Self::repair`].
    quarantined: HashSet<Digest>,
    /// Physical bytes currently held (including dead, until swept).
    stored: u64,
    dedup_hits: u64,
    dedup_saved: u64,
    gc_chunks: u64,
    gc_bytes: u64,
    scrub_errors: u64,
    repaired: u64,
    metrics: Metrics,
}

impl ChunkStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Point dedup/GC counters at a shared sink (they also stay readable
    /// through the accessors below).
    pub fn attach_metrics(&mut self, metrics: &Metrics) {
        self.metrics = metrics.clone();
    }

    /// Insert a chunk (or take a reference on an existing identical one).
    /// Returns its digest; the caller owns one reference.
    pub fn put(&mut self, data: &[u8]) -> Digest {
        let d = chunk_digest(data);
        match self.chunks.get_mut(&d) {
            Some(c) => {
                c.refs += 1;
                self.dead.remove(&d);
                self.dedup_hits += 1;
                self.dedup_saved += data.len() as u64;
                self.metrics.incr(names::CHUNK_DEDUP_HITS);
                self.metrics.add(names::CHUNK_DEDUP_BYTES_SAVED, data.len() as u64);
            }
            None => {
                self.stored += data.len() as u64;
                self.chunks.insert(d, Chunk { bytes: data.to_vec(), refs: 1 });
            }
        }
        d
    }

    /// UNCHECKED chunk bytes, if resident (dead-but-unswept chunks still
    /// resolve — a reader holding a stale manifest never sees a torn
    /// read). Crate-internal and test-only: every server-facing read
    /// must go through [`Self::get_verified`] instead.
    pub(crate) fn get(&self, d: &Digest) -> Option<&[u8]> {
        self.chunks.get(d).map(|c| c.bytes.as_slice())
    }

    /// VERIFIED chunk bytes: recompute the digest on the way out and
    /// refuse a mismatch (bit rot between the original `put` and now).
    /// Quarantined chunks refuse without rehashing. This is the read the
    /// server, the replica fill path, and the scrubber all use — corrupt
    /// bytes are never served, only detected.
    pub fn get_verified(&self, d: &Digest) -> Result<&[u8], ChunkGetError> {
        if self.quarantined.contains(d) {
            return Err(ChunkGetError::Corrupt);
        }
        match self.chunks.get(d) {
            None => Err(ChunkGetError::Missing),
            Some(c) if chunk_digest(&c.bytes) == *d => Ok(c.bytes.as_slice()),
            Some(_) => Err(ChunkGetError::Corrupt),
        }
    }

    /// Scrub a bounded slice of the chunk table: verify up to `limit`
    /// chunks starting at `cursor` (wrapping; the digests are walked in
    /// sorted order so the sweep is deterministic), quarantining every
    /// mismatch. Returns the next cursor and the digests newly
    /// quarantined this slice. Repeated slices amortize a full-store
    /// scrub across the op cadence (DESIGN.md §2.10).
    pub fn scrub_slice(&mut self, cursor: usize, limit: usize) -> (usize, Vec<Digest>) {
        let mut keys: Vec<Digest> = self.chunks.keys().copied().collect();
        keys.sort_unstable();
        let n = keys.len();
        if n == 0 {
            return (0, Vec::new());
        }
        let start = cursor % n;
        let mut bad = Vec::new();
        for i in 0..limit.min(n) {
            let d = keys[(start + i) % n];
            if self.quarantined.contains(&d) {
                continue;
            }
            if chunk_digest(&self.chunks[&d].bytes) != d {
                self.quarantined.insert(d);
                self.scrub_errors += 1;
                self.metrics.incr(names::CHUNK_SCRUB_ERRORS);
                bad.push(d);
            }
        }
        ((start + limit.min(n)) % n, bad)
    }

    /// Quarantine one digest directly (a read path detected the mismatch
    /// before the scrub cursor reached it). Returns `true` if the chunk
    /// is resident and was not already quarantined.
    pub fn quarantine(&mut self, d: &Digest) -> bool {
        if self.chunks.contains_key(d) && self.quarantined.insert(*d) {
            self.scrub_errors += 1;
            self.metrics.incr(names::CHUNK_SCRUB_ERRORS);
            true
        } else {
            false
        }
    }

    /// Repair a quarantined chunk from replacement bytes (fetched from a
    /// replica): the bytes are digest-verified HERE — a corrupt or
    /// mismatched fill is refused — then swap in for the rotted copy,
    /// refcounts intact. Returns the repaired digest, or `None` if the
    /// bytes match no quarantined resident chunk.
    pub fn repair(&mut self, bytes: &[u8]) -> Option<Digest> {
        let d = chunk_digest(bytes);
        if !self.quarantined.contains(&d) {
            return None;
        }
        let c = self.chunks.get_mut(&d)?;
        self.quarantined.remove(&d);
        self.stored = self.stored - c.bytes.len() as u64 + bytes.len() as u64;
        c.bytes = bytes.to_vec();
        self.repaired += 1;
        self.metrics.incr(names::CHUNK_REPAIRED);
        Some(d)
    }

    /// Digests currently quarantined, sorted (the repair loop's work list).
    pub fn quarantined(&self) -> Vec<Digest> {
        let mut v: Vec<Digest> = self.quarantined.iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// All resident digests, sorted (scrub planning / fault injection).
    pub fn digests(&self) -> Vec<Digest> {
        let mut v: Vec<Digest> = self.chunks.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Fault-injection surface (bit-rot modeling, DESIGN.md §2.10): flip
    /// one bit of one stored chunk, both selected deterministically from
    /// `sel`. Returns the digest of the chunk whose bytes were damaged.
    pub fn corrupt_byte(&mut self, sel: u64) -> Option<Digest> {
        let keys = self.digests();
        if keys.is_empty() {
            return None;
        }
        let d = keys[(sel % keys.len() as u64) as usize];
        self.corrupt_chunk(&d, sel >> 16).then_some(d)
    }

    /// Directed fault injection: flip one bit inside a specific chunk's
    /// stored bytes (`off` wraps). Returns `false` for unknown/empty chunks.
    pub fn corrupt_chunk(&mut self, d: &Digest, off: u64) -> bool {
        match self.chunks.get_mut(d) {
            Some(c) if !c.bytes.is_empty() => {
                let at = (off % c.bytes.len() as u64) as usize;
                c.bytes[at] ^= 0x40;
                true
            }
            _ => false,
        }
    }

    pub fn contains(&self, d: &Digest) -> bool {
        self.chunks.contains_key(d)
    }

    /// Take an extra reference on an existing chunk. Returns `false` if
    /// the digest is unknown (caller decides whether that is fatal).
    pub fn incref(&mut self, d: &Digest) -> bool {
        match self.chunks.get_mut(d) {
            Some(c) => {
                c.refs += 1;
                self.dead.remove(d);
                true
            }
            None => false,
        }
    }

    /// Release one reference. At zero the chunk joins the dead set for a
    /// later [`Self::gc`]; unknown digests are ignored (idempotent
    /// release paths — e.g. a replayed truncation — stay safe).
    pub fn decref(&mut self, d: &Digest) {
        if let Some(c) = self.chunks.get_mut(d) {
            c.refs = c.refs.saturating_sub(1);
            if c.refs == 0 {
                self.dead.insert(*d);
            }
        }
    }

    /// Sweep the dead set: free every chunk whose refcount is still zero.
    /// Returns (chunks, bytes) collected.
    pub fn gc(&mut self) -> (u64, u64) {
        let mut n = 0u64;
        let mut bytes = 0u64;
        for d in std::mem::take(&mut self.dead) {
            match self.chunks.get(&d) {
                Some(c) if c.refs == 0 => {
                    bytes += c.bytes.len() as u64;
                    n += 1;
                    self.chunks.remove(&d);
                    // a swept chunk is gone, not corrupt — drop any pending
                    // quarantine so `repair` can't resurrect freed digests
                    self.quarantined.remove(&d);
                }
                _ => {} // resurrected (or already gone): not collectable
            }
        }
        self.stored -= bytes;
        self.gc_chunks += n;
        self.gc_bytes += bytes;
        if n > 0 {
            self.metrics.add(names::CHUNK_GC_COLLECTED, n);
        }
        (n, bytes)
    }

    /// Physical bytes currently held (dedup makes this <= logical bytes).
    pub fn stored_bytes(&self) -> u64 {
        self.stored
    }

    pub fn chunk_count(&self) -> usize {
        self.chunks.len()
    }

    pub fn dead_count(&self) -> usize {
        self.dead.len()
    }

    pub fn dedup_hits(&self) -> u64 {
        self.dedup_hits
    }

    pub fn dedup_bytes_saved(&self) -> u64 {
        self.dedup_saved
    }

    pub fn gc_collected(&self) -> (u64, u64) {
        (self.gc_chunks, self.gc_bytes)
    }

    /// Corrupt chunks detected (scrub or read-path refusal) since start.
    pub fn scrub_errors(&self) -> u64 {
        self.scrub_errors
    }

    /// Quarantined chunks healed from replica fills since start.
    pub fn repaired(&self) -> u64 {
        self.repaired
    }

    /// Current refcount of a chunk (tests / invariant checks).
    pub fn refs(&self, d: &Digest) -> u64 {
        self.chunks.get(d).map(|c| c.refs).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut cs = ChunkStore::new();
        let d = cs.put(b"hello chunk");
        assert_eq!(d, chunk_digest(b"hello chunk"));
        assert_eq!(cs.get(&d).unwrap(), b"hello chunk");
        assert_eq!(cs.stored_bytes(), 11);
        assert_eq!(cs.refs(&d), 1);
    }

    #[test]
    fn dedup_stores_once_and_counts() {
        let mut cs = ChunkStore::new();
        let a = cs.put(b"same bytes");
        let b = cs.put(b"same bytes");
        assert_eq!(a, b);
        assert_eq!(cs.chunk_count(), 1);
        assert_eq!(cs.refs(&a), 2);
        assert_eq!(cs.stored_bytes(), 10);
        assert_eq!(cs.dedup_hits(), 1);
        assert_eq!(cs.dedup_bytes_saved(), 10);
    }

    #[test]
    fn gc_only_collects_unreferenced() {
        let mut cs = ChunkStore::new();
        let keep = cs.put(b"keep");
        let drop_ = cs.put(b"drop");
        cs.decref(&drop_);
        assert!(cs.contains(&drop_), "dead bytes retained until sweep");
        let (n, bytes) = cs.gc();
        assert_eq!((n, bytes), (1, 4));
        assert!(!cs.contains(&drop_));
        assert!(cs.contains(&keep));
        assert_eq!(cs.stored_bytes(), 4);
    }

    #[test]
    fn dead_chunk_resurrects_on_put_or_incref() {
        let mut cs = ChunkStore::new();
        let d = cs.put(b"lazarus");
        cs.decref(&d);
        assert_eq!(cs.dead_count(), 1);
        // a re-put takes a fresh reference and cancels the death
        let d2 = cs.put(b"lazarus");
        assert_eq!(d, d2);
        assert_eq!(cs.gc(), (0, 0));
        assert!(cs.contains(&d));
        // same through incref
        cs.decref(&d);
        assert!(cs.incref(&d));
        assert_eq!(cs.gc(), (0, 0));
        assert!(cs.contains(&d));
    }

    #[test]
    fn decref_unknown_is_ignored_incref_reports() {
        let mut cs = ChunkStore::new();
        let ghost = chunk_digest(b"never stored");
        cs.decref(&ghost); // no panic
        assert!(!cs.incref(&ghost));
    }

    #[test]
    fn clone_is_deep() {
        let mut a = ChunkStore::new();
        let d = a.put(b"shared?");
        let mut b = a.clone();
        b.decref(&d);
        b.gc();
        assert!(!b.contains(&d));
        assert!(a.contains(&d), "clone must not share chunk state");
    }

    #[test]
    fn verified_get_refuses_flipped_bits() {
        let mut cs = ChunkStore::new();
        let d = cs.put(b"precious bytes");
        assert_eq!(cs.get_verified(&d).unwrap(), b"precious bytes");
        assert!(cs.corrupt_chunk(&d, 3));
        assert_eq!(cs.get_verified(&d), Err(ChunkGetError::Corrupt));
        // the unchecked accessor still returns the rotted bytes (tests only)
        assert_ne!(cs.get(&d).unwrap(), b"precious bytes");
        let ghost = chunk_digest(b"never stored");
        assert_eq!(cs.get_verified(&ghost), Err(ChunkGetError::Missing));
    }

    #[test]
    fn scrub_quarantines_and_repair_heals() {
        let mut cs = ChunkStore::new();
        let good = cs.put(b"untouched");
        let bad = cs.put(b"will rot");
        assert!(cs.corrupt_chunk(&bad, 0));
        // full sweep in one slice: exactly the rotted chunk is quarantined
        let (_, found) = cs.scrub_slice(0, 16);
        assert_eq!(found, vec![bad]);
        assert_eq!(cs.scrub_errors(), 1);
        assert_eq!(cs.quarantined(), vec![bad]);
        assert_eq!(cs.get_verified(&bad), Err(ChunkGetError::Corrupt));
        assert_eq!(cs.get_verified(&good).unwrap(), b"untouched");
        // a second sweep finds nothing new (already quarantined)
        let (_, again) = cs.scrub_slice(0, 16);
        assert!(again.is_empty());
        assert_eq!(cs.scrub_errors(), 1);
        // a mismatched fill is refused; the true bytes heal the chunk
        assert_eq!(cs.repair(b"wrong bytes"), None);
        assert_eq!(cs.repair(b"will rot"), Some(bad));
        assert_eq!(cs.get_verified(&bad).unwrap(), b"will rot");
        assert_eq!(cs.repaired(), 1);
        assert!(cs.quarantined().is_empty());
        assert_eq!(cs.refs(&bad), 1, "repair preserves refcounts");
    }

    #[test]
    fn scrub_slices_amortize_across_cursor() {
        let mut cs = ChunkStore::new();
        let mut ds: Vec<Digest> = (0..8u8).map(|i| cs.put(&[i; 64])).collect();
        ds.sort_unstable();
        for d in &ds {
            assert!(cs.corrupt_chunk(d, 7));
        }
        // limit-2 slices: four ticks cover the whole table exactly once
        let mut cursor = 0;
        let mut found = Vec::new();
        for _ in 0..4 {
            let (next, bad) = cs.scrub_slice(cursor, 2);
            assert_eq!(bad.len(), 2);
            found.extend(bad);
            cursor = next;
        }
        found.sort_unstable();
        assert_eq!(found, ds);
    }

    #[test]
    fn quarantine_direct_and_gc_clears_it() {
        let mut cs = ChunkStore::new();
        let d = cs.put(b"doomed");
        assert!(cs.corrupt_chunk(&d, 1));
        assert!(cs.quarantine(&d));
        assert!(!cs.quarantine(&d), "idempotent");
        cs.decref(&d);
        cs.gc();
        assert!(!cs.contains(&d));
        assert!(cs.quarantined().is_empty(), "gc drops quarantine entries");
        assert_eq!(cs.repair(b"doomed"), None, "freed digests cannot be re-filled");
    }

    #[test]
    fn corrupt_byte_is_deterministic() {
        let mut cs = ChunkStore::new();
        cs.put(b"aaaa");
        cs.put(b"bbbb");
        let mut twin = cs.clone();
        let d1 = cs.corrupt_byte(0x1234_5678).unwrap();
        let d2 = twin.corrupt_byte(0x1234_5678).unwrap();
        assert_eq!(d1, d2);
        assert_eq!(cs.get(&d1), twin.get(&d2));
        assert!(ChunkStore::new().corrupt_byte(7).is_none(), "empty store: no-op");
    }

    #[test]
    fn digests_are_domain_separated() {
        // a chunk digest is not a bare SHA-256 of the content
        assert_ne!(chunk_digest(b"abc").to_vec(), hmacsha::sha256(b"abc").to_vec());
        assert_eq!(digest_hex(&chunk_digest(b"abc")).len(), 16);
    }
}
