//! Disk timing models.
//!
//! Two storage tiers appear in the paper's testbed: site parallel file
//! systems (GPFS scratch — used as the XUFS *cache space* and as the
//! "local GPFS" series in Figs. 4–5) and the home-space disk behind the
//! user's file server. Both are modeled analytically: a per-operation cost
//! (metadata / seek / RPC inside the FS) plus streaming bandwidth.

use crate::simnet::{Clock, VirtualTime};

/// Analytic disk/FS timing model.
#[derive(Debug, Clone, PartialEq)]
pub struct DiskModel {
    /// Sequential streaming bandwidth, bytes/sec.
    pub bps: f64,
    /// Fixed per-operation cost (open/stat/create/...), seconds.
    pub op_s: f64,
}

impl DiskModel {
    pub fn new(bps: f64, op_s: f64) -> Self {
        DiskModel { bps, op_s }
    }

    /// A parallel FS (GPFS-like): `servers` stripes aggregate bandwidth,
    /// with slightly higher per-op cost (distributed metadata/token work).
    pub fn parallel(per_server_bps: f64, servers: usize, op_s: f64) -> Self {
        DiskModel { bps: per_server_bps * servers.max(1) as f64, op_s }
    }

    /// Duration of a pure metadata operation.
    pub fn op_secs(&self) -> f64 {
        self.op_s
    }

    /// Duration of a sequential transfer of `bytes` (plus one op cost).
    pub fn io_secs(&self, bytes: u64) -> f64 {
        self.op_s + bytes as f64 / self.bps
    }

    /// Account a metadata op against a clock.
    pub fn op(&self, clock: &dyn Clock) -> f64 {
        clock.advance_secs(self.op_s);
        self.op_s
    }

    /// Account a data transfer against a clock.
    pub fn io(&self, clock: &dyn Clock, bytes: u64) -> f64 {
        let t = self.io_secs(bytes);
        clock.advance_secs(t);
        t
    }

    /// Completion time of an async write started now (used by the metaq
    /// flush horizon bookkeeping).
    pub fn io_done_at(&self, now: VirtualTime, bytes: u64) -> VirtualTime {
        now.add_secs(self.io_secs(bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::SimClock;

    #[test]
    fn io_time_is_op_plus_stream() {
        let d = DiskModel::new(100.0 * 1024.0 * 1024.0, 0.002);
        let t = d.io_secs(100 * 1024 * 1024);
        assert!((t - 1.002).abs() < 1e-9, "t={t}");
        assert_eq!(d.op_secs(), 0.002);
    }

    #[test]
    fn parallel_fs_aggregates() {
        let d = DiskModel::parallel(100.0e6, 4, 0.003);
        assert_eq!(d.bps, 400.0e6);
        let single = DiskModel::new(100.0e6, 0.003);
        assert!(d.io_secs(1 << 30) < single.io_secs(1 << 30) / 3.0);
    }

    #[test]
    fn clock_accounting() {
        let c = SimClock::new();
        let d = DiskModel::new(1.0e6, 0.001);
        d.op(&c);
        d.io(&c, 1_000_000);
        assert!((c.now().as_secs() - (0.001 + 0.001 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn done_at_horizon() {
        let d = DiskModel::new(1.0e6, 0.0);
        let t0 = VirtualTime::from_secs(10.0);
        let done = d.io_done_at(t0, 2_000_000);
        assert!((done.as_secs() - 12.0).abs() < 1e-9);
    }
}
