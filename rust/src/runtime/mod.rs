//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes the transfer-plan / digest graphs
//! on the request path (python is never involved at runtime).
//!
//! The engine picks, per transfer, the largest artifact variant whose
//! block geometry fits, loops full chunks through it, and finishes ragged
//! tails with the bit-identical [`native`] implementation (cross-checked
//! by tests and golden vectors). With no artifacts directory the engine is
//! fully native — same results, no PJRT dependency at runtime.
//!
//! The PJRT execution path needs the `xla` bindings crate, which is not
//! part of the offline crate set; it is gated behind the `pjrt` cargo
//! feature (see `rust/Cargo.toml`). The default build is fully native and
//! produces bit-identical digests.

pub mod native;

use std::collections::HashMap;
use std::fmt;
use std::sync::Mutex;

use crate::metrics::{names, Metrics};

/// Runtime error: artifact loading or PJRT execution failed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "runtime error: {}", self.0)
    }
}
impl std::error::Error for RuntimeError {}

/// Result alias for runtime operations.
pub type Result<T> = std::result::Result<T, RuntimeError>;

#[cfg(feature = "pjrt")]
fn rt_err(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// Result of planning a delta writeback for one file.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferPlan {
    pub digests: Vec<i32>,
    pub dirty: Vec<bool>,
    /// Stripe id per block (-1 for clean blocks).
    pub stripe: Vec<i32>,
}

impl TransferPlan {
    pub fn dirty_blocks(&self) -> usize {
        self.dirty.iter().filter(|&&d| d).count()
    }
}

/// One loaded HLO artifact.
#[cfg(feature = "pjrt")]
struct Variant {
    kind: String,
    blocks: usize,
    lanes: usize,
    stripes: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// Digest/plan engine: PJRT-backed when artifacts are present (and the
/// `pjrt` feature is enabled), native otherwise. Thread-safe (`execute`
/// is serialized per engine).
pub struct DigestEngine {
    #[cfg(feature = "pjrt")]
    pjrt: Option<Pjrt>,
    weights: Mutex<HashMap<usize, Vec<i32>>>,
    metrics: Metrics,
}

#[cfg(feature = "pjrt")]
struct Pjrt {
    _client: xla::PjRtClient,
    variants: Vec<Variant>,
    /// PJRT executions are serialized; the CPU client is not re-entrant
    /// under concurrent `execute` from multiple threads.
    gate: Mutex<()>,
}

// SAFETY: the `xla` crate wraps the PJRT C API in `Rc` + raw pointers, so
// its types are neither Send nor Sync by default. All `Rc` handles in this
// engine (the client and every loaded executable that references it) are
// owned *together* inside this one struct — no `Rc` clone ever escapes it —
// so moving the struct between threads moves every reference count holder
// at once. Cross-thread *use* is serialized by `gate`, which every
// `execute` path locks first; the PJRT CPU client itself is thread-safe
// under serialized access.
#[cfg(feature = "pjrt")]
unsafe impl Send for Pjrt {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Pjrt {}

impl fmt::Debug for DigestEngine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("DigestEngine")
            .field("backend", &if self.is_pjrt() { "pjrt" } else { "native" })
            .finish()
    }
}

impl DigestEngine {
    /// Native-only engine.
    pub fn native(metrics: Metrics) -> Self {
        DigestEngine {
            #[cfg(feature = "pjrt")]
            pjrt: None,
            weights: Mutex::new(HashMap::new()),
            metrics,
        }
    }

    /// Load every artifact listed in `<dir>/manifest.json`; falls back to
    /// native when the directory or manifest is missing (or the `pjrt`
    /// feature is disabled — the build that matters offline).
    #[cfg(not(feature = "pjrt"))]
    pub fn from_artifacts(dir: &str, metrics: Metrics) -> Result<Self> {
        let _ = dir;
        Ok(Self::native(metrics))
    }

    /// Load every artifact listed in `<dir>/manifest.json`; falls back to
    /// native when the directory or manifest is missing.
    #[cfg(feature = "pjrt")]
    pub fn from_artifacts(dir: &str, metrics: Metrics) -> Result<Self> {
        use crate::util::Json;
        use std::path::Path;

        let manifest_path = Path::new(dir).join("manifest.json");
        if !manifest_path.exists() {
            return Ok(Self::native(metrics));
        }
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| rt_err(format!("reading {manifest_path:?}: {e}")))?;
        let manifest = Json::parse(&text).map_err(|e| rt_err(format!("manifest.json: {e}")))?;
        let client = xla::PjRtClient::cpu().map_err(|e| rt_err(format!("pjrt cpu client: {e:?}")))?;
        let mut variants = Vec::new();
        for v in manifest
            .get("variants")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| rt_err("manifest.json: missing variants"))?
        {
            let file = v
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| rt_err("variant missing file"))?;
            let path = Path::new(dir).join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| rt_err("non-utf8 path"))?,
            )
            .map_err(|e| rt_err(format!("loading {path:?}: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| rt_err(format!("compiling {file}: {e:?}")))?;
            variants.push(Variant {
                kind: v.get("kind").and_then(|k| k.as_str()).unwrap_or("").to_string(),
                blocks: v.get("blocks").and_then(|b| b.as_i64()).unwrap_or(0) as usize,
                lanes: v.get("lanes").and_then(|l| l.as_i64()).unwrap_or(0) as usize,
                stripes: v.get("stripes").and_then(|s| s.as_i64()).unwrap_or(0) as usize,
                exe,
            });
        }
        // biggest variants first so chunking prefers them
        variants.sort_by(|a, b| b.blocks.cmp(&a.blocks));
        Ok(DigestEngine {
            pjrt: Some(Pjrt { _client: client, variants, gate: Mutex::new(()) }),
            weights: Mutex::new(HashMap::new()),
            metrics,
        })
    }

    pub fn is_pjrt(&self) -> bool {
        #[cfg(feature = "pjrt")]
        {
            self.pjrt.is_some()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            false
        }
    }

    fn weights_for(&self, lanes: usize) -> Vec<i32> {
        let mut g = self.weights.lock().unwrap();
        g.entry(lanes).or_insert_with(|| native::make_weights(lanes)).clone()
    }

    /// Per-block digests of `data` with `block_bytes` blocks.
    ///
    /// Bulk digests run on the native engine: it is bit-identical to the
    /// HLO artifacts (pinned by golden vectors + `tests/pjrt_runtime.rs`)
    /// and ~6x faster than interpret-lowered HLO on the CPU PJRT client
    /// (EXPERIMENTS.md §Perf L3 #2). The PJRT path stays on the request
    /// path through [`Self::plan`]'s fused variants and is directly
    /// callable via [`Self::digests_via_pjrt`].
    pub fn digests(&self, data: &[u8], block_bytes: usize) -> Vec<i32> {
        let lanes = block_bytes / 4;
        let weights = self.weights_for(lanes);
        let n_blocks = if data.is_empty() { 1 } else { data.len().div_ceil(block_bytes) };
        self.metrics.incr(names::DIGEST_CALLS);
        self.metrics.add(names::DIGEST_BLOCKS, n_blocks as u64);
        native::digest_blocks(data, block_bytes, &weights)
    }

    /// Digest through the AOT PJRT artifacts (None without artifacts or
    /// on an execution error). Bit-identical to [`Self::digests`].
    #[cfg(not(feature = "pjrt"))]
    pub fn digests_via_pjrt(&self, _data: &[u8], _block_bytes: usize) -> Option<Vec<i32>> {
        None
    }

    /// Digest through the AOT PJRT artifacts (None without artifacts or
    /// on an execution error). Bit-identical to [`Self::digests`].
    #[cfg(feature = "pjrt")]
    pub fn digests_via_pjrt(&self, data: &[u8], block_bytes: usize) -> Option<Vec<i32>> {
        let pjrt = self.pjrt.as_ref()?;
        let lanes = block_bytes / 4;
        let weights = self.weights_for(lanes);
        let n_blocks = if data.is_empty() { 1 } else { data.len().div_ceil(block_bytes) };
        self.metrics.incr(names::DIGEST_CALLS);
        self.metrics.add(names::DIGEST_BLOCKS, n_blocks as u64);
        self.digests_pjrt(pjrt, data, block_bytes, lanes, n_blocks, &weights)
    }

    /// Chunk full variant-sized groups of blocks through PJRT; do the
    /// ragged tail natively. Returns None (caller falls back to native)
    /// only on an execution error.
    #[cfg(feature = "pjrt")]
    fn digests_pjrt(
        &self,
        pjrt: &Pjrt,
        data: &[u8],
        block_bytes: usize,
        lanes: usize,
        n_blocks: usize,
        weights: &[i32],
    ) -> Option<Vec<i32>> {
        let mut out = Vec::with_capacity(n_blocks);
        let mut block = 0usize;
        while block < n_blocks {
            let remaining = n_blocks - block;
            let var = pjrt
                .variants
                .iter()
                .find(|v| v.kind == "digest" && v.lanes == lanes && v.blocks <= remaining);
            let Some(var) = var else {
                // no fitting variant: finish the tail natively
                let start = block * block_bytes;
                let tail = &data[start.min(data.len())..];
                out.extend(native::digest_blocks(tail, block_bytes, weights).into_iter().take(remaining));
                // digest_blocks on empty tail yields 1 zero-block digest;
                // pad out if the remaining count is larger (all-zero blocks)
                while out.len() < n_blocks {
                    let zero = native::digest_lanes(&vec![0i32; lanes], weights);
                    out.push(zero);
                }
                return Some(out);
            };
            let chunk_bytes = var.blocks * block_bytes;
            let start = block * block_bytes;
            let end = (start + chunk_bytes).min(data.len());
            let mut lanes_buf = vec![0i32; var.blocks * lanes];
            let chunk = &data[start.min(data.len())..end];
            for (i, four) in chunk.chunks(4).enumerate() {
                let mut b = [0u8; 4];
                b[..four.len()].copy_from_slice(four);
                lanes_buf[i] = i32::from_le_bytes(b);
            }
            let result = self.exec_digest(pjrt, var, &lanes_buf, weights);
            match result {
                Ok(d) => out.extend(d),
                Err(_) => return None,
            }
            block += var.blocks;
        }
        out.truncate(n_blocks);
        Some(out)
    }

    #[cfg(feature = "pjrt")]
    fn exec_digest(
        &self,
        pjrt: &Pjrt,
        var: &Variant,
        lanes_buf: &[i32],
        weights: &[i32],
    ) -> Result<Vec<i32>> {
        let _g = pjrt.gate.lock().unwrap();
        let blocks_lit = xla::Literal::vec1(lanes_buf)
            .reshape(&[var.blocks as i64, var.lanes as i64])
            .map_err(|e| rt_err(format!("reshape: {e:?}")))?;
        let weights_lit = xla::Literal::vec1(&weights[..var.lanes]);
        let bufs = var
            .exe
            .execute::<xla::Literal>(&[blocks_lit, weights_lit])
            .map_err(|e| rt_err(format!("execute: {e:?}")))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err(format!("to_literal: {e:?}")))?;
        let tuple = lit.to_tuple().map_err(|e| rt_err(format!("to_tuple: {e:?}")))?;
        let first = tuple.into_iter().next().ok_or_else(|| rt_err("empty result tuple"))?;
        first.to_vec::<i32>().map_err(|e| rt_err(format!("to_vec: {e:?}")))
    }

    /// Full transfer plan: digests + dirty mask vs `old_digests` + a
    /// balanced stripe assignment over `num_stripes`.
    pub fn plan(
        &self,
        data: &[u8],
        old_digests: &[i32],
        block_bytes: usize,
        num_stripes: usize,
    ) -> TransferPlan {
        // The HLO "plan" variants fuse digest+dirty+stripe for fixed-size
        // chunks; chunking the *stripe* stage would change the balanced
        // assignment semantics (the cumsum must span the whole file), so
        // the engine always computes digests (PJRT-accelerated) and then
        // derives dirty+stripes over the full block vector natively —
        // identical maths, whole-file scope. The fused plan artifacts are
        // still exercised directly by `exec_plan_variant` (tests + the
        // single-chunk fast path below).
        #[cfg(feature = "pjrt")]
        if let Some(pjrt) = &self.pjrt {
            let lanes = block_bytes / 4;
            let n_blocks = if data.is_empty() { 1 } else { data.len().div_ceil(block_bytes) };
            if let Some(var) = pjrt.variants.iter().find(|v| {
                v.kind == "plan" && v.lanes == lanes && v.blocks == n_blocks && v.stripes == num_stripes
            }) {
                let weights = self.weights_for(lanes);
                if let Ok(plan) =
                    self.exec_plan_variant(pjrt, var, data, old_digests, block_bytes, &weights)
                {
                    self.metrics.incr(names::DIGEST_CALLS);
                    self.metrics.add(names::DIGEST_BLOCKS, n_blocks as u64);
                    return plan;
                }
            }
        }
        let digests = self.digests(data, block_bytes);
        let mut dirty = native::dirty_mask(&digests, old_digests);
        // if the file shrank, old digests past the new end don't name
        // shippable blocks — the shrink travels via WriteDelta.total_size
        dirty.truncate(digests.len());
        let block_sizes = block_byte_sizes(data.len(), block_bytes, digests.len());
        let stripe = native::stripe_plan(&dirty, &block_sizes, num_stripes);
        TransferPlan { digests, dirty, stripe }
    }

    /// Execute a fused plan artifact for an exactly-matching geometry.
    #[cfg(feature = "pjrt")]
    fn exec_plan_variant(
        &self,
        pjrt: &Pjrt,
        var: &Variant,
        data: &[u8],
        old_digests: &[i32],
        block_bytes: usize,
        weights: &[i32],
    ) -> Result<TransferPlan> {
        let _g = pjrt.gate.lock().unwrap();
        let mut lanes_buf = vec![0i32; var.blocks * var.lanes];
        for (i, four) in data.chunks(4).enumerate() {
            let mut b = [0u8; 4];
            b[..four.len()].copy_from_slice(four);
            lanes_buf[i] = i32::from_le_bytes(b);
        }
        let mut old = old_digests.to_vec();
        old.resize(var.blocks, 0);
        let sizes: Vec<i32> = block_byte_sizes(data.len(), block_bytes, var.blocks)
            .into_iter()
            .map(|s| s as i32)
            .collect();

        let blocks_lit = xla::Literal::vec1(&lanes_buf)
            .reshape(&[var.blocks as i64, var.lanes as i64])
            .map_err(|e| rt_err(format!("reshape: {e:?}")))?;
        let old_lit = xla::Literal::vec1(&old);
        let weights_lit = xla::Literal::vec1(&weights[..var.lanes]);
        let sizes_lit = xla::Literal::vec1(&sizes);
        let bufs = var
            .exe
            .execute::<xla::Literal>(&[blocks_lit, old_lit, weights_lit, sizes_lit])
            .map_err(|e| rt_err(format!("execute: {e:?}")))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| rt_err(format!("to_literal: {e:?}")))?;
        let mut tuple = lit.to_tuple().map_err(|e| rt_err(format!("to_tuple: {e:?}")))?;
        if tuple.len() != 3 {
            return Err(rt_err(format!("plan artifact returned {} outputs", tuple.len())));
        }
        let stripe = tuple.pop().unwrap().to_vec::<i32>().map_err(|e| rt_err(format!("{e:?}")))?;
        let dirty_i = tuple.pop().unwrap().to_vec::<i32>().map_err(|e| rt_err(format!("{e:?}")))?;
        let digests = tuple.pop().unwrap().to_vec::<i32>().map_err(|e| rt_err(format!("{e:?}")))?;
        Ok(TransferPlan { digests, dirty: dirty_i.into_iter().map(|d| d != 0).collect(), stripe })
    }
}

/// Actual byte count of each block (the last real block may be short;
/// padded plan blocks get 0 bytes so they never affect striping).
pub fn block_byte_sizes(data_len: usize, block_bytes: usize, n_blocks: usize) -> Vec<u32> {
    (0..n_blocks)
        .map(|i| {
            let start = i * block_bytes;
            let end = (start + block_bytes).min(data_len);
            end.saturating_sub(start) as u32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn native_engine() -> DigestEngine {
        DigestEngine::native(Metrics::new())
    }

    #[test]
    fn native_digests_deterministic() {
        let e = native_engine();
        let mut rng = Rng::new(3);
        let mut data = vec![0u8; 200_000];
        rng.fill_bytes(&mut data);
        let a = e.digests(&data, 65536);
        let b = e.digests(&data, 65536);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4); // ceil(200000 / 65536)
    }

    #[test]
    fn plan_flags_changed_blocks() {
        let e = native_engine();
        let mut rng = Rng::new(4);
        let mut data = vec![0u8; 300_000];
        rng.fill_bytes(&mut data);
        let old = e.digests(&data, 65536);
        data[70_000] ^= 0xFF; // block 1
        data[200_000] ^= 0xFF; // block 3
        let plan = e.plan(&data, &old, 65536, 12);
        assert_eq!(plan.dirty, vec![false, true, false, true, false]);
        assert_eq!(plan.dirty_blocks(), 2);
        assert_eq!(plan.stripe[0], -1);
        assert!(plan.stripe[1] >= 0 && plan.stripe[3] >= 0);
    }

    #[test]
    fn plan_empty_old_digests_all_dirty() {
        let e = native_engine();
        let data = vec![1u8; 100_000];
        let plan = e.plan(&data, &[], 65536, 12);
        assert!(plan.dirty.iter().all(|&d| d));
    }

    #[test]
    fn block_sizes_tail() {
        assert_eq!(block_byte_sizes(200_000, 65536, 5), vec![65536, 65536, 65536, 3392, 0]);
        assert_eq!(block_byte_sizes(0, 65536, 1), vec![0]);
    }

    #[test]
    fn metrics_counted() {
        let m = Metrics::new();
        let e = DigestEngine::native(m.clone());
        e.digests(&[1, 2, 3], 1024);
        assert_eq!(m.counter(names::DIGEST_CALLS), 1);
        assert_eq!(m.counter(names::DIGEST_BLOCKS), 1);
    }

    #[test]
    fn missing_artifacts_dir_falls_back_to_native() {
        let e = DigestEngine::from_artifacts("/nonexistent/dir", Metrics::new()).unwrap();
        assert!(!e.is_pjrt());
    }

    // PJRT-backed equivalence tests live in rust/tests/pjrt_runtime.rs
    // (they need the artifacts/ directory built by `make artifacts` and
    // the `pjrt` cargo feature).
}
