//! Native (pure-rust) digest engine — bit-identical to the Pallas/HLO
//! pipeline in `python/compile/`.
//!
//! Exists for two reasons: (1) the transfer engine must work when AOT
//! artifacts are absent, (2) tests cross-check the PJRT path against this
//! implementation, which is itself pinned by golden vectors shared with
//! `python/tests/test_vectors.py`.

/// Polynomial base for weights (== `ref.DIGEST_BASE`).
pub const DIGEST_BASE: u32 = 1_000_003;

/// Finalization multiplier (== `ref.MIX_MUL`, 0x9E3779B9 as i32).
pub const MIX_MUL: i32 = -1_640_531_527;

/// Int32 lanes per 64 KiB stripe block.
pub const LANES_64K: usize = 16384;

/// w[i] = DIGEST_BASE^i mod 2^32, as i32 (== `ref.make_weights`).
pub fn make_weights(n: usize) -> Vec<i32> {
    let mut w = Vec::with_capacity(n);
    let mut acc: u32 = 1;
    for _ in 0..n {
        w.push(acc as i32);
        acc = acc.wrapping_mul(DIGEST_BASE);
    }
    w
}

/// Digest one block of int32 lanes (== `ref.block_digest_ref` row).
pub fn digest_lanes(lanes: &[i32], weights: &[i32]) -> i32 {
    debug_assert!(lanes.len() <= weights.len());
    let mut raw: i32 = 0;
    for (x, w) in lanes.iter().zip(weights) {
        raw = raw.wrapping_add(x.wrapping_mul(*w));
    }
    let mixed = raw.wrapping_mul(MIX_MUL);
    // jnp.right_shift on int32 is arithmetic — rust's `>>` on i32 matches.
    mixed ^ (mixed >> 15)
}

/// Widen little-endian bytes to int32 lanes, zero-padding the tail —
/// exactly how the rust side feeds file content to the HLO artifacts.
pub fn bytes_to_lanes(bytes: &[u8], lanes: usize) -> Vec<i32> {
    let mut out = vec![0i32; lanes];
    for (i, chunk) in bytes.chunks(4).enumerate().take(lanes) {
        let mut b = [0u8; 4];
        b[..chunk.len()].copy_from_slice(chunk);
        out[i] = i32::from_le_bytes(b);
    }
    out
}

/// Per-block digests of a byte buffer with `block_bytes`-sized blocks
/// (last block zero-padded). Returns one digest per block; empty content
/// yields a single digest of the zero block.
///
/// Hot path (EXPERIMENTS.md §Perf L3 #1): full blocks are digested
/// straight off the byte buffer in 4-lane unrolled strides — no per-block
/// lane `Vec` — which lets LLVM vectorize the wrapping i32 MACs. Only the
/// ragged tail goes through the padded scalar path.
pub fn digest_blocks(data: &[u8], block_bytes: usize, weights: &[i32]) -> Vec<i32> {
    let lanes = block_bytes / 4;
    debug_assert!(weights.len() >= lanes);
    if data.is_empty() {
        return vec![digest_lanes(&vec![0i32; lanes], weights)];
    }
    let mut out = Vec::with_capacity(data.len().div_ceil(block_bytes));
    let mut chunks = data.chunks_exact(block_bytes);
    for chunk in &mut chunks {
        out.push(digest_full_block(chunk, &weights[..lanes]));
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let l = bytes_to_lanes(rem, lanes);
        out.push(digest_lanes(&l, weights));
    }
    out
}

/// Digest one full (`lanes.len() * 4`-byte) block directly from bytes.
#[inline]
fn digest_full_block(chunk: &[u8], weights: &[i32]) -> i32 {
    debug_assert_eq!(chunk.len(), weights.len() * 4);
    let mut acc = [0i32; 4];
    let mut i = 0usize;
    let n = weights.len();
    while i + 4 <= n {
        // 4 independent accumulators break the dependence chain so the
        // wrapping mul-adds vectorize
        for k in 0..4 {
            let b = i + k;
            let v = i32::from_le_bytes([
                chunk[4 * b],
                chunk[4 * b + 1],
                chunk[4 * b + 2],
                chunk[4 * b + 3],
            ]);
            acc[k] = acc[k].wrapping_add(v.wrapping_mul(weights[b]));
        }
        i += 4;
    }
    let mut raw = acc[0].wrapping_add(acc[1]).wrapping_add(acc[2]).wrapping_add(acc[3]);
    while i < n {
        let v = i32::from_le_bytes([
            chunk[4 * i],
            chunk[4 * i + 1],
            chunk[4 * i + 2],
            chunk[4 * i + 3],
        ]);
        raw = raw.wrapping_add(v.wrapping_mul(weights[i]));
        i += 1;
    }
    let mixed = raw.wrapping_mul(MIX_MUL);
    mixed ^ (mixed >> 15)
}

/// Dirty mask (== `ref.dirty_mask_ref`): new vs old digests; if lengths
/// differ, the extra/missing blocks are dirty.
pub fn dirty_mask(new: &[i32], old: &[i32]) -> Vec<bool> {
    let n = new.len().max(old.len());
    (0..n)
        .map(|i| match (new.get(i), old.get(i)) {
            (Some(a), Some(b)) => a != b,
            _ => true,
        })
        .collect()
}

/// Balanced stripe plan (== `ref.stripe_plan_ref`): cumsum of dirty
/// payload split into `num_stripes` equal spans; clean blocks get -1.
pub fn stripe_plan(dirty: &[bool], block_bytes: &[u32], num_stripes: usize) -> Vec<i32> {
    debug_assert_eq!(dirty.len(), block_bytes.len());
    let stripes = num_stripes.max(1) as i64;
    let payload: Vec<i64> =
        dirty.iter().zip(block_bytes).map(|(&d, &b)| if d { b as i64 } else { 0 }).collect();
    let total: i64 = payload.iter().sum();
    let span = ((total + stripes - 1) / stripes).max(1);
    let mut before: i64 = 0;
    payload
        .iter()
        .zip(dirty)
        .map(|(&p, &d)| {
            let s = ((before / span).min(stripes - 1)) as i32;
            before += p;
            if d {
                s
            } else {
                -1
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Golden vectors shared with python/tests/test_vectors.py — generated
    // from ref.py and frozen on both sides.
    const GOLDEN_N: usize = 8;
    const GOLDEN_WEIGHTS: [i32; 8] =
        [1, 1000003, -721379959, 583896283, 1525764945, -429739981, 272515929, 1071616587];
    const GOLDEN_DIGESTS: [i32; 4] = [19047297, 1229507876, 1855012728, 644638899];

    fn golden_block(j: u32) -> Vec<i32> {
        (0..GOLDEN_N as u32).map(|i| (j.wrapping_mul(1000003) + i * 7 + 1) as i32).collect()
    }

    #[test]
    fn golden_weights_match_python() {
        assert_eq!(make_weights(GOLDEN_N), GOLDEN_WEIGHTS);
    }

    #[test]
    fn golden_digests_match_python() {
        let w = make_weights(GOLDEN_N);
        for (j, want) in GOLDEN_DIGESTS.iter().enumerate() {
            assert_eq!(digest_lanes(&golden_block(j as u32), &w), *want, "block {j}");
        }
    }

    #[test]
    fn bytes_to_lanes_le_and_padding() {
        let lanes = bytes_to_lanes(&[1, 0, 0, 0, 0xFF, 0xFF, 0xFF, 0xFF, 7], 4);
        assert_eq!(lanes, vec![1, -1, 7, 0]);
    }

    #[test]
    fn digest_blocks_chunks_and_pads() {
        let w = make_weights(4);
        let data = [1u8; 20]; // 16-byte blocks -> 2 blocks, second padded
        let d = digest_blocks(&data, 16, &w);
        assert_eq!(d.len(), 2);
        // a full block of 0x01010101 differs from the padded 4-byte tail
        assert_ne!(d[0], d[1]);
        // deterministic
        assert_eq!(d, digest_blocks(&data, 16, &w));
        // empty content: one zero-block digest
        assert_eq!(digest_blocks(&[], 16, &w).len(), 1);
    }

    #[test]
    fn single_bit_corruption_detected() {
        let w = make_weights(LANES_64K);
        let mut data = vec![0x5Au8; 192 * 1024];
        let base = digest_blocks(&data, 64 * 1024, &w);
        data[70_000] ^= 0x10; // inside block 1
        let got = digest_blocks(&data, 64 * 1024, &w);
        assert_eq!(base[0], got[0]);
        assert_ne!(base[1], got[1]);
        assert_eq!(base[2], got[2]);
    }

    #[test]
    fn dirty_mask_length_mismatch_is_dirty() {
        assert_eq!(dirty_mask(&[1, 2, 3], &[1, 9, 3]), vec![false, true, false]);
        assert_eq!(dirty_mask(&[1, 2], &[1]), vec![false, true]);
        assert_eq!(dirty_mask(&[1], &[1, 2]), vec![false, true]);
    }

    #[test]
    fn stripe_plan_matches_reference_semantics() {
        // mirrors python test_short_tail_block_weighting
        let dirty = vec![true; 8];
        let mut bytes = vec![64u32; 8];
        bytes[7] = 4;
        let plan = stripe_plan(&dirty, &bytes, 2);
        assert_eq!(plan[0], 0);
        assert_eq!(plan[7], 1);
        // clean blocks unassigned
        let plan2 = stripe_plan(&[false, true], &[64, 64], 12);
        assert_eq!(plan2, vec![-1, 0]);
    }

    #[test]
    fn stripe_plan_balanced_counts() {
        let dirty = vec![true; 48];
        let bytes = vec![1024u32; 48];
        let plan = stripe_plan(&dirty, &bytes, 12);
        let mut counts = [0; 12];
        for p in plan {
            counts[p as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 4), "{counts:?}");
    }

    #[test]
    fn all_clean_plan_is_empty() {
        let plan = stripe_plan(&[false; 4], &[64; 4], 12);
        assert!(plan.iter().all(|&p| p == -1));
    }
}
