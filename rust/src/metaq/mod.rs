//! Persisted meta-operation queue (paper §3.1).
//!
//! "System calls that modify a file (or directory) in a XUFS partition
//! return when the local cache copy is updated, and the operation is
//! appended to a persisted meta-operation queue. No file (or directory)
//! operation blocks on a remote network call."
//!
//! Ops are persisted into the cache space's file store under
//! `/.xufs/queue/<seq>` (binary-encoded), so they survive a client crash;
//! the `xufs sync` command-line tool replays them after recovery
//! ([`MetaQueue::recover`] + the client's flush path). Sequence numbers
//! are monotonic per client and make server-side application idempotent.

use crate::homefs::{FileStore, FsResult};
use crate::proto::{Decoder, Encoder, MetaOp};
use crate::simnet::VirtualTime;

/// Directory inside the cache space holding the persisted queue.
pub const QUEUE_DIR: &str = "/.xufs/queue";

/// WriteFull payloads at or above this size are persisted BY REFERENCE:
/// the aggregated content already lives in the cache store at the op's
/// path (the close wrote it there before enqueueing), so the queue entry
/// only records path+digests and recovery rebuilds the full write from
/// the surviving cache copy. Avoids doubling cache-space usage and a full
/// payload memcpy per close (EXPERIMENTS.md §Perf L3 #3). Recovery after
/// further local closes still yields the correct final home state —
/// last-close-wins means the *latest* cache content is what must land.
pub const SPILL_THRESHOLD: usize = 256 * 1024;

fn persist_bytes(op: &MetaOp) -> Vec<u8> {
    let mut e = Encoder::new();
    match op {
        MetaOp::WriteFull { path, data, digests } if data.len() >= SPILL_THRESHOLD => {
            e.u8(1); // by-reference entry
            e.str(path);
            e.i32_slice(digests);
        }
        _ => {
            e.u8(0); // inline entry
            op.encode_into(&mut e);
        }
    }
    e.into_bytes()
}

fn recover_entry(store: &FileStore, bytes: &[u8]) -> Option<MetaOp> {
    let mut d = Decoder::new(bytes);
    match d.u8().ok()? {
        0 => {
            let op = MetaOp::decode_from(&mut d).ok()?;
            d.expect_end().ok()?;
            Some(op)
        }
        1 => {
            let path = d.str().ok()?;
            let digests = d.i32_vec().ok()?;
            d.expect_end().ok()?;
            let data = store.read(&path).ok()?.to_vec();
            Some(MetaOp::WriteFull { path, data, digests })
        }
        _ => None,
    }
}

/// The persisted queue. Holds an in-memory view; every mutation is written
/// through to the backing store immediately.
#[derive(Debug)]
pub struct MetaQueue {
    pending: Vec<(u64, MetaOp)>,
    next_seq: u64,
}

fn entry_path(seq: u64) -> String {
    format!("{QUEUE_DIR}/{seq:020}")
}

impl MetaQueue {
    pub fn new() -> Self {
        MetaQueue { pending: Vec::new(), next_seq: 1 }
    }

    /// Append an op: persists to `store` then records it in memory.
    /// Returns the assigned sequence number.
    pub fn append(&mut self, store: &mut FileStore, op: MetaOp, now: VirtualTime) -> FsResult<u64> {
        let seq = self.next_seq;
        self.next_seq += 1;
        store.mkdir_p(QUEUE_DIR, now)?;
        store.write(&entry_path(seq), &persist_bytes(&op), now)?;
        self.pending.push((seq, op));
        Ok(seq)
    }

    /// Ops awaiting replay, in order.
    pub fn pending(&self) -> &[(u64, MetaOp)] {
        &self.pending
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Total WAN payload of the pending ops.
    pub fn pending_bytes(&self) -> u64 {
        self.pending.iter().map(|(_, op)| op.wire_bytes()).sum()
    }

    /// Remove the front op for shipping (disk entry stays until `ack`;
    /// on failure `push_front` restores it). Avoids cloning large
    /// payloads on the flush path.
    pub fn take_front(&mut self) -> Option<(u64, MetaOp)> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.pending.remove(0))
        }
    }

    /// Put an unshipped op back at the front (disconnection mid-flush).
    pub fn push_front(&mut self, seq: u64, op: MetaOp) {
        self.pending.insert(0, (seq, op));
    }

    /// Move out EVERY pending op for a compound flush (one WAN round trip
    /// for the whole queue). Disk entries stay until `ack`; on failure
    /// [`Self::push_front_all`] restores the batch.
    pub fn take_all(&mut self) -> Vec<(u64, MetaOp)> {
        std::mem::take(&mut self.pending)
    }

    /// Restore a batch of unshipped ops (in order) at the queue front
    /// (disconnection mid-compound).
    pub fn push_front_all(&mut self, mut ops: Vec<(u64, MetaOp)>) {
        ops.append(&mut self.pending);
        self.pending = ops;
    }

    /// Server acknowledged `seq`: drop it from memory and disk.
    pub fn ack(&mut self, store: &mut FileStore, seq: u64, now: VirtualTime) -> FsResult<()> {
        self.pending.retain(|(s, _)| *s != seq);
        let _ = store.unlink(&entry_path(seq), now); // absent on re-ack: fine
        Ok(())
    }

    /// Replace a pending op in place (e.g. delta flush demoted to a full
    /// flush after the server reported a stale base). Keeps the same seq
    /// ordering; persists the new encoding.
    pub fn replace(
        &mut self,
        store: &mut FileStore,
        seq: u64,
        op: MetaOp,
        now: VirtualTime,
    ) -> FsResult<bool> {
        for (s, o) in &mut self.pending {
            if *s == seq {
                store.write(&entry_path(seq), &persist_bytes(&op), now)?;
                *o = op;
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Rebuild the queue from the persisted entries after a client crash.
    /// Corrupt entries are skipped (counted), matching the recovery tool's
    /// best-effort semantics.
    pub fn recover(store: &FileStore) -> (Self, usize) {
        let mut pending = Vec::new();
        let mut corrupt = 0;
        let mut max_seq = 0;
        if let Ok(entries) = store.readdir(QUEUE_DIR) {
            for (name, _) in entries {
                let Ok(seq) = name.parse::<u64>() else {
                    corrupt += 1;
                    continue;
                };
                match store.read(&entry_path(seq)).ok().map(|b| b.to_vec()).and_then(|b| recover_entry(store, &b)) {
                    Some(op) => {
                        pending.push((seq, op));
                        max_seq = max_seq.max(seq);
                    }
                    None => corrupt += 1,
                }
            }
        }
        pending.sort_by_key(|(s, _)| *s);
        // next_seq continues after everything ever persisted, so replayed
        // and new ops can't collide
        (MetaQueue { pending, next_seq: max_seq + 1 }, corrupt)
    }
}

impl Default for MetaQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::homefs::FileStore;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    fn op(path: &str) -> MetaOp {
        MetaOp::WriteFull { path: path.into(), data: b"x".to_vec(), digests: vec![1] }
    }

    #[test]
    fn append_assigns_monotonic_seqs_and_persists() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let s2 = q.append(&mut store, MetaOp::Unlink { path: "/b".into() }, t(2.0)).unwrap();
        assert!(s2 > s1);
        assert_eq!(q.len(), 2);
        assert!(store.exists(&entry_path(s1)));
        assert!(store.exists(&entry_path(s2)));
        assert!(q.pending_bytes() > 0);
    }

    #[test]
    fn ack_removes_from_memory_and_disk() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let s2 = q.append(&mut store, op("/b"), t(1.0)).unwrap();
        q.ack(&mut store, s1, t(2.0)).unwrap();
        assert_eq!(q.len(), 1);
        assert!(!store.exists(&entry_path(s1)));
        assert!(store.exists(&entry_path(s2)));
    }

    #[test]
    fn recovery_restores_order_and_continues_seqs() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        q.append(&mut store, op("/b"), t(1.0)).unwrap();
        let s3 = q.append(&mut store, MetaOp::Mkdir { path: "/d".into() }, t(1.0)).unwrap();
        q.ack(&mut store, s1, t(2.0)).unwrap();

        // crash: drop q, recover from store
        let (mut r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        assert_eq!(r.len(), 2);
        assert_eq!(r.pending()[0].1.path(), "/b");
        assert_eq!(r.pending()[1].1, MetaOp::Mkdir { path: "/d".into() });
        // new appends continue past the recovered max
        let s4 = r.append(&mut store, op("/e"), t(3.0)).unwrap();
        assert!(s4 > s3);
    }

    #[test]
    fn recovery_skips_corrupt_entries() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        q.append(&mut store, op("/a"), t(1.0)).unwrap();
        // corrupt one persisted entry + an unparseable name
        store.write(&entry_path(2), b"garbage", t(1.5)).unwrap();
        store.write(&format!("{QUEUE_DIR}/not-a-seq"), b"junk", t(1.5)).unwrap();
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(r.len(), 1);
        assert_eq!(corrupt, 2);
    }

    #[test]
    fn replace_preserves_seq() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let full = MetaOp::WriteFull { path: "/a".into(), data: vec![9; 100], digests: vec![] };
        assert!(q.replace(&mut store, s, full.clone(), t(2.0)).unwrap());
        assert_eq!(q.pending()[0], (s, full.clone()));
        // persisted encoding updated too
        let (r, _) = MetaQueue::recover(&store);
        assert_eq!(r.pending()[0].1, full);
        assert!(!q.replace(&mut store, 999, op("/x"), t(3.0)).unwrap());
    }

    #[test]
    fn large_writefull_spills_by_reference() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        // the close path writes the content to the cache store first...
        let content = vec![0xCDu8; SPILL_THRESHOLD * 2];
        store.write("/big.bin", &content, t(0.5)).unwrap();
        let used_before = store.used_bytes();
        // ...then enqueues the full write
        let op_big = MetaOp::WriteFull { path: "/big.bin".into(), data: content.clone(), digests: vec![7, 8] };
        let seq = q.append(&mut store, op_big.clone(), t(1.0)).unwrap();
        // the persisted entry is tiny (by-reference), not another 512 KiB
        let entry = store.read(&entry_path(seq)).unwrap();
        assert!(entry.len() < 256, "spilled entry is {} bytes", entry.len());
        assert!(store.used_bytes() < used_before + 1024);
        // crash + recovery rebuilds the full op from the cache copy
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        assert_eq!(r.pending()[0].1, op_big);
    }

    #[test]
    fn spilled_entry_recovers_latest_cache_content() {
        // a second close before the flush updates the cache copy; recovery
        // must ship the LATEST content (last-close-wins)
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let v1 = vec![1u8; SPILL_THRESHOLD];
        store.write("/f", &v1, t(0.5)).unwrap();
        q.append(&mut store, MetaOp::WriteFull { path: "/f".into(), data: v1, digests: vec![] }, t(1.0))
            .unwrap();
        let v2 = vec![2u8; SPILL_THRESHOLD];
        store.write("/f", &v2, t(2.0)).unwrap();
        let (r, _) = MetaQueue::recover(&store);
        match &r.pending()[0].1 {
            MetaOp::WriteFull { data, .. } => assert_eq!(data, &v2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn take_front_push_front_roundtrip() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        q.append(&mut store, op("/b"), t(1.0)).unwrap();
        let (seq, o) = q.take_front().unwrap();
        assert_eq!(seq, s1);
        assert_eq!(q.len(), 1);
        q.push_front(seq, o);
        assert_eq!(q.pending()[0].0, s1);
        assert_eq!(q.len(), 2);
        assert!(MetaQueue::new().take_front().is_none());
    }

    #[test]
    fn take_all_push_front_all_roundtrip() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let s2 = q.append(&mut store, op("/b"), t(1.0)).unwrap();
        let batch = q.take_all();
        assert_eq!(batch.len(), 2);
        assert!(q.is_empty());
        // disk entries survive the take (crash-safety until ack)
        assert!(store.exists(&entry_path(s1)));
        // append while a batch is in flight, then restore: order holds
        let s3 = q.append(&mut store, op("/c"), t(2.0)).unwrap();
        q.push_front_all(batch);
        let seqs: Vec<u64> = q.pending().iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![s1, s2, s3]);
    }

    #[test]
    fn empty_recovery() {
        let store = FileStore::default();
        let (q, corrupt) = MetaQueue::recover(&store);
        assert!(q.is_empty());
        assert_eq!(corrupt, 0);
    }
}
