//! Durable write-behind op log (paper §3.1, hardened in DESIGN.md §2.5).
//!
//! "System calls that modify a file (or directory) in a XUFS partition
//! return when the local cache copy is updated, and the operation is
//! appended to a persisted meta-operation queue. No file (or directory)
//! operation blocks on a remote network call."
//!
//! The queue is persisted as a single **append-only log** in the cache
//! space (`/.xufs/oplog`). Every mutation appends one HMAC-SHA256-framed
//! record (reusing [`crate::util::hmacsha`]) and is written through to
//! the cache-space FS before the call returns — the model of an
//! `O_APPEND` write followed by `fdatasync`. Three record kinds exist:
//!
//! ```text
//! record := kind:u8 | seq:u64le | len:u32le | payload | hmac:[u8;32]
//! kind   := 0 op-append   payload = encoded MetaOp (inline or by-ref)
//!           1 ack         payload = empty (server acknowledged seq)
//!           2 watermark   payload = empty (seq floor after compaction)
//! hmac   := HMAC-SHA256("xufs-oplog-v1", kind || seq || payload)
//! ```
//!
//! Crash-recovery scans the log front to back, verifying each frame's
//! HMAC; the first bad frame truncates the trusted prefix (a torn tail is
//! the expected artifact of dying mid-append — everything after it is
//! unordered garbage). Pending ops = appends minus acks, replayed in seq
//! order; per-client sequence numbers make server-side application
//! idempotent, so replaying after a lost reply is safe. Acked records are
//! garbage-collected by compaction, which rewrites the log as a watermark
//! record (so recovered sequence numbers never regress and collide with
//! the server's idempotence watermark) plus the still-unacked ops.
//!
//! Large `WriteFull` payloads are persisted BY REFERENCE: the aggregated
//! content already lives in the cache store at the op's path (the close
//! wrote it there before enqueueing), so the record only carries
//! path+digests and recovery rebuilds the write from the surviving cache
//! copy. Recovery after further local closes still yields the correct
//! final home state — last-close-wins means the *latest* cache content
//! is what must land.

use std::collections::BTreeMap;

use crate::homefs::{FileStore, FsResult};
use crate::proto::{Decoder, Encoder, MetaOp};
use crate::simnet::VirtualTime;
use crate::util::hmacsha;

/// The append-only op log inside the cache space.
pub const OPLOG_PATH: &str = "/.xufs/oplog";

/// Directory holding the log (kept for tooling that lists `/.xufs`).
pub const OPLOG_DIR: &str = "/.xufs";

/// WriteFull payloads at or above this size are persisted by reference
/// (see module docs).
pub const SPILL_THRESHOLD: usize = 256 * 1024;

/// Acks between compactions. Compaction also fires whenever the last
/// unacked record is retired (the log collapses to one watermark record).
pub const COMPACT_EVERY_ACKS: usize = 64;

const LOG_HMAC_KEY: &[u8] = b"xufs-oplog-v1";
const REC_OP: u8 = 0;
const REC_ACK: u8 = 1;
const REC_MARK: u8 = 2;
const REC_HDR: usize = 1 + 8 + 4;
const REC_MAC: usize = 32;

fn persist_bytes(op: &MetaOp) -> Vec<u8> {
    let mut e = Encoder::new();
    match op {
        MetaOp::WriteFull { path, data, digests, base_version } if data.len() >= SPILL_THRESHOLD => {
            e.u8(1); // by-reference entry
            e.str(path);
            e.i32_slice(digests);
            e.u64(*base_version);
        }
        _ => {
            e.u8(0); // inline entry
            op.encode_into(&mut e);
        }
    }
    e.into_bytes()
}

fn recover_entry(store: &FileStore, bytes: &[u8]) -> Option<MetaOp> {
    let mut d = Decoder::new(bytes);
    match d.u8().ok()? {
        0 => {
            let op = MetaOp::decode_from(&mut d).ok()?;
            d.expect_end().ok()?;
            Some(op)
        }
        1 => {
            let path = d.str().ok()?;
            let digests = d.i32_vec().ok()?;
            let base_version = d.u64().ok()?;
            d.expect_end().ok()?;
            let data = store.read(&path).ok()?.to_vec();
            Some(MetaOp::WriteFull { path, data, digests, base_version })
        }
        _ => None,
    }
}

fn frame_record(kind: u8, seq: u64, payload: &[u8]) -> Vec<u8> {
    let mut rec = Vec::with_capacity(REC_HDR + payload.len() + REC_MAC);
    rec.push(kind);
    rec.extend_from_slice(&seq.to_le_bytes());
    rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    rec.extend_from_slice(payload);
    let mac = hmacsha::hmac_sha256(LOG_HMAC_KEY, &[&[kind], &seq.to_le_bytes(), payload]);
    rec.extend_from_slice(&mac);
    rec
}

/// The durable queue. Holds an in-memory view; every mutation appends to
/// the backing log before returning.
#[derive(Debug)]
pub struct MetaQueue {
    pending: Vec<(u64, MetaOp)>,
    /// Encoded payload of every persisted-but-unacked op record, by seq.
    /// This is the compaction source — it still covers ops that were
    /// `take_*`n for shipping and not yet acked, so compacting mid-flush
    /// can never drop an unacknowledged record from the log.
    logged: BTreeMap<u64, Vec<u8>>,
    next_seq: u64,
    /// Byte offset appends go to (the trusted end of the log; a torn
    /// tail past it is overwritten by the next append and re-truncated
    /// by the next recovery — stale bytes cannot verify as frames).
    log_end: u64,
    acked_since_compact: usize,
}

impl MetaQueue {
    pub fn new() -> Self {
        MetaQueue {
            pending: Vec::new(),
            logged: BTreeMap::new(),
            next_seq: 1,
            log_end: 0,
            acked_since_compact: 0,
        }
    }

    fn append_record(
        &mut self,
        store: &mut FileStore,
        kind: u8,
        seq: u64,
        payload: &[u8],
        now: VirtualTime,
    ) -> FsResult<()> {
        if !store.exists(OPLOG_PATH) {
            store.mkdir_p(OPLOG_DIR, now)?;
            store.write(OPLOG_PATH, &[], now)?;
            self.log_end = 0;
        } else {
            // bytes past the trusted end — a torn tail from a previous
            // crash, or a foreign log under a fresh queue — are dropped
            // before appending, so they can neither interleave behind new
            // frames nor resurface as phantom corrupt records on the next
            // recovery
            let len = store.stat(OPLOG_PATH).map(|a| a.size).unwrap_or(0);
            if len > self.log_end {
                store.truncate(OPLOG_PATH, self.log_end, now)?;
            }
        }
        let rec = frame_record(kind, seq, payload);
        // write-through append (the model's O_APPEND + fdatasync)
        store.write_at(OPLOG_PATH, self.log_end, &rec, now)?;
        self.log_end += rec.len() as u64;
        Ok(())
    }

    /// Rewrite the log as watermark + still-unacked ops, dropping acked
    /// history. The watermark pins `next_seq` across crashes so replayed
    /// and new ops can never collide on the server's idempotence
    /// watermark.
    fn compact(&mut self, store: &mut FileStore, now: VirtualTime) -> FsResult<()> {
        let mut log = frame_record(REC_MARK, self.next_seq.saturating_sub(1), &[]);
        for (seq, payload) in &self.logged {
            log.extend_from_slice(&frame_record(REC_OP, *seq, payload));
        }
        store.mkdir_p(OPLOG_DIR, now)?;
        store.write(OPLOG_PATH, &log, now)?;
        self.log_end = log.len() as u64;
        self.acked_since_compact = 0;
        Ok(())
    }

    /// Append an op: persists to the log then records it in memory.
    /// Returns the assigned sequence number.
    pub fn append(&mut self, store: &mut FileStore, op: MetaOp, now: VirtualTime) -> FsResult<u64> {
        let seq = self.next_seq;
        self.next_seq += 1;
        let payload = persist_bytes(&op);
        self.append_record(store, REC_OP, seq, &payload, now)?;
        self.logged.insert(seq, payload);
        self.pending.push((seq, op));
        Ok(seq)
    }

    /// Ops awaiting replay, in order.
    pub fn pending(&self) -> &[(u64, MetaOp)] {
        &self.pending
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Total WAN payload of the pending ops.
    pub fn pending_bytes(&self) -> u64 {
        self.pending.iter().map(|(_, op)| op.wire_bytes()).sum()
    }

    /// Remove the front op for shipping (its log record stays until
    /// `ack`; on failure `push_front` restores it). Avoids cloning large
    /// payloads on the flush path.
    pub fn take_front(&mut self) -> Option<(u64, MetaOp)> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.pending.remove(0))
        }
    }

    /// Put an unshipped op back at the front (disconnection mid-flush).
    pub fn push_front(&mut self, seq: u64, op: MetaOp) {
        self.pending.insert(0, (seq, op));
    }

    /// Move out EVERY pending op for a compound flush (one WAN round trip
    /// for the whole queue). Log records stay until `ack`; on failure
    /// [`Self::push_front_all`] restores the batch.
    pub fn take_all(&mut self) -> Vec<(u64, MetaOp)> {
        std::mem::take(&mut self.pending)
    }

    /// Restore a batch of unshipped ops (in order) at the queue front
    /// (disconnection mid-compound).
    pub fn push_front_all(&mut self, mut ops: Vec<(u64, MetaOp)>) {
        ops.append(&mut self.pending);
        self.pending = ops;
    }

    /// Server acknowledged `seq`: append the ack record, drop the op from
    /// memory, and compact when the log has accumulated enough retired
    /// history (or emptied entirely).
    pub fn ack(&mut self, store: &mut FileStore, seq: u64, now: VirtualTime) -> FsResult<()> {
        self.pending.retain(|(s, _)| *s != seq);
        if self.logged.remove(&seq).is_none() {
            // re-ack of an already-retired seq: nothing to record
            return Ok(());
        }
        self.acked_since_compact += 1;
        if self.logged.is_empty() || self.acked_since_compact >= COMPACT_EVERY_ACKS {
            // compaction's watermark + unacked-ops rewrite encodes this
            // ack implicitly — appending the ack frame first would be a
            // wasted synchronous log write
            self.compact(store, now)
        } else {
            self.append_record(store, REC_ACK, seq, &[], now)
        }
    }

    /// Replace a pending op in place (e.g. delta flush demoted to a full
    /// flush after the server reported a stale base). Keeps the same seq
    /// ordering; appends the superseding record (recovery keeps the last
    /// record per seq).
    pub fn replace(
        &mut self,
        store: &mut FileStore,
        seq: u64,
        op: MetaOp,
        now: VirtualTime,
    ) -> FsResult<bool> {
        let Some(idx) = self.pending.iter().position(|(s, _)| *s == seq) else {
            return Ok(false);
        };
        let payload = persist_bytes(&op);
        self.append_record(store, REC_OP, seq, &payload, now)?;
        self.logged.insert(seq, payload);
        self.pending[idx].1 = op;
        Ok(true)
    }

    /// Rebuild the queue from the persisted log after a client crash.
    /// Scans front to back verifying each frame's HMAC; the first bad
    /// frame ends the trusted prefix (torn-tail truncation, counted as
    /// one corrupt record). Frame-valid records whose payload no longer
    /// decodes (e.g. a by-reference target unlinked before the crash)
    /// are skipped and counted, matching the recovery tool's best-effort
    /// semantics.
    pub fn recover(store: &FileStore) -> (Self, usize) {
        let mut raw_ops: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        let mut corrupt = 0usize;
        let mut max_seq = 0u64;
        let mut end = 0u64;
        if let Ok(buf) = store.read(OPLOG_PATH) {
            let mut at = 0usize;
            while at < buf.len() {
                if buf.len() - at < REC_HDR + REC_MAC {
                    corrupt += 1; // torn header
                    break;
                }
                let kind = buf[at];
                let mut seq_bytes = [0u8; 8];
                seq_bytes.copy_from_slice(&buf[at + 1..at + 9]);
                let seq = u64::from_le_bytes(seq_bytes);
                let mut len_bytes = [0u8; 4];
                len_bytes.copy_from_slice(&buf[at + 9..at + 13]);
                let len = u32::from_le_bytes(len_bytes) as usize;
                let Some(frame_end) = at
                    .checked_add(REC_HDR)
                    .and_then(|x| x.checked_add(len))
                    .and_then(|x| x.checked_add(REC_MAC))
                else {
                    corrupt += 1;
                    break;
                };
                if frame_end > buf.len() {
                    corrupt += 1; // torn payload
                    break;
                }
                let payload = &buf[at + REC_HDR..at + REC_HDR + len];
                let mac = &buf[at + REC_HDR + len..frame_end];
                let want =
                    hmacsha::hmac_sha256(LOG_HMAC_KEY, &[&[kind], &seq.to_le_bytes(), payload]);
                if !hmacsha::ct_eq(mac, &want) {
                    corrupt += 1; // tampered or torn frame: distrust the rest
                    break;
                }
                match kind {
                    REC_OP => {
                        raw_ops.insert(seq, payload.to_vec());
                    }
                    REC_ACK => {
                        raw_ops.remove(&seq);
                    }
                    REC_MARK => {}
                    _ => {
                        corrupt += 1; // unknown kind: distrust the rest
                        break;
                    }
                }
                max_seq = max_seq.max(seq);
                at = frame_end;
                end = at as u64;
            }
        }
        let mut pending = Vec::new();
        let mut logged = BTreeMap::new();
        for (seq, payload) in raw_ops {
            match recover_entry(store, &payload) {
                Some(op) => {
                    pending.push((seq, op));
                    logged.insert(seq, payload);
                }
                None => corrupt += 1,
            }
        }
        (
            MetaQueue {
                pending,
                logged,
                // next_seq continues after everything ever persisted, so
                // replayed and new ops can't collide
                next_seq: max_seq + 1,
                log_end: end,
                acked_since_compact: 0,
            },
            corrupt,
        )
    }
}

impl Default for MetaQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::homefs::FileStore;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    fn op(path: &str) -> MetaOp {
        MetaOp::WriteFull { path: path.into(), data: b"x".to_vec(), digests: vec![1], base_version: 0 }
    }

    fn log_len(store: &FileStore) -> usize {
        store.read(OPLOG_PATH).map(|b| b.len()).unwrap_or(0)
    }

    #[test]
    fn append_assigns_monotonic_seqs_and_persists() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let len1 = log_len(&store);
        let s2 = q.append(&mut store, MetaOp::Unlink { path: "/b".into() }, t(2.0)).unwrap();
        assert!(s2 > s1);
        assert_eq!(q.len(), 2);
        assert!(store.exists(OPLOG_PATH));
        assert!(log_len(&store) > len1, "every append grows the log");
        assert!(q.pending_bytes() > 0);
    }

    #[test]
    fn ack_retires_and_empty_log_compacts_keeping_watermark() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let s2 = q.append(&mut store, op("/b"), t(1.0)).unwrap();
        q.ack(&mut store, s1, t(2.0)).unwrap();
        assert_eq!(q.len(), 1);
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        assert_eq!(r.len(), 1);
        assert_eq!(r.pending()[0].0, s2);
        // acking the last op compacts the log down to the watermark...
        q.ack(&mut store, s2, t(3.0)).unwrap();
        let compacted = log_len(&store);
        assert!(compacted < 120, "compacted log is one watermark record ({compacted} bytes)");
        // ...which pins the sequence floor across a crash
        let (mut r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        assert!(r.is_empty());
        let s3 = r.append(&mut store, op("/c"), t(4.0)).unwrap();
        assert!(s3 > s2, "recovered seqs must not regress past acked history");
    }

    #[test]
    fn recovery_restores_order_and_continues_seqs() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        q.append(&mut store, op("/b"), t(1.0)).unwrap();
        let s3 = q.append(&mut store, MetaOp::Mkdir { path: "/d".into() }, t(1.0)).unwrap();
        q.ack(&mut store, s1, t(2.0)).unwrap();

        // crash: drop q, recover from store
        let (mut r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        assert_eq!(r.len(), 2);
        assert_eq!(r.pending()[0].1.path(), "/b");
        assert_eq!(r.pending()[1].1, MetaOp::Mkdir { path: "/d".into() });
        // new appends continue past the recovered max
        let s4 = r.append(&mut store, op("/e"), t(3.0)).unwrap();
        assert!(s4 > s3);
    }

    #[test]
    fn torn_tail_is_truncated_not_fatal() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        q.append(&mut store, op("/a"), t(1.0)).unwrap();
        q.append(&mut store, op("/b"), t(1.0)).unwrap();
        // crash mid-append: a partial third record at the tail
        let end = log_len(&store) as u64;
        store.write_at(OPLOG_PATH, end, &[REC_OP, 3, 0, 0], t(1.5)).unwrap();
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 1, "torn tail counts once");
        assert_eq!(r.len(), 2, "records before the tear survive");
    }

    #[test]
    fn append_after_torn_recovery_trims_the_residue() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        q.append(&mut store, op("/a"), t(1.0)).unwrap();
        // crash leaves a LONG torn tail (bigger than the next record)
        let end = log_len(&store) as u64;
        store.write_at(OPLOG_PATH, end, &vec![0xEE; 500], t(1.5)).unwrap();
        let (mut r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 1);
        // the next append must not leave residue behind the new record:
        // a second recovery sees a clean log, not phantom corruption
        r.append(&mut store, op("/b"), t(2.0)).unwrap();
        let (r2, corrupt2) = MetaQueue::recover(&store);
        assert_eq!(corrupt2, 0, "torn residue must not resurface");
        assert_eq!(r2.len(), 2);
    }

    #[test]
    fn tampered_record_distrust_suffix() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let flip_at = log_len(&store) as u64 - 1; // inside record 1's MAC
        q.append(&mut store, op("/b"), t(1.0)).unwrap();
        let byte = store.read(OPLOG_PATH).unwrap()[flip_at as usize] ^ 0xFF;
        store.write_at(OPLOG_PATH, flip_at, &[byte], t(1.5)).unwrap();
        let (r, corrupt) = MetaQueue::recover(&store);
        assert!(corrupt >= 1);
        assert_eq!(r.len(), 0, "everything at or after the bad frame is untrusted");
    }

    #[test]
    fn replace_preserves_seq() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let full =
            MetaOp::WriteFull { path: "/a".into(), data: vec![9; 100], digests: vec![], base_version: 0 };
        assert!(q.replace(&mut store, s, full.clone(), t(2.0)).unwrap());
        assert_eq!(q.pending()[0], (s, full.clone()));
        // the superseding record wins on recovery too
        let (r, _) = MetaQueue::recover(&store);
        assert_eq!(r.pending()[0].1, full);
        assert!(!q.replace(&mut store, 999, op("/x"), t(3.0)).unwrap());
    }

    #[test]
    fn large_writefull_spills_by_reference() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        // the close path writes the content to the cache store first...
        let content = vec![0xCDu8; SPILL_THRESHOLD * 2];
        store.write("/big.bin", &content, t(0.5)).unwrap();
        let used_before = store.used_bytes();
        // ...then enqueues the full write
        let op_big = MetaOp::WriteFull {
            path: "/big.bin".into(),
            data: content.clone(),
            digests: vec![7, 8],
            base_version: 3,
        };
        q.append(&mut store, op_big.clone(), t(1.0)).unwrap();
        // the persisted record is tiny (by-reference), not another 512 KiB
        assert!(log_len(&store) < 256, "spilled record is {} bytes", log_len(&store));
        assert!(store.used_bytes() < used_before + 1024);
        // crash + recovery rebuilds the full op from the cache copy
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        assert_eq!(r.pending()[0].1, op_big);
    }

    #[test]
    fn spilled_entry_recovers_latest_cache_content() {
        // a second close before the flush updates the cache copy; recovery
        // must ship the LATEST content (last-close-wins)
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let v1 = vec![1u8; SPILL_THRESHOLD];
        store.write("/f", &v1, t(0.5)).unwrap();
        q.append(
            &mut store,
            MetaOp::WriteFull { path: "/f".into(), data: v1, digests: vec![], base_version: 0 },
            t(1.0),
        )
        .unwrap();
        let v2 = vec![2u8; SPILL_THRESHOLD];
        store.write("/f", &v2, t(2.0)).unwrap();
        let (r, _) = MetaQueue::recover(&store);
        match &r.pending()[0].1 {
            MetaOp::WriteFull { data, .. } => assert_eq!(data, &v2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn spilled_ghost_target_is_skipped_not_fatal() {
        // by-reference record whose cache copy was unlinked before the
        // crash: that one op is lost (counted), the rest replays
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let big = vec![3u8; SPILL_THRESHOLD];
        store.write("/gone", &big, t(0.5)).unwrap();
        q.append(
            &mut store,
            MetaOp::WriteFull { path: "/gone".into(), data: big, digests: vec![], base_version: 0 },
            t(1.0),
        )
        .unwrap();
        q.append(&mut store, op("/kept"), t(1.0)).unwrap();
        store.unlink("/gone", t(2.0)).unwrap();
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 1);
        assert_eq!(r.len(), 1);
        assert_eq!(r.pending()[0].1.path(), "/kept");
    }

    #[test]
    fn take_front_push_front_roundtrip() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        q.append(&mut store, op("/b"), t(1.0)).unwrap();
        let (seq, o) = q.take_front().unwrap();
        assert_eq!(seq, s1);
        assert_eq!(q.len(), 1);
        q.push_front(seq, o);
        assert_eq!(q.pending()[0].0, s1);
        assert_eq!(q.len(), 2);
        assert!(MetaQueue::new().take_front().is_none());
    }

    #[test]
    fn in_flight_batch_survives_crash_until_acked() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let s1 = q.append(&mut store, op("/a"), t(1.0)).unwrap();
        let s2 = q.append(&mut store, op("/b"), t(1.0)).unwrap();
        let batch = q.take_all();
        assert_eq!(batch.len(), 2);
        assert!(q.is_empty());
        // crash while the batch is in flight: the log still carries both
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        assert_eq!(r.len(), 2);
        // append while a batch is in flight, then restore: order holds
        let s3 = q.append(&mut store, op("/c"), t(2.0)).unwrap();
        q.push_front_all(batch);
        let seqs: Vec<u64> = q.pending().iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![s1, s2, s3]);
        // an ack mid-flight compacts without dropping the unacked records
        q.ack(&mut store, s1, t(3.0)).unwrap();
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        let seqs: Vec<u64> = r.pending().iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![s2, s3]);
    }

    #[test]
    fn compaction_drops_acked_history() {
        let mut store = FileStore::default();
        let mut q = MetaQueue::new();
        let mut seqs = Vec::new();
        for i in 0..(COMPACT_EVERY_ACKS + 4) {
            seqs.push(q.append(&mut store, op(&format!("/f{i}")), t(1.0)).unwrap());
        }
        let grown = log_len(&store);
        for &s in &seqs[..COMPACT_EVERY_ACKS] {
            q.ack(&mut store, s, t(2.0)).unwrap();
        }
        assert!(
            log_len(&store) < grown,
            "compaction shrank the log ({} -> {})",
            grown,
            log_len(&store)
        );
        let (r, corrupt) = MetaQueue::recover(&store);
        assert_eq!(corrupt, 0);
        assert_eq!(r.len(), 4);
    }

    #[test]
    fn empty_recovery() {
        let store = FileStore::default();
        let (q, corrupt) = MetaQueue::recover(&store);
        assert!(q.is_empty());
        assert_eq!(corrupt, 0);
    }
}
