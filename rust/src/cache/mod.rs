//! Client cache space (paper §3.1).
//!
//! When a remote name space is mounted, a private cache space is created on
//! the client host — at TeraGrid sites, on the parallel-FS work partition.
//! XUFS recreates remote directories entirely in cache space: placeholder
//! entries plus **hidden attribute files** holding each entry's attributes
//! (so `stat()` never touches the WAN), file content fetched whole on first
//! `open()`, writes aggregated in **shadow files** flushed on `close()`
//! (last-close-wins), and **localized directories** whose contents never
//! leave the client.
//!
//! The cache space is itself a [`FileStore`] (the on-disk layout the paper
//! describes), plus an in-memory index rebuilt from those hidden files
//! after a client crash — [`CacheSpace::recover`] is exactly that rebuild.
//!
//! Since the block-granular data plane (DESIGN.md §2.4) the content model
//! is no longer all-or-nothing: every entry carries a [`Residency`] map
//! recording which stripe blocks are cached and which are locally dirty.
//! The map is persisted in the hidden attribute files (one token char per
//! block) and rebuilt by recovery; a `cache.budget_bytes` budget evicts
//! least-recently-used clean blocks when resident content outgrows it.

use std::collections::{BTreeSet, HashMap};

use crate::homefs::{FileStore, FsError, FsResult, NodeKind};
use crate::metrics::{names, Metrics};
use crate::proto::{BlockExtent, WireAttr};
use crate::simnet::VirtualTime;
use crate::util::path as vpath;
use crate::util::Json;

/// Consistency state of a cached entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryState {
    /// Content matches `version` at the home space (as far as callbacks
    /// have told us).
    Clean,
    /// Locally modified; flush queued in the meta-operation queue.
    Dirty,
    /// Callback invalidated it; must re-fetch before next open.
    Invalid,
    /// Attributes cached (from directory materialization) but content
    /// never fetched — the "initial empty file entry" of the paper.
    AttrOnly,
}

impl EntryState {
    fn as_str(self) -> &'static str {
        match self {
            EntryState::Clean => "clean",
            EntryState::Dirty => "dirty",
            EntryState::Invalid => "invalid",
            EntryState::AttrOnly => "attronly",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "clean" => EntryState::Clean,
            "dirty" => EntryState::Dirty,
            "invalid" => EntryState::Invalid,
            "attronly" => EntryState::AttrOnly,
            _ => return None,
        })
    }
}

/// Per-entry residency map: which stripe blocks of the entry are cached,
/// which are locally dirty, and when each was last touched (the LRU input
/// for budgeted block eviction). Dirty implies present.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Residency {
    present: Vec<bool>,
    dirty: Vec<bool>,
    stamp: Vec<VirtualTime>,
}

impl Residency {
    /// An all-absent map over `blocks` blocks.
    pub fn new(blocks: usize) -> Self {
        Residency {
            present: vec![false; blocks],
            dirty: vec![false; blocks],
            stamp: vec![VirtualTime::ZERO; blocks],
        }
    }

    /// A fully-present clean map (whole-file install).
    pub fn full(blocks: usize, now: VirtualTime) -> Self {
        Residency {
            present: vec![true; blocks],
            dirty: vec![false; blocks],
            stamp: vec![now; blocks],
        }
    }

    /// A fully-present, fully-dirty map (whole-file local modification).
    pub fn full_dirty(blocks: usize, now: VirtualTime) -> Self {
        Residency {
            present: vec![true; blocks],
            dirty: vec![true; blocks],
            stamp: vec![now; blocks],
        }
    }

    pub fn blocks(&self) -> usize {
        self.present.len()
    }

    pub fn present_blocks(&self) -> usize {
        self.present.iter().filter(|&&p| p).count()
    }

    pub fn dirty_blocks(&self) -> usize {
        self.dirty.iter().filter(|&&d| d).count()
    }

    pub fn is_present(&self, i: usize) -> bool {
        self.present.get(i).copied().unwrap_or(false)
    }

    pub fn is_dirty(&self, i: usize) -> bool {
        self.dirty.get(i).copied().unwrap_or(false)
    }

    pub fn stamp(&self, i: usize) -> VirtualTime {
        self.stamp.get(i).copied().unwrap_or(VirtualTime::ZERO)
    }

    /// Grow or shrink the map to `blocks` (new blocks start absent).
    pub fn resize(&mut self, blocks: usize) {
        self.present.resize(blocks, false);
        self.dirty.resize(blocks, false);
        self.stamp.resize(blocks, VirtualTime::ZERO);
    }

    /// Drop every block (capacity eviction / content reset).
    pub fn clear(&mut self) {
        self.present.fill(false);
        self.dirty.fill(false);
        self.stamp.fill(VirtualTime::ZERO);
    }

    pub fn mark_present(&mut self, i: usize, now: VirtualTime) {
        if i >= self.present.len() {
            self.resize(i + 1);
        }
        self.present[i] = true;
        self.stamp[i] = now;
    }

    pub fn mark_dirty(&mut self, i: usize, now: VirtualTime) {
        self.mark_present(i, now);
        self.dirty[i] = true;
    }

    /// Flush acknowledged: every dirty block is now clean at home.
    pub fn clear_dirty(&mut self) {
        self.dirty.fill(false);
    }

    /// Evict one block (caller guarantees it is clean).
    pub fn evict(&mut self, i: usize) {
        if i < self.present.len() {
            self.present[i] = false;
            self.stamp[i] = VirtualTime::ZERO;
        }
    }

    /// Refresh the LRU stamps of blocks `[first, last)`.
    pub fn touch_range(&mut self, first: usize, last: usize, now: VirtualTime) {
        for i in first..last.min(self.stamp.len()) {
            self.stamp[i] = now;
        }
    }

    /// Contiguous runs of absent blocks inside `[first, last)`, as
    /// `(start_block, count)` pairs — the extents a paged read must fault.
    pub fn missing_extents(&self, first: u64, last: u64) -> Vec<(u64, u64)> {
        let mut out: Vec<(u64, u64)> = Vec::new();
        for i in first..last {
            if self.is_present(i as usize) {
                continue;
            }
            match out.last_mut() {
                Some((start, count)) if *start + *count == i => *count += 1,
                _ => out.push((i, 1)),
            }
        }
        out
    }

    /// Bytes a present block `i` occupies, given the entry size.
    pub fn block_len(i: usize, size: u64, block_bytes: u64) -> u64 {
        size.saturating_sub(i as u64 * block_bytes).min(block_bytes)
    }

    /// Total bytes of resident content.
    pub fn resident_bytes(&self, size: u64, block_bytes: u64) -> u64 {
        self.present
            .iter()
            .enumerate()
            .filter(|(_, &p)| p)
            .map(|(i, _)| Self::block_len(i, size, block_bytes))
            .sum()
    }

    /// Persisted token: one char per block — `.` absent, `c` clean, `d`
    /// dirty.
    pub fn encode(&self) -> String {
        (0..self.blocks())
            .map(|i| {
                if self.is_dirty(i) {
                    'd'
                } else if self.is_present(i) {
                    'c'
                } else {
                    '.'
                }
            })
            .collect()
    }

    /// Parse a persisted token; `None` on any unknown char (the caller
    /// demotes the entry rather than trusting a corrupt map).
    pub fn parse(token: &str) -> Option<Residency> {
        let mut r = Residency::new(token.len());
        for (i, ch) in token.chars().enumerate() {
            match ch {
                '.' => {}
                'c' => r.present[i] = true,
                'd' => {
                    r.present[i] = true;
                    r.dirty[i] = true;
                }
                _ => return None,
            }
        }
        Some(r)
    }
}

/// Index record for one cached home-space path.
#[derive(Debug, Clone)]
pub struct CacheEntry {
    pub state: EntryState,
    /// Home-space version the cached content corresponds to.
    pub version: u64,
    /// Per-block digests of the cached content (delta-writeback base).
    pub digests: Vec<i32>,
    /// Cached attributes (size/kind/mtime as of `version`).
    pub attr: WireAttr,
    /// Last access (LRU eviction).
    pub last_used: VirtualTime,
    /// Which blocks of the content are cached / dirty (DESIGN.md §2.4).
    pub residency: Residency,
}

/// A directory whose entries have been materialized.
#[derive(Debug, Clone, Default)]
pub struct DirState {
    pub complete: bool,
    pub prefetched: bool,
}

/// The cache space: on-disk layout + index.
#[derive(Debug)]
pub struct CacheSpace {
    /// Cache contents, keyed by *home-space path* (1:1 layout).
    fs: FileStore,
    entries: HashMap<String, CacheEntry>,
    dirs: HashMap<String, DirState>,
    localized: Vec<String>,
    capacity: u64,
    /// Stripe-block size the residency maps are gridded on.
    block_bytes: u64,
    /// Resident-content budget for LRU block eviction (0 = unbudgeted).
    budget: u64,
}

impl CacheSpace {
    pub fn new(capacity: u64, localized: Vec<String>) -> Self {
        CacheSpace {
            fs: FileStore::default(),
            entries: HashMap::new(),
            dirs: HashMap::new(),
            localized: localized.into_iter().map(|d| vpath::normalize(&d)).collect(),
            capacity,
            block_bytes: crate::config::STRIPE_BLOCK,
            budget: 0,
        }
    }

    /// Configure the paged data plane: the residency block size and the
    /// resident-content budget (`cache.budget_bytes`; 0 = unbudgeted).
    pub fn set_paging(&mut self, block_bytes: u64, budget_bytes: u64) {
        self.block_bytes = block_bytes.max(1);
        self.budget = budget_bytes;
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    /// Blocks a file of `size` bytes spans on the residency grid.
    pub fn blocks_for(&self, size: u64) -> usize {
        size.div_ceil(self.block_bytes.max(1)) as usize
    }

    /// Total bytes of resident cached content across all entries.
    pub fn resident_bytes(&self) -> u64 {
        let bb = self.block_bytes.max(1);
        self.entries.values().map(|e| e.residency.resident_bytes(e.attr.size, bb)).sum()
    }

    /// Is `path` inside a localized directory (content never shipped home)?
    pub fn is_localized(&self, path: &str) -> bool {
        self.localized.iter().any(|d| vpath::is_under(path, d))
    }

    pub fn localized_dirs(&self) -> &[String] {
        &self.localized
    }

    pub fn store(&self) -> &FileStore {
        &self.fs
    }

    pub fn store_mut(&mut self) -> &mut FileStore {
        &mut self.fs
    }

    pub fn entry(&self, path: &str) -> Option<&CacheEntry> {
        self.entries.get(&vpath::normalize(path))
    }

    pub fn entry_mut(&mut self, path: &str) -> Option<&mut CacheEntry> {
        self.entries.get_mut(&vpath::normalize(path))
    }

    pub fn dir_state(&self, path: &str) -> Option<&DirState> {
        self.dirs.get(&vpath::normalize(path))
    }

    pub fn set_dir_prefetched(&mut self, path: &str) {
        self.dirs.entry(vpath::normalize(path)).or_default().prefetched = true;
    }

    pub fn used_bytes(&self) -> u64 {
        self.fs.used_bytes()
    }

    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Record a materialized directory: create the directory in cache
    /// space, placeholder entries and hidden attribute files.
    pub fn materialize_dir(
        &mut self,
        dir: &str,
        entries: &[(String, WireAttr)],
        now: VirtualTime,
    ) -> FsResult<()> {
        let dir_n = vpath::normalize(dir);
        self.fs.mkdir_p(&dir_n, now)?;
        for (name, attr) in entries {
            let p = vpath::join(&dir_n, name);
            match attr.kind {
                NodeKind::Dir => {
                    self.fs.mkdir_p(&p, now)?;
                }
                NodeKind::File => {
                    if !self.fs.exists(&p) {
                        self.fs.create(&p, now)?;
                    }
                }
            }
            let (state, version, digests, residency) = match self.entries.get(&p) {
                // don't clobber content we already hold
                Some(e) if e.state != EntryState::AttrOnly => {
                    (e.state, e.version, e.digests.clone(), e.residency.clone())
                }
                _ => {
                    let residency = Residency::new(self.blocks_for(attr.size));
                    (EntryState::AttrOnly, attr.version, Vec::new(), residency)
                }
            };
            self.entries.insert(
                p.clone(),
                CacheEntry {
                    state,
                    version,
                    digests,
                    attr: attr.clone(),
                    last_used: now,
                    residency,
                },
            );
            self.sync_attr_file(&p, now)?;
        }
        self.dirs.entry(dir_n).or_default().complete = true;
        Ok(())
    }

    /// Paper §3.1: attributes live in hidden files alongside the entries.
    /// Kept in sync on every state change so crash recovery sees the truth.
    fn sync_attr_file(&mut self, path: &str, now: VirtualTime) -> FsResult<()> {
        let p = vpath::normalize(path);
        let Some(e) = self.entries.get(&p) else { return Ok(()) };
        let json = Json::obj()
            .set("kind", if e.attr.kind == NodeKind::Dir { "dir" } else { "file" })
            .set("size", e.attr.size)
            .set("mtime_ns", e.attr.mtime_ns)
            .set("mode", e.attr.mode as u64)
            .set("version", e.version)
            .set("state", e.state.as_str())
            .set("residency", e.residency.encode())
            .set("digests", Json::Arr(e.digests.iter().map(|&d| Json::Num(d as f64)).collect()));
        let dir = vpath::parent(&p);
        let name = vpath::basename(&p);
        let apath = vpath::join(&dir, &vpath::attr_file_name(&name));
        self.fs.mkdir_p(&dir, now)?;
        self.fs.write(&apath, json.to_string().as_bytes(), now)
    }

    /// Install fetched content as a clean cached copy.
    pub fn install(
        &mut self,
        path: &str,
        data: &[u8],
        version: u64,
        digests: Vec<i32>,
        attr: WireAttr,
        now: VirtualTime,
    ) -> FsResult<()> {
        let p = vpath::normalize(path);
        self.fs.mkdir_p(&vpath::parent(&p), now)?;
        self.fs.write(&p, data, now)?;
        let residency = Residency::full(self.blocks_for(data.len() as u64), now);
        self.entries.insert(
            p.clone(),
            CacheEntry {
                state: EntryState::Clean,
                version,
                digests,
                attr,
                last_used: now,
                residency,
            },
        );
        self.sync_attr_file(&p, now)?;
        self.maybe_evict(&p, now);
        Ok(())
    }

    /// Prepare an entry for paged access at the authoritative `version`
    /// (from a `FetchMeta`): keep resident blocks when the version still
    /// matches (revalidation after a suspected-stale period), otherwise
    /// reset the residency map — the cached blocks are stale and every
    /// read faults fresh ones.
    pub fn begin_paged(
        &mut self,
        path: &str,
        version: u64,
        size: u64,
        digests: Vec<i32>,
        now: VirtualTime,
    ) -> FsResult<()> {
        let p = vpath::normalize(path);
        self.fs.mkdir_p(&vpath::parent(&p), now)?;
        if !self.fs.exists(&p) {
            self.fs.create(&p, now)?;
        }
        let nblocks = self.blocks_for(size);
        let reusable = self
            .entries
            .get(&p)
            .map(|e| e.version == version && e.residency.blocks() == nblocks)
            .unwrap_or(false);
        if reusable {
            let e = self.entries.get_mut(&p).unwrap();
            if e.state != EntryState::Dirty {
                e.state = EntryState::Clean;
            }
            e.digests = digests;
            e.attr.size = size;
            e.attr.version = version;
            e.last_used = now;
        } else {
            let old = self.entries.remove(&p);
            // judged on the residency map, not the state token, so dirty
            // blocks survive even if a refresh path mislabelled the entry
            let keeps_dirty =
                old.as_ref().map(|e| e.residency.dirty_blocks() > 0).unwrap_or(false);
            let (state, attr, residency) = if keeps_dirty {
                // the home version moved under local edits: clean blocks
                // are stale and dropped, dirty blocks survive — last-
                // close-wins means the queued flush overwrites the home
                // copy with them anyway
                let e = old.unwrap();
                let mut a = e.attr;
                a.size = a.size.max(size);
                a.version = version;
                let mut r = Residency::new(self.blocks_for(a.size));
                for i in 0..e.residency.blocks() {
                    if e.residency.is_dirty(i) {
                        r.mark_dirty(i, now);
                    }
                }
                (EntryState::Dirty, a, r)
            } else {
                // stale bytes must not leak into the new block grid
                self.fs.truncate(&p, 0, now)?;
                let attr = match old {
                    Some(e) => {
                        let mut a = e.attr;
                        a.size = size;
                        a.version = version;
                        a
                    }
                    None => {
                        WireAttr { kind: NodeKind::File, size, mtime_ns: now.0, mode: 0o600, version }
                    }
                };
                (EntryState::Clean, attr, Residency::new(nblocks))
            };
            self.entries.insert(
                p.clone(),
                CacheEntry { state, version, digests, attr, last_used: now, residency },
            );
        }
        self.sync_attr_file(&p, now)
    }

    /// Install faulted blocks (a range-fetch reply) into an existing
    /// paged entry: write the bytes at their block offsets and mark the
    /// blocks present.
    pub fn install_blocks(
        &mut self,
        path: &str,
        extents: &[BlockExtent],
        now: VirtualTime,
    ) -> FsResult<()> {
        let p = vpath::normalize(path);
        let bb = self.block_bytes.max(1);
        for x in extents {
            self.fs.write_at(&p, x.index as u64 * bb, &x.data, now)?;
        }
        let Some(e) = self.entries.get_mut(&p) else {
            return Err(FsError::NotFound(p));
        };
        for x in extents {
            e.residency.mark_present(x.index as usize, now);
        }
        e.last_used = now;
        self.sync_attr_file(&p, now)?;
        // same capacity pressure valve as whole-file installs
        self.maybe_evict(&p, now);
        Ok(())
    }

    /// Record a block-granular local modification (paged close merge):
    /// the content is already in the cache store; `blocks` are the ones
    /// this close dirtied, `digests` the patched whole-file vector.
    pub fn mark_dirty_blocks(
        &mut self,
        path: &str,
        blocks: &[u64],
        digests: Vec<i32>,
        new_size: u64,
        now: VirtualTime,
    ) -> FsResult<()> {
        let p = vpath::normalize(path);
        let nblocks = self.blocks_for(new_size);
        let Some(e) = self.entries.get_mut(&p) else {
            return Err(FsError::NotFound(p));
        };
        e.state = EntryState::Dirty;
        e.digests = digests;
        e.attr.size = new_size;
        e.attr.mtime_ns = now.0;
        e.residency.resize(nblocks);
        for &b in blocks {
            e.residency.mark_dirty(b as usize, now);
        }
        e.last_used = now;
        self.sync_attr_file(&p, now)
    }

    /// Refresh per-block LRU stamps after a paged read of `[first, last)`.
    pub fn touch_blocks(&mut self, path: &str, first: u64, last: u64, now: VirtualTime) {
        if let Some(e) = self.entries.get_mut(&vpath::normalize(path)) {
            e.residency.touch_range(first as usize, last as usize, now);
            e.last_used = now;
        }
    }

    /// Re-register an entry's index record under a new path after its
    /// content followed a store rename — residency, digests and state
    /// survive the move (re-installing would mistake zero-filled
    /// non-resident holes for cached content).
    pub fn adopt(&mut self, path: &str, mut entry: CacheEntry, now: VirtualTime) -> FsResult<()> {
        let p = vpath::normalize(path);
        entry.last_used = now;
        self.entries.insert(p.clone(), entry);
        self.sync_attr_file(&p, now)
    }

    /// Record a local modification (shadow-file flush): content already
    /// written to the cache store by the caller.
    pub fn mark_dirty(&mut self, path: &str, digests: Vec<i32>, now: VirtualTime) -> FsResult<()> {
        let p = vpath::normalize(path);
        let attr = self.fs.stat(&p)?;
        let wire = WireAttr::from_attr(&attr);
        let version = self.entries.get(&p).map(|e| e.version).unwrap_or(0);
        let residency = Residency::full_dirty(self.blocks_for(wire.size), now);
        self.entries.insert(
            p.clone(),
            CacheEntry {
                state: EntryState::Dirty,
                version,
                digests,
                attr: wire,
                last_used: now,
                residency,
            },
        );
        self.sync_attr_file(&p, now)
    }

    /// Flush acknowledged by the server: entry is clean at `new_version`.
    pub fn mark_flushed(&mut self, path: &str, new_version: u64, now: VirtualTime) -> FsResult<()> {
        let p = vpath::normalize(path);
        if let Some(e) = self.entries.get_mut(&p) {
            e.state = EntryState::Clean;
            e.version = new_version;
            e.attr.version = new_version;
            e.last_used = now;
            e.residency.clear_dirty();
        }
        self.sync_attr_file(&p, now)
    }

    /// Callback invalidation: mark stale (content kept for disconnected
    /// reads, but the next open must re-fetch). Dirty entries stay dirty —
    /// last-close-wins means our queued flush will overwrite anyway.
    pub fn invalidate(&mut self, path: &str, now: VirtualTime) -> bool {
        let p = vpath::normalize(path);
        // a changed entry also invalidates the materialized parent listing
        self.dirs.remove(&vpath::parent(&p));
        match self.entries.get_mut(&p) {
            Some(e) if e.state != EntryState::Dirty => {
                e.state = EntryState::Invalid;
                let _ = self.sync_attr_file(&p, now);
                true
            }
            _ => false,
        }
    }

    /// Home-space removal: drop the cached copy entirely.
    pub fn remove(&mut self, path: &str, now: VirtualTime) {
        let p = vpath::normalize(path);
        self.dirs.remove(&vpath::parent(&p));
        self.dirs.remove(&p);
        self.entries.remove(&p);
        let _ = self.fs.unlink(&p, now);
        let dir = vpath::parent(&p);
        let name = vpath::basename(&p);
        let _ = self.fs.unlink(&vpath::join(&dir, &vpath::attr_file_name(&name)), now);
    }

    /// After a callback-channel reconnect the client may have missed
    /// invalidations: distrust every clean entry (AttrOnly entries are
    /// revalidated on open anyway).
    pub fn suspect_all_clean(&mut self, now: VirtualTime) -> usize {
        let keys: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, e)| e.state == EntryState::Clean)
            .map(|(k, _)| k.clone())
            .collect();
        let n = keys.len();
        for k in keys {
            if let Some(e) = self.entries.get_mut(&k) {
                e.state = EntryState::Invalid;
            }
            let _ = self.sync_attr_file(&k, now);
        }
        n
    }

    pub fn touch(&mut self, path: &str, now: VirtualTime) {
        if let Some(e) = self.entries.get_mut(&vpath::normalize(path)) {
            e.last_used = now;
        }
    }

    /// LRU eviction of *clean* content when over capacity. Never evicts
    /// dirty entries (their flush hasn't been acknowledged), localized
    /// files, or the entry just installed.
    fn maybe_evict(&mut self, keep: &str, now: VirtualTime) {
        while self.fs.used_bytes() > self.capacity {
            let victim = self
                .entries
                .iter()
                .filter(|(p, e)| {
                    e.state == EntryState::Clean
                        && p.as_str() != keep
                        && !self.is_localized(p)
                        && self.fs.stat(p).map(|a| a.size > 0).unwrap_or(false)
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(p, _)| p.clone());
            let Some(victim) = victim else { break };
            let _ = self.fs.truncate(&victim, 0, now);
            if let Some(e) = self.entries.get_mut(&victim) {
                e.state = EntryState::AttrOnly;
                e.digests.clear();
                e.residency.clear();
            }
            let _ = self.sync_attr_file(&victim, now);
        }
    }

    /// Budgeted LRU block eviction (`cache.budget_bytes`): while resident
    /// content exceeds the budget, evict the globally least-recently-used
    /// *clean* blocks. Dirty blocks are never evicted (their flush is not
    /// acknowledged), localized files never evict, and blocks stamped at
    /// `now` (just faulted, not yet consumed) are spared so a budget
    /// below one fault window degrades to a soft budget instead of
    /// livelocking the read path. Entries whose last block goes are
    /// demoted to `AttrOnly`. Returns `(blocks, bytes)` evicted.
    ///
    /// The budget bounds the *modeled* resident bytes: the dense
    /// in-memory [`FileStore`] cannot hole-punch mid-file blocks, so the
    /// backing bytes of a partially-evicted entry are only reclaimed
    /// when the whole entry demotes (a real deployment's sparse cache
    /// files reclaim per block). Under budget this returns after one
    /// O(resident-blocks) scan; the sort runs only when over.
    pub fn enforce_budget(&mut self, now: VirtualTime) -> (u64, u64) {
        if self.budget == 0 {
            return (0, 0);
        }
        let bb = self.block_bytes.max(1);
        let mut resident = self.resident_bytes();
        if resident <= self.budget {
            return (0, 0);
        }
        let mut cands: Vec<(VirtualTime, String, usize)> = Vec::new();
        for (p, e) in &self.entries {
            if self.localized.iter().any(|d| vpath::is_under(p, d)) {
                continue;
            }
            for i in 0..e.residency.blocks() {
                if e.residency.is_present(i) && !e.residency.is_dirty(i) {
                    let stamp = e.residency.stamp(i);
                    if stamp < now {
                        cands.push((stamp, p.clone(), i));
                    }
                }
            }
        }
        cands.sort();
        let mut evicted_blocks = 0u64;
        let mut evicted_bytes = 0u64;
        let mut demoted: Vec<String> = Vec::new();
        let mut touched: BTreeSet<String> = BTreeSet::new();
        for (_, p, i) in cands {
            if resident <= self.budget {
                break;
            }
            let Some(e) = self.entries.get_mut(&p) else { continue };
            let bytes = Residency::block_len(i, e.attr.size, bb);
            e.residency.evict(i);
            resident = resident.saturating_sub(bytes);
            evicted_bytes += bytes;
            evicted_blocks += 1;
            if e.residency.present_blocks() == 0 && e.state == EntryState::Clean {
                e.state = EntryState::AttrOnly;
                e.digests.clear();
                demoted.push(p.clone());
            }
            touched.insert(p);
        }
        // fully-evicted entries free their (zero-filled) store bytes too
        for p in demoted {
            let _ = self.fs.truncate(&p, 0, now);
        }
        for p in touched {
            let _ = self.sync_attr_file(&p, now);
        }
        (evicted_blocks, evicted_bytes)
    }

    /// Rebuild the index from the hidden attribute files — the client
    /// crash-recovery path (the on-disk cache space survived the crash).
    ///
    /// Persisted state is NOT trusted: an unknown `state` or residency
    /// token demotes the entry to [`EntryState::Invalid`] (re-fetch
    /// before the next open) instead of silently dropping or mis-typing
    /// it, counted in `cache.recover_demoted`.
    pub fn recover(
        fs: FileStore,
        capacity: u64,
        localized: Vec<String>,
        now: VirtualTime,
        metrics: &Metrics,
    ) -> Self {
        let mut cache = CacheSpace {
            fs,
            entries: HashMap::new(),
            dirs: HashMap::new(),
            localized: localized.into_iter().map(|d| vpath::normalize(&d)).collect(),
            capacity,
            block_bytes: crate::config::STRIPE_BLOCK,
            budget: 0,
        };
        let walked = cache.fs.walk("/").unwrap_or_default();
        // orphaned write-handle shadows: the client died between pwrite
        // and close. The unmerged bytes are gone (POSIX: un-closed writes
        // are not durable); the base entry stays intact. Leaving the
        // shadows would leak cache-space bytes forever.
        let orphans: Vec<String> = walked
            .iter()
            .filter(|(p, _)| vpath::is_shadow_file(&vpath::basename(p)))
            .map(|(p, _)| p.clone())
            .collect();
        for p in &orphans {
            let _ = cache.fs.unlink(p, now);
        }
        for (path, _attr) in walked {
            let name = vpath::basename(&path);
            let Some(entry_name) = name.strip_prefix(".xufs.attr.") else { continue };
            let dir = vpath::parent(&path);
            let entry_path = vpath::join(&dir, entry_name);
            let Ok(raw) = cache.fs.read(&path) else { continue };
            let Ok(json) = Json::parse(&String::from_utf8_lossy(&raw)) else { continue };
            let kind = if json.get("kind").and_then(|k| k.as_str()) == Some("dir") {
                NodeKind::Dir
            } else {
                NodeKind::File
            };
            let mut demoted = false;
            let state = match json.get("state").and_then(|s| s.as_str()) {
                None => EntryState::AttrOnly,
                Some(s) => EntryState::parse(s).unwrap_or_else(|| {
                    demoted = true;
                    EntryState::Invalid
                }),
            };
            let digests: Vec<i32> = json
                .get("digests")
                .and_then(|d| d.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as i32).collect())
                .unwrap_or_default();
            let attr = WireAttr {
                kind,
                size: json.get("size").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
                mtime_ns: json.get("mtime_ns").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
                mode: json.get("mode").and_then(|v| v.as_i64()).unwrap_or(0o600) as u32,
                version: json.get("version").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
            };
            let nblocks = attr.size.div_ceil(cache.block_bytes.max(1)) as usize;
            let residency = match json.get("residency").and_then(|r| r.as_str()) {
                Some(token) => match Residency::parse(token) {
                    Some(r) => r,
                    None => {
                        demoted = true;
                        Residency::new(nblocks)
                    }
                },
                // legacy attr file without a residency token: trust the
                // stored bytes as the (whole-file-era) cached content
                None => match (state, cache.fs.stat(&entry_path)) {
                    (EntryState::Clean, Ok(a)) if a.size > 0 => Residency::full(nblocks, now),
                    (EntryState::Dirty, Ok(_)) => Residency::full_dirty(nblocks, now),
                    _ => Residency::new(nblocks),
                },
            };
            let state = if demoted {
                metrics.incr(names::CACHE_RECOVER_DEMOTED);
                EntryState::Invalid
            } else {
                state
            };
            let residency = if demoted { Residency::new(nblocks) } else { residency };
            cache.entries.insert(
                entry_path,
                CacheEntry {
                    state,
                    version: attr.version,
                    digests,
                    attr,
                    last_used: now,
                    residency,
                },
            );
        }
        cache
    }

    /// Post-recover integrity pass (DESIGN.md §2.10): re-digest every
    /// present CLEAN block of every recovered entry against the entry's
    /// persisted digest vector, demoting mismatches to Absent (counted
    /// in `cache.recover_demoted`) — recovery must not trust bytes that
    /// rotted on the cache disk while the client was down; a demoted
    /// block just re-faults from home on its next read. Dirty blocks
    /// are exempt: they are the only copy of unshipped local writes,
    /// and dropping them would turn detection into data loss (their rot
    /// surfaces as a digest mismatch at the server instead). Returns
    /// the number of blocks demoted.
    ///
    /// Call AFTER [`Self::set_paging`]: digests are per stripe block,
    /// so the pass must use the configured block size, not the default
    /// the raw recovery walk assumes.
    pub fn verify_recovered(
        &mut self,
        engine: &crate::runtime::DigestEngine,
        now: VirtualTime,
        metrics: &Metrics,
    ) -> u64 {
        let bb = self.block_bytes.max(1);
        let mut demoted_blocks = 0u64;
        let paths: Vec<String> = self.entries.keys().cloned().collect();
        let mut emptied: Vec<String> = Vec::new();
        let mut touched: Vec<String> = Vec::new();
        for p in paths {
            if self.is_localized(&p) {
                // localized content has no home version to re-fault
                // from; nothing safe to demote to
                continue;
            }
            let (size, nblocks, digests) = match self.entries.get(&p) {
                Some(e) if e.attr.kind == NodeKind::File && !e.digests.is_empty() => {
                    (e.attr.size, e.attr.size.div_ceil(bb) as usize, e.digests.clone())
                }
                _ => continue,
            };
            let mut bad: Vec<usize> = Vec::new();
            for i in 0..nblocks {
                let (present, dirty) = match self.entries.get(&p) {
                    Some(e) => (e.residency.is_present(i), e.residency.is_dirty(i)),
                    None => break,
                };
                if !present || dirty {
                    continue;
                }
                let len = Residency::block_len(i, size, bb) as usize;
                if len == 0 {
                    continue;
                }
                let ok = match self.fs.read_at(&p, i as u64 * bb, len) {
                    Ok(data) => {
                        engine.digests(&data, bb as usize).first().copied()
                            == digests.get(i).copied()
                    }
                    // an unreadable block cannot be trusted either
                    Err(_) => false,
                };
                if !ok {
                    bad.push(i);
                }
            }
            if bad.is_empty() {
                continue;
            }
            let Some(e) = self.entries.get_mut(&p) else { continue };
            for i in bad {
                e.residency.evict(i);
                demoted_blocks += 1;
                metrics.incr(names::CACHE_RECOVER_DEMOTED);
            }
            if e.residency.present_blocks() == 0 && e.state == EntryState::Clean {
                // nothing trustworthy left: same demotion the budget
                // evictor applies to fully-evicted clean entries
                e.state = EntryState::AttrOnly;
                e.digests.clear();
                emptied.push(p.clone());
            }
            touched.push(p);
        }
        for p in emptied {
            let _ = self.fs.truncate(&p, 0, now);
        }
        for p in touched {
            let _ = self.sync_attr_file(&p, now);
        }
        demoted_blocks
    }

    /// Readdir served from cache, hiding `.xufs.*` metadata.
    pub fn readdir(&self, dir: &str) -> Result<Vec<(String, WireAttr)>, FsError> {
        let dir_n = vpath::normalize(dir);
        let mut out = Vec::new();
        for (name, _attr) in self.fs.readdir(&dir_n)? {
            if vpath::is_hidden_meta(&name) {
                continue;
            }
            let p = vpath::join(&dir_n, &name);
            let wire = match self.entries.get(&p) {
                Some(e) => e.attr.clone(),
                None => WireAttr::from_attr(&self.fs.stat(&p)?),
            };
            out.push((name, wire));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    fn wattr(size: u64, version: u64, kind: NodeKind) -> WireAttr {
        WireAttr { kind, size, mtime_ns: 0, mode: 0o600, version }
    }

    fn cache() -> CacheSpace {
        CacheSpace::new(u64::MAX, vec!["/scratch/out".into()])
    }

    #[test]
    fn materialize_creates_placeholders_and_attr_files() {
        let mut c = cache();
        c.materialize_dir(
            "/home/u",
            &[
                ("a.txt".into(), wattr(100, 3, NodeKind::File)),
                ("sub".into(), wattr(0, 1, NodeKind::Dir)),
            ],
            t(1.0),
        )
        .unwrap();
        // placeholder file is empty (content not fetched)
        assert_eq!(c.store().stat("/home/u/a.txt").unwrap().size, 0);
        // but the cached attr reports the real size (stat from hidden file)
        assert_eq!(c.entry("/home/u/a.txt").unwrap().attr.size, 100);
        assert_eq!(c.entry("/home/u/a.txt").unwrap().state, EntryState::AttrOnly);
        assert!(c.store().exists("/home/u/.xufs.attr.a.txt"));
        assert!(c.dir_state("/home/u").unwrap().complete);
        // readdir hides metadata files
        let names: Vec<String> = c.readdir("/home/u").unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a.txt", "sub"]);
    }

    #[test]
    fn install_then_invalidate_then_remove() {
        let mut c = cache();
        c.install("/home/u/f", b"data", 5, vec![1, 2], wattr(4, 5, NodeKind::File), t(1.0)).unwrap();
        assert_eq!(c.entry("/home/u/f").unwrap().state, EntryState::Clean);
        assert_eq!(c.store().read("/home/u/f").unwrap(), b"data");
        assert!(c.invalidate("/home/u/f", t(2.0)));
        assert_eq!(c.entry("/home/u/f").unwrap().state, EntryState::Invalid);
        // content retained for disconnected reads
        assert_eq!(c.store().read("/home/u/f").unwrap(), b"data");
        c.remove("/home/u/f", t(3.0));
        assert!(c.entry("/home/u/f").is_none());
        assert!(!c.store().exists("/home/u/f"));
        assert!(!c.store().exists("/home/u/.xufs.attr.f"));
    }

    #[test]
    fn dirty_entries_resist_invalidation() {
        let mut c = cache();
        c.install("/f", b"v1", 1, vec![], wattr(2, 1, NodeKind::File), t(1.0)).unwrap();
        c.store_mut().write("/f", b"local edit", t(2.0)).unwrap();
        c.mark_dirty("/f", vec![9], t(2.0)).unwrap();
        // last-close-wins: our queued flush will overwrite the home copy
        assert!(!c.invalidate("/f", t(3.0)));
        assert_eq!(c.entry("/f").unwrap().state, EntryState::Dirty);
        c.mark_flushed("/f", 7, t(4.0)).unwrap();
        let e = c.entry("/f").unwrap();
        assert_eq!(e.state, EntryState::Clean);
        assert_eq!(e.version, 7);
    }

    #[test]
    fn localized_paths() {
        let c = cache();
        assert!(c.is_localized("/scratch/out/run1/data.bin"));
        assert!(c.is_localized("/scratch/out"));
        assert!(!c.is_localized("/scratch/outside"));
        assert!(!c.is_localized("/home/u/f"));
    }

    #[test]
    fn eviction_lru_spares_dirty() {
        let mut c = CacheSpace::new(1900, vec![]);
        c.install("/old", &[1u8; 400], 1, vec![], wattr(400, 1, NodeKind::File), t(1.0)).unwrap();
        c.install("/dirty", &[2u8; 400], 1, vec![], wattr(400, 1, NodeKind::File), t(2.0)).unwrap();
        c.store_mut().write("/dirty", &[3u8; 400], t(2.5)).unwrap();
        c.mark_dirty("/dirty", vec![], t(2.5)).unwrap();
        // this install pushes over capacity; /old (LRU clean) is truncated
        c.install("/new", &[4u8; 900], 1, vec![], wattr(900, 1, NodeKind::File), t(3.0)).unwrap();
        assert_eq!(c.entry("/old").unwrap().state, EntryState::AttrOnly);
        assert_eq!(c.store().stat("/old").unwrap().size, 0);
        assert_eq!(c.entry("/dirty").unwrap().state, EntryState::Dirty);
        assert_eq!(c.store().read("/dirty").unwrap(), &[3u8; 400]);
    }

    #[test]
    fn crash_recovery_rebuilds_index_from_hidden_files() {
        let mut c = cache();
        c.materialize_dir("/home/u", &[("a".into(), wattr(10, 2, NodeKind::File))], t(1.0)).unwrap();
        c.install("/home/u/b", b"content", 4, vec![11, 22], wattr(7, 4, NodeKind::File), t(2.0))
            .unwrap();
        c.store_mut().write("/home/u/c", b"dirty stuff", t(3.0)).unwrap();
        c.mark_dirty("/home/u/c", vec![33], t(3.0)).unwrap();

        // "crash": drop the in-memory index, keep the on-disk store
        let disk = c.fs.clone();
        let r = CacheSpace::recover(disk, u64::MAX, vec![], t(10.0), &Metrics::new());
        assert_eq!(r.entry("/home/u/a").unwrap().state, EntryState::AttrOnly);
        let b = r.entry("/home/u/b").unwrap();
        assert_eq!(b.state, EntryState::Clean);
        assert_eq!(b.version, 4);
        assert_eq!(b.digests, vec![11, 22]);
        let cc = r.entry("/home/u/c").unwrap();
        assert_eq!(cc.state, EntryState::Dirty);
        assert_eq!(cc.digests, vec![33]);
        // content survived, and so did the residency maps
        assert_eq!(r.store().read("/home/u/b").unwrap(), b"content");
        assert_eq!(b.residency.present_blocks(), 1);
        assert_eq!(cc.residency.dirty_blocks(), 1);
    }

    #[test]
    fn recover_demotes_unknown_tokens_to_invalid() {
        let mut c = cache();
        c.install("/home/u/ok", b"fine", 2, vec![5], wattr(4, 2, NodeKind::File), t(1.0)).unwrap();
        c.install("/home/u/bad", b"data", 3, vec![6], wattr(4, 3, NodeKind::File), t(1.0)).unwrap();
        c.install("/home/u/worse", b"data", 4, vec![7], wattr(4, 4, NodeKind::File), t(1.0)).unwrap();
        // corrupt the persisted state string of one entry and the
        // residency token of another
        let mut disk = c.fs.clone();
        let garble = |disk: &mut FileStore, apath: &str, field: &str, junk: &str| {
            let raw = String::from_utf8_lossy(&disk.read(apath).unwrap()).to_string();
            let patched = raw.replace(field, junk);
            assert_ne!(raw, patched, "fixture must actually corrupt {apath}");
            disk.write(apath, patched.as_bytes(), t(5.0)).unwrap();
        };
        garble(&mut disk, "/home/u/.xufs.attr.bad", "\"clean\"", "\"zombie\"");
        garble(&mut disk, "/home/u/.xufs.attr.worse", "\"residency\":\"c\"", "\"residency\":\"?\"");
        let m = Metrics::new();
        let r = CacheSpace::recover(disk, u64::MAX, vec![], t(9.0), &m);
        // demoted to Invalid (re-fetch before next open), not dropped
        assert_eq!(r.entry("/home/u/bad").unwrap().state, EntryState::Invalid);
        assert_eq!(r.entry("/home/u/worse").unwrap().state, EntryState::Invalid);
        assert_eq!(r.entry("/home/u/worse").unwrap().residency.present_blocks(), 0);
        assert_eq!(m.counter(names::CACHE_RECOVER_DEMOTED), 2);
        // the intact entry recovers untouched
        assert_eq!(r.entry("/home/u/ok").unwrap().state, EntryState::Clean);
    }

    #[test]
    fn residency_token_roundtrip_and_rejects_garbage() {
        let mut r = Residency::new(5);
        r.mark_present(1, t(1.0));
        r.mark_dirty(3, t(2.0));
        assert_eq!(r.encode(), ".c.d.");
        assert_eq!(Residency::parse(".c.d."), Some(r.clone()));
        assert_eq!(Residency::parse("x.c"), None);
        assert_eq!(Residency::parse(""), Some(Residency::new(0)));
        // missing extents group into contiguous runs
        assert_eq!(r.missing_extents(0, 5), vec![(0, 1), (2, 1), (4, 1)]);
        r.mark_present(0, t(3.0));
        assert_eq!(r.missing_extents(0, 5), vec![(2, 1), (4, 1)]);
        assert_eq!(r.missing_extents(0, 2), vec![]);
    }

    #[test]
    fn budget_evicts_lru_clean_blocks_never_dirty() {
        let mut c = cache();
        let bb = c.block_bytes();
        c.set_paging(bb, 3 * bb); // budget: three blocks
        let size = 4 * bb;
        // a fully-resident clean file of 4 blocks
        c.install("/a", &vec![1u8; size as usize], 1, vec![], wattr(size, 1, NodeKind::File), t(1.0))
            .unwrap();
        // a dirty single-block file
        c.store_mut().write("/d", &vec![2u8; bb as usize], t(2.0)).unwrap();
        c.mark_dirty("/d", vec![], t(2.0)).unwrap();
        // 5 blocks resident vs a 3-block budget: evict the 2 oldest clean
        // blocks of /a; the dirty block must survive
        c.touch_blocks("/a", 2, 4, t(3.0)); // blocks 2,3 recently used
        let (blocks, bytes) = c.enforce_budget(t(4.0));
        assert_eq!(blocks, 2);
        assert_eq!(bytes, 2 * bb);
        let a = c.entry("/a").unwrap();
        assert!(!a.residency.is_present(0) && !a.residency.is_present(1));
        assert!(a.residency.is_present(2) && a.residency.is_present(3));
        assert_eq!(c.entry("/d").unwrap().residency.dirty_blocks(), 1);
        // evicting the rest demotes /a to AttrOnly; /d is never evicted
        c.set_paging(bb, 1);
        let (_, bytes) = c.enforce_budget(t(5.0));
        assert_eq!(bytes, 2 * bb);
        assert_eq!(c.entry("/a").unwrap().state, EntryState::AttrOnly);
        assert_eq!(c.store().stat("/a").unwrap().size, 0);
        assert_eq!(c.entry("/d").unwrap().state, EntryState::Dirty);
        assert_eq!(c.store().read("/d").unwrap(), &vec![2u8; bb as usize][..]);
    }

    #[test]
    fn budget_spares_blocks_stamped_now() {
        let mut c = cache();
        let bb = c.block_bytes();
        c.set_paging(bb, 1);
        c.install("/f", &vec![7u8; bb as usize], 1, vec![], wattr(bb, 1, NodeKind::File), t(2.0))
            .unwrap();
        // the just-installed block is stamped at `now`: a same-tick
        // enforcement must not evict what the reader is about to consume
        assert_eq!(c.enforce_budget(t(2.0)), (0, 0));
        assert_eq!(c.enforce_budget(t(3.0)), (1, bb));
    }

    #[test]
    fn suspect_all_clean_after_reconnect() {
        let mut c = cache();
        c.install("/a", b"1", 1, vec![], wattr(1, 1, NodeKind::File), t(1.0)).unwrap();
        c.install("/b", b"2", 1, vec![], wattr(1, 1, NodeKind::File), t(1.0)).unwrap();
        c.store_mut().write("/b", b"x", t(2.0)).unwrap();
        c.mark_dirty("/b", vec![], t(2.0)).unwrap();
        assert_eq!(c.suspect_all_clean(t(3.0)), 1);
        assert_eq!(c.entry("/a").unwrap().state, EntryState::Invalid);
        assert_eq!(c.entry("/b").unwrap().state, EntryState::Dirty);
    }

    #[test]
    fn invalidate_drops_parent_dir_completeness() {
        let mut c = cache();
        c.materialize_dir("/d", &[("f".into(), wattr(1, 1, NodeKind::File))], t(1.0)).unwrap();
        assert!(c.dir_state("/d").unwrap().complete);
        c.install("/d/f", b"x", 1, vec![], wattr(1, 1, NodeKind::File), t(2.0)).unwrap();
        c.invalidate("/d/f", t(3.0));
        assert!(c.dir_state("/d").is_none(), "listing must be re-fetched");
    }

    #[test]
    fn rematerialize_preserves_cached_content_state() {
        let mut c = cache();
        c.install("/d/f", b"cached", 3, vec![5], wattr(6, 3, NodeKind::File), t(1.0)).unwrap();
        c.materialize_dir("/d", &[("f".into(), wattr(6, 3, NodeKind::File))], t(2.0)).unwrap();
        let e = c.entry("/d/f").unwrap();
        assert_eq!(e.state, EntryState::Clean, "re-listing must not forget content");
        assert_eq!(e.digests, vec![5]);
    }
}
