//! Client cache space (paper §3.1).
//!
//! When a remote name space is mounted, a private cache space is created on
//! the client host — at TeraGrid sites, on the parallel-FS work partition.
//! XUFS recreates remote directories entirely in cache space: placeholder
//! entries plus **hidden attribute files** holding each entry's attributes
//! (so `stat()` never touches the WAN), file content fetched whole on first
//! `open()`, writes aggregated in **shadow files** flushed on `close()`
//! (last-close-wins), and **localized directories** whose contents never
//! leave the client.
//!
//! The cache space is itself a [`FileStore`] (the on-disk layout the paper
//! describes), plus an in-memory index rebuilt from those hidden files
//! after a client crash — [`CacheSpace::recover`] is exactly that rebuild.

use std::collections::HashMap;

use crate::homefs::{FileStore, FsError, FsResult, NodeKind};
use crate::proto::WireAttr;
use crate::simnet::VirtualTime;
use crate::util::path as vpath;
use crate::util::Json;

/// Consistency state of a cached entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryState {
    /// Content matches `version` at the home space (as far as callbacks
    /// have told us).
    Clean,
    /// Locally modified; flush queued in the meta-operation queue.
    Dirty,
    /// Callback invalidated it; must re-fetch before next open.
    Invalid,
    /// Attributes cached (from directory materialization) but content
    /// never fetched — the "initial empty file entry" of the paper.
    AttrOnly,
}

impl EntryState {
    fn as_str(self) -> &'static str {
        match self {
            EntryState::Clean => "clean",
            EntryState::Dirty => "dirty",
            EntryState::Invalid => "invalid",
            EntryState::AttrOnly => "attronly",
        }
    }

    fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "clean" => EntryState::Clean,
            "dirty" => EntryState::Dirty,
            "invalid" => EntryState::Invalid,
            "attronly" => EntryState::AttrOnly,
            _ => return None,
        })
    }
}

/// Index record for one cached home-space path.
#[derive(Debug, Clone)]
pub struct CacheEntry {
    pub state: EntryState,
    /// Home-space version the cached content corresponds to.
    pub version: u64,
    /// Per-block digests of the cached content (delta-writeback base).
    pub digests: Vec<i32>,
    /// Cached attributes (size/kind/mtime as of `version`).
    pub attr: WireAttr,
    /// Last access (LRU eviction).
    pub last_used: VirtualTime,
}

/// A directory whose entries have been materialized.
#[derive(Debug, Clone, Default)]
pub struct DirState {
    pub complete: bool,
    pub prefetched: bool,
}

/// The cache space: on-disk layout + index.
#[derive(Debug)]
pub struct CacheSpace {
    /// Cache contents, keyed by *home-space path* (1:1 layout).
    fs: FileStore,
    entries: HashMap<String, CacheEntry>,
    dirs: HashMap<String, DirState>,
    localized: Vec<String>,
    capacity: u64,
}

impl CacheSpace {
    pub fn new(capacity: u64, localized: Vec<String>) -> Self {
        CacheSpace {
            fs: FileStore::default(),
            entries: HashMap::new(),
            dirs: HashMap::new(),
            localized: localized.into_iter().map(|d| vpath::normalize(&d)).collect(),
            capacity,
        }
    }

    /// Is `path` inside a localized directory (content never shipped home)?
    pub fn is_localized(&self, path: &str) -> bool {
        self.localized.iter().any(|d| vpath::is_under(path, d))
    }

    pub fn localized_dirs(&self) -> &[String] {
        &self.localized
    }

    pub fn store(&self) -> &FileStore {
        &self.fs
    }

    pub fn store_mut(&mut self) -> &mut FileStore {
        &mut self.fs
    }

    pub fn entry(&self, path: &str) -> Option<&CacheEntry> {
        self.entries.get(&vpath::normalize(path))
    }

    pub fn entry_mut(&mut self, path: &str) -> Option<&mut CacheEntry> {
        self.entries.get_mut(&vpath::normalize(path))
    }

    pub fn dir_state(&self, path: &str) -> Option<&DirState> {
        self.dirs.get(&vpath::normalize(path))
    }

    pub fn set_dir_prefetched(&mut self, path: &str) {
        self.dirs.entry(vpath::normalize(path)).or_default().prefetched = true;
    }

    pub fn used_bytes(&self) -> u64 {
        self.fs.used_bytes()
    }

    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Record a materialized directory: create the directory in cache
    /// space, placeholder entries and hidden attribute files.
    pub fn materialize_dir(
        &mut self,
        dir: &str,
        entries: &[(String, WireAttr)],
        now: VirtualTime,
    ) -> FsResult<()> {
        let dir_n = vpath::normalize(dir);
        self.fs.mkdir_p(&dir_n, now)?;
        for (name, attr) in entries {
            let p = vpath::join(&dir_n, name);
            match attr.kind {
                NodeKind::Dir => {
                    self.fs.mkdir_p(&p, now)?;
                }
                NodeKind::File => {
                    if !self.fs.exists(&p) {
                        self.fs.create(&p, now)?;
                    }
                }
            }
            let (state, version, digests) = match self.entries.get(&p) {
                // don't clobber content we already hold
                Some(e) if e.state != EntryState::AttrOnly => {
                    (e.state, e.version, e.digests.clone())
                }
                _ => (EntryState::AttrOnly, attr.version, Vec::new()),
            };
            self.entries.insert(
                p.clone(),
                CacheEntry { state, version, digests, attr: attr.clone(), last_used: now },
            );
            self.sync_attr_file(&p, now)?;
        }
        self.dirs.entry(dir_n).or_default().complete = true;
        Ok(())
    }

    /// Paper §3.1: attributes live in hidden files alongside the entries.
    /// Kept in sync on every state change so crash recovery sees the truth.
    fn sync_attr_file(&mut self, path: &str, now: VirtualTime) -> FsResult<()> {
        let p = vpath::normalize(path);
        let Some(e) = self.entries.get(&p) else { return Ok(()) };
        let json = Json::obj()
            .set("kind", if e.attr.kind == NodeKind::Dir { "dir" } else { "file" })
            .set("size", e.attr.size)
            .set("mtime_ns", e.attr.mtime_ns)
            .set("mode", e.attr.mode as u64)
            .set("version", e.version)
            .set("state", e.state.as_str())
            .set("digests", Json::Arr(e.digests.iter().map(|&d| Json::Num(d as f64)).collect()));
        let dir = vpath::parent(&p);
        let name = vpath::basename(&p);
        let apath = vpath::join(&dir, &vpath::attr_file_name(&name));
        self.fs.mkdir_p(&dir, now)?;
        self.fs.write(&apath, json.to_string().as_bytes(), now)
    }

    /// Install fetched content as a clean cached copy.
    pub fn install(
        &mut self,
        path: &str,
        data: &[u8],
        version: u64,
        digests: Vec<i32>,
        attr: WireAttr,
        now: VirtualTime,
    ) -> FsResult<()> {
        let p = vpath::normalize(path);
        self.fs.mkdir_p(&vpath::parent(&p), now)?;
        self.fs.write(&p, data, now)?;
        self.entries.insert(
            p.clone(),
            CacheEntry { state: EntryState::Clean, version, digests, attr, last_used: now },
        );
        self.sync_attr_file(&p, now)?;
        self.maybe_evict(&p, now);
        Ok(())
    }

    /// Record a local modification (shadow-file flush): content already
    /// written to the cache store by the caller.
    pub fn mark_dirty(&mut self, path: &str, digests: Vec<i32>, now: VirtualTime) -> FsResult<()> {
        let p = vpath::normalize(path);
        let attr = self.fs.stat(&p)?;
        let wire = WireAttr::from_attr(&attr);
        let version = self.entries.get(&p).map(|e| e.version).unwrap_or(0);
        self.entries.insert(
            p.clone(),
            CacheEntry { state: EntryState::Dirty, version, digests, attr: wire, last_used: now },
        );
        self.sync_attr_file(&p, now)
    }

    /// Flush acknowledged by the server: entry is clean at `new_version`.
    pub fn mark_flushed(&mut self, path: &str, new_version: u64, now: VirtualTime) -> FsResult<()> {
        let p = vpath::normalize(path);
        if let Some(e) = self.entries.get_mut(&p) {
            e.state = EntryState::Clean;
            e.version = new_version;
            e.attr.version = new_version;
            e.last_used = now;
        }
        self.sync_attr_file(&p, now)
    }

    /// Callback invalidation: mark stale (content kept for disconnected
    /// reads, but the next open must re-fetch). Dirty entries stay dirty —
    /// last-close-wins means our queued flush will overwrite anyway.
    pub fn invalidate(&mut self, path: &str, now: VirtualTime) -> bool {
        let p = vpath::normalize(path);
        // a changed entry also invalidates the materialized parent listing
        self.dirs.remove(&vpath::parent(&p));
        match self.entries.get_mut(&p) {
            Some(e) if e.state != EntryState::Dirty => {
                e.state = EntryState::Invalid;
                let _ = self.sync_attr_file(&p, now);
                true
            }
            _ => false,
        }
    }

    /// Home-space removal: drop the cached copy entirely.
    pub fn remove(&mut self, path: &str, now: VirtualTime) {
        let p = vpath::normalize(path);
        self.dirs.remove(&vpath::parent(&p));
        self.dirs.remove(&p);
        self.entries.remove(&p);
        let _ = self.fs.unlink(&p, now);
        let dir = vpath::parent(&p);
        let name = vpath::basename(&p);
        let _ = self.fs.unlink(&vpath::join(&dir, &vpath::attr_file_name(&name)), now);
    }

    /// After a callback-channel reconnect the client may have missed
    /// invalidations: distrust every clean entry (AttrOnly entries are
    /// revalidated on open anyway).
    pub fn suspect_all_clean(&mut self, now: VirtualTime) -> usize {
        let keys: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, e)| e.state == EntryState::Clean)
            .map(|(k, _)| k.clone())
            .collect();
        let n = keys.len();
        for k in keys {
            if let Some(e) = self.entries.get_mut(&k) {
                e.state = EntryState::Invalid;
            }
            let _ = self.sync_attr_file(&k, now);
        }
        n
    }

    pub fn touch(&mut self, path: &str, now: VirtualTime) {
        if let Some(e) = self.entries.get_mut(&vpath::normalize(path)) {
            e.last_used = now;
        }
    }

    /// LRU eviction of *clean* content when over capacity. Never evicts
    /// dirty entries (their flush hasn't been acknowledged), localized
    /// files, or the entry just installed.
    fn maybe_evict(&mut self, keep: &str, now: VirtualTime) {
        while self.fs.used_bytes() > self.capacity {
            let victim = self
                .entries
                .iter()
                .filter(|(p, e)| {
                    e.state == EntryState::Clean
                        && p.as_str() != keep
                        && !self.is_localized(p)
                        && self.fs.stat(p).map(|a| a.size > 0).unwrap_or(false)
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(p, _)| p.clone());
            let Some(victim) = victim else { break };
            let _ = self.fs.truncate(&victim, 0, now);
            if let Some(e) = self.entries.get_mut(&victim) {
                e.state = EntryState::AttrOnly;
                e.digests.clear();
            }
            let _ = self.sync_attr_file(&victim, now);
        }
    }

    /// Rebuild the index from the hidden attribute files — the client
    /// crash-recovery path (the on-disk cache space survived the crash).
    pub fn recover(fs: FileStore, capacity: u64, localized: Vec<String>, now: VirtualTime) -> Self {
        let mut cache = CacheSpace {
            fs,
            entries: HashMap::new(),
            dirs: HashMap::new(),
            localized: localized.into_iter().map(|d| vpath::normalize(&d)).collect(),
            capacity,
        };
        let walked = cache.fs.walk("/").unwrap_or_default();
        for (path, _attr) in walked {
            let name = vpath::basename(&path);
            let Some(entry_name) = name.strip_prefix(".xufs.attr.") else { continue };
            let dir = vpath::parent(&path);
            let entry_path = vpath::join(&dir, entry_name);
            let Ok(raw) = cache.fs.read(&path) else { continue };
            let Ok(json) = Json::parse(&String::from_utf8_lossy(raw)) else { continue };
            let kind = if json.get("kind").and_then(|k| k.as_str()) == Some("dir") {
                NodeKind::Dir
            } else {
                NodeKind::File
            };
            let state = json
                .get("state")
                .and_then(|s| s.as_str())
                .and_then(EntryState::parse)
                .unwrap_or(EntryState::AttrOnly);
            let digests: Vec<i32> = json
                .get("digests")
                .and_then(|d| d.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_i64()).map(|x| x as i32).collect())
                .unwrap_or_default();
            let attr = WireAttr {
                kind,
                size: json.get("size").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
                mtime_ns: json.get("mtime_ns").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
                mode: json.get("mode").and_then(|v| v.as_i64()).unwrap_or(0o600) as u32,
                version: json.get("version").and_then(|v| v.as_i64()).unwrap_or(0) as u64,
            };
            cache.entries.insert(
                entry_path,
                CacheEntry { state, version: attr.version, digests, attr, last_used: now },
            );
        }
        cache
    }

    /// Readdir served from cache, hiding `.xufs.*` metadata.
    pub fn readdir(&self, dir: &str) -> Result<Vec<(String, WireAttr)>, FsError> {
        let dir_n = vpath::normalize(dir);
        let mut out = Vec::new();
        for (name, _attr) in self.fs.readdir(&dir_n)? {
            if vpath::is_hidden_meta(&name) {
                continue;
            }
            let p = vpath::join(&dir_n, &name);
            let wire = match self.entries.get(&p) {
                Some(e) => e.attr.clone(),
                None => WireAttr::from_attr(&self.fs.stat(&p)?),
            };
            out.push((name, wire));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    fn wattr(size: u64, version: u64, kind: NodeKind) -> WireAttr {
        WireAttr { kind, size, mtime_ns: 0, mode: 0o600, version }
    }

    fn cache() -> CacheSpace {
        CacheSpace::new(u64::MAX, vec!["/scratch/out".into()])
    }

    #[test]
    fn materialize_creates_placeholders_and_attr_files() {
        let mut c = cache();
        c.materialize_dir(
            "/home/u",
            &[
                ("a.txt".into(), wattr(100, 3, NodeKind::File)),
                ("sub".into(), wattr(0, 1, NodeKind::Dir)),
            ],
            t(1.0),
        )
        .unwrap();
        // placeholder file is empty (content not fetched)
        assert_eq!(c.store().stat("/home/u/a.txt").unwrap().size, 0);
        // but the cached attr reports the real size (stat from hidden file)
        assert_eq!(c.entry("/home/u/a.txt").unwrap().attr.size, 100);
        assert_eq!(c.entry("/home/u/a.txt").unwrap().state, EntryState::AttrOnly);
        assert!(c.store().exists("/home/u/.xufs.attr.a.txt"));
        assert!(c.dir_state("/home/u").unwrap().complete);
        // readdir hides metadata files
        let names: Vec<String> = c.readdir("/home/u").unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a.txt", "sub"]);
    }

    #[test]
    fn install_then_invalidate_then_remove() {
        let mut c = cache();
        c.install("/home/u/f", b"data", 5, vec![1, 2], wattr(4, 5, NodeKind::File), t(1.0)).unwrap();
        assert_eq!(c.entry("/home/u/f").unwrap().state, EntryState::Clean);
        assert_eq!(c.store().read("/home/u/f").unwrap(), b"data");
        assert!(c.invalidate("/home/u/f", t(2.0)));
        assert_eq!(c.entry("/home/u/f").unwrap().state, EntryState::Invalid);
        // content retained for disconnected reads
        assert_eq!(c.store().read("/home/u/f").unwrap(), b"data");
        c.remove("/home/u/f", t(3.0));
        assert!(c.entry("/home/u/f").is_none());
        assert!(!c.store().exists("/home/u/f"));
        assert!(!c.store().exists("/home/u/.xufs.attr.f"));
    }

    #[test]
    fn dirty_entries_resist_invalidation() {
        let mut c = cache();
        c.install("/f", b"v1", 1, vec![], wattr(2, 1, NodeKind::File), t(1.0)).unwrap();
        c.store_mut().write("/f", b"local edit", t(2.0)).unwrap();
        c.mark_dirty("/f", vec![9], t(2.0)).unwrap();
        // last-close-wins: our queued flush will overwrite the home copy
        assert!(!c.invalidate("/f", t(3.0)));
        assert_eq!(c.entry("/f").unwrap().state, EntryState::Dirty);
        c.mark_flushed("/f", 7, t(4.0)).unwrap();
        let e = c.entry("/f").unwrap();
        assert_eq!(e.state, EntryState::Clean);
        assert_eq!(e.version, 7);
    }

    #[test]
    fn localized_paths() {
        let c = cache();
        assert!(c.is_localized("/scratch/out/run1/data.bin"));
        assert!(c.is_localized("/scratch/out"));
        assert!(!c.is_localized("/scratch/outside"));
        assert!(!c.is_localized("/home/u/f"));
    }

    #[test]
    fn eviction_lru_spares_dirty() {
        let mut c = CacheSpace::new(1900, vec![]);
        c.install("/old", &[1u8; 400], 1, vec![], wattr(400, 1, NodeKind::File), t(1.0)).unwrap();
        c.install("/dirty", &[2u8; 400], 1, vec![], wattr(400, 1, NodeKind::File), t(2.0)).unwrap();
        c.store_mut().write("/dirty", &[3u8; 400], t(2.5)).unwrap();
        c.mark_dirty("/dirty", vec![], t(2.5)).unwrap();
        // this install pushes over capacity; /old (LRU clean) is truncated
        c.install("/new", &[4u8; 900], 1, vec![], wattr(900, 1, NodeKind::File), t(3.0)).unwrap();
        assert_eq!(c.entry("/old").unwrap().state, EntryState::AttrOnly);
        assert_eq!(c.store().stat("/old").unwrap().size, 0);
        assert_eq!(c.entry("/dirty").unwrap().state, EntryState::Dirty);
        assert_eq!(c.store().read("/dirty").unwrap(), &[3u8; 400]);
    }

    #[test]
    fn crash_recovery_rebuilds_index_from_hidden_files() {
        let mut c = cache();
        c.materialize_dir("/home/u", &[("a".into(), wattr(10, 2, NodeKind::File))], t(1.0)).unwrap();
        c.install("/home/u/b", b"content", 4, vec![11, 22], wattr(7, 4, NodeKind::File), t(2.0))
            .unwrap();
        c.store_mut().write("/home/u/c", b"dirty stuff", t(3.0)).unwrap();
        c.mark_dirty("/home/u/c", vec![33], t(3.0)).unwrap();

        // "crash": drop the in-memory index, keep the on-disk store
        let disk = c.fs.clone();
        let r = CacheSpace::recover(disk, u64::MAX, vec![], t(10.0));
        assert_eq!(r.entry("/home/u/a").unwrap().state, EntryState::AttrOnly);
        let b = r.entry("/home/u/b").unwrap();
        assert_eq!(b.state, EntryState::Clean);
        assert_eq!(b.version, 4);
        assert_eq!(b.digests, vec![11, 22]);
        let cc = r.entry("/home/u/c").unwrap();
        assert_eq!(cc.state, EntryState::Dirty);
        assert_eq!(cc.digests, vec![33]);
        // content survived
        assert_eq!(r.store().read("/home/u/b").unwrap(), b"content");
    }

    #[test]
    fn suspect_all_clean_after_reconnect() {
        let mut c = cache();
        c.install("/a", b"1", 1, vec![], wattr(1, 1, NodeKind::File), t(1.0)).unwrap();
        c.install("/b", b"2", 1, vec![], wattr(1, 1, NodeKind::File), t(1.0)).unwrap();
        c.store_mut().write("/b", b"x", t(2.0)).unwrap();
        c.mark_dirty("/b", vec![], t(2.0)).unwrap();
        assert_eq!(c.suspect_all_clean(t(3.0)), 1);
        assert_eq!(c.entry("/a").unwrap().state, EntryState::Invalid);
        assert_eq!(c.entry("/b").unwrap().state, EntryState::Dirty);
    }

    #[test]
    fn invalidate_drops_parent_dir_completeness() {
        let mut c = cache();
        c.materialize_dir("/d", &[("f".into(), wattr(1, 1, NodeKind::File))], t(1.0)).unwrap();
        assert!(c.dir_state("/d").unwrap().complete);
        c.install("/d/f", b"x", 1, vec![], wattr(1, 1, NodeKind::File), t(2.0)).unwrap();
        c.invalidate("/d/f", t(3.0));
        assert!(c.dir_state("/d").is_none(), "listing must be re-fetched");
    }

    #[test]
    fn rematerialize_preserves_cached_content_state() {
        let mut c = cache();
        c.install("/d/f", b"cached", 3, vec![5], wattr(6, 3, NodeKind::File), t(1.0)).unwrap();
        c.materialize_dir("/d", &[("f".into(), wattr(6, 3, NodeKind::File))], t(2.0)).unwrap();
        let e = c.entry("/d/f").unwrap();
        assert_eq!(e.state, EntryState::Clean, "re-listing must not forget content");
        assert_eq!(e.digests, vec![5]);
    }
}
