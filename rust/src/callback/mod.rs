//! Notification callback channel (paper §3.1).
//!
//! Cache consistency with the home space is maintained by the notification
//! callback manager: the client registers with the file server over a
//! persistent channel; any change at the home space invalidates the cached
//! copy. This module provides the shared channel both transports use: in
//! the simulated deployment the server pushes events directly into the
//! channel; over TCP a pump thread feeds it from the socket. The client
//! drains it at every op boundary (the interposed calls are the natural
//! poll points) and the coordinator's background loop.
//!
//! Disconnection semantics (AFS-2 style, paper §3.1 + §5): while the
//! channel is down the client keeps serving cached files (availability
//! during outages); on reconnect it must *re-register* and treat cached
//! entries as suspect until revalidated, since callbacks may have been
//! lost — the channel tracks a `generation` that bumps on every reconnect
//! so the client can tell.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::proto::NotifyEvent;

#[derive(Debug, Default)]
struct Inner {
    queue: VecDeque<NotifyEvent>,
    connected: bool,
    generation: u64,
    /// Events dropped while disconnected (diagnostic; the client cannot
    /// see these, which is exactly why reconnect implies revalidation).
    dropped: u64,
}

/// Shared callback channel endpoint.
#[derive(Debug, Clone, Default)]
pub struct NotifyChannel {
    inner: Arc<Mutex<Inner>>,
}

impl NotifyChannel {
    pub fn new() -> Self {
        let ch = NotifyChannel::default();
        ch.inner.lock().unwrap().connected = true;
        ch
    }

    /// Server side: push an event. Events sent while the channel is down
    /// are lost (counted), like TCP data to a dead peer.
    pub fn push(&self, ev: NotifyEvent) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.connected {
            g.queue.push_back(ev);
            true
        } else {
            g.dropped += 1;
            false
        }
    }

    /// Client side: drain pending events.
    pub fn drain(&self) -> Vec<NotifyEvent> {
        let mut g = self.inner.lock().unwrap();
        g.queue.drain(..).collect()
    }

    /// Sever the channel (network outage / server crash). Pending
    /// undelivered events are discarded — they were in flight.
    pub fn disconnect(&self) {
        let mut g = self.inner.lock().unwrap();
        g.connected = false;
        g.queue.clear();
    }

    /// Re-establish the channel; bumps the generation so the client knows
    /// callbacks may have been missed.
    pub fn reconnect(&self) -> u64 {
        let mut g = self.inner.lock().unwrap();
        g.connected = true;
        g.generation += 1;
        g.generation
    }

    pub fn is_connected(&self) -> bool {
        self.inner.lock().unwrap().connected
    }

    pub fn generation(&self) -> u64 {
        self.inner.lock().unwrap().generation
    }

    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inval(p: &str) -> NotifyEvent {
        NotifyEvent::Invalidate { path: p.into(), new_version: 2 }
    }

    #[test]
    fn push_drain_fifo() {
        let ch = NotifyChannel::new();
        assert!(ch.push(inval("/a")));
        assert!(ch.push(inval("/b")));
        let evs = ch.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0], inval("/a"));
        assert!(ch.drain().is_empty());
    }

    #[test]
    fn disconnected_drops_events() {
        let ch = NotifyChannel::new();
        ch.push(inval("/in-flight"));
        ch.disconnect();
        // in-flight event was lost with the connection
        assert_eq!(ch.pending(), 0);
        assert!(!ch.push(inval("/lost")));
        assert_eq!(ch.dropped(), 1);
        assert!(ch.drain().is_empty());
    }

    #[test]
    fn reconnect_bumps_generation() {
        let ch = NotifyChannel::new();
        assert_eq!(ch.generation(), 0);
        ch.disconnect();
        assert!(!ch.is_connected());
        let g = ch.reconnect();
        assert_eq!(g, 1);
        assert!(ch.is_connected());
        assert!(ch.push(inval("/again")));
    }

    #[test]
    fn shared_between_clones() {
        let ch = NotifyChannel::new();
        let server_side = ch.clone();
        server_side.push(NotifyEvent::ServerRestart);
        assert_eq!(ch.drain(), vec![NotifyEvent::ServerRestart]);
    }
}
