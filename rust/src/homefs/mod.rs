//! In-memory file store substrate.
//!
//! One implementation serves three roles in the deployment (DESIGN.md §3):
//! the **home space** behind the user's XUFS file server, the **cache
//! space** contents on the client side, and the server-side store of the
//! GPFS-WAN baseline. It is a real file system core — inode table,
//! hierarchical directories, path resolution, rename/unlink semantics,
//! per-file versions (the cache-consistency token) — with deterministic
//! behaviour and no host-FS dependence.

mod store;

pub use store::{Attr, FileStore, FsError, Ino, NodeKind};

/// Result alias for file-store operations.
pub type FsResult<T> = Result<T, FsError>;
