//! The inode-based file store (see `homefs/mod.rs`).

use std::collections::{BTreeMap, HashMap};
use std::fmt;

use crate::simnet::VirtualTime;
use crate::util::path as vpath;

/// Inode number.
pub type Ino = u64;

/// Largest file the dense in-memory store will materialize: 32 GiB —
/// an order of magnitude above the biggest simulated workload file
/// (~2.6 GB, Table 1's top bucket) while keeping a stray `pwrite` at an
/// absurd offset an `FsError::Invalid` instead of a process-killing
/// allocation (the store is dense; bytes up to the write's end are
/// really allocated).
pub const MAX_FILE_BYTES: u64 = 32 << 30;

/// Errors mirroring the POSIX cases the interposed libc calls surface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FsError {
    NotFound(String),
    NotADir(String),
    IsADir(String),
    Exists(String),
    NotEmpty(String),
    BadHandle,
    NoSpace,
    Invalid(String),
    Disconnected,
    Perm(String),
    Stale(String),
    LockConflict(String),
    Protocol(String),
    /// A bulk transfer died mid-flight after part of it landed; a retry
    /// can resume from `resumed_from_block` instead of restarting (the
    /// typed context `client::LinkError::Interrupted` carries across the
    /// `FsError` surface).
    Interrupted { resumed_from_block: u64 },
}

impl fmt::Display for FsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsError::NotFound(p) => write!(f, "no such file or directory: {p}"),
            FsError::NotADir(p) => write!(f, "not a directory: {p}"),
            FsError::IsADir(p) => write!(f, "is a directory: {p}"),
            FsError::Exists(p) => write!(f, "file exists: {p}"),
            FsError::NotEmpty(p) => write!(f, "directory not empty: {p}"),
            FsError::BadHandle => write!(f, "bad file handle"),
            FsError::NoSpace => write!(f, "no space left on device"),
            FsError::Invalid(m) => write!(f, "invalid argument: {m}"),
            FsError::Disconnected => write!(f, "operation would block (disconnected)"),
            FsError::Perm(m) => write!(f, "permission denied: {m}"),
            FsError::Stale(m) => write!(f, "stale cache entry: {m}"),
            FsError::LockConflict(m) => write!(f, "lock held by another client: {m}"),
            FsError::Protocol(m) => write!(f, "protocol error: {m}"),
            FsError::Interrupted { resumed_from_block } => {
                write!(f, "transfer interrupted (resumable from block {resumed_from_block})")
            }
        }
    }
}

impl std::error::Error for FsError {}

/// What a directory entry points at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    File,
    Dir,
}

impl fmt::Display for NodeKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NodeKind::File => write!(f, "file"),
            NodeKind::Dir => write!(f, "dir"),
        }
    }
}

/// Stat attributes. `version` bumps on every content or attribute change
/// and is the token the callback-consistency protocol compares.
#[derive(Debug, Clone, PartialEq)]
pub struct Attr {
    pub ino: Ino,
    pub kind: NodeKind,
    pub size: u64,
    pub mtime: VirtualTime,
    pub mode: u32,
    pub version: u64,
}

#[derive(Debug, Clone)]
enum Node {
    File { data: Vec<u8> },
    Dir { entries: BTreeMap<String, Ino> },
}

#[derive(Debug, Clone)]
struct Inode {
    node: Node,
    mtime: VirtualTime,
    mode: u32,
    version: u64,
}

impl Inode {
    fn kind(&self) -> NodeKind {
        match self.node {
            Node::File { .. } => NodeKind::File,
            Node::Dir { .. } => NodeKind::Dir,
        }
    }

    fn size(&self) -> u64 {
        match &self.node {
            Node::File { data } => data.len() as u64,
            Node::Dir { entries } => entries.len() as u64,
        }
    }
}

/// The store. All paths are virtual (`util::path`), normalized internally.
#[derive(Debug, Clone)]
pub struct FileStore {
    inodes: HashMap<Ino, Inode>,
    next_ino: Ino,
    root: Ino,
    used: u64,
    capacity: u64,
}

pub const DEFAULT_FILE_MODE: u32 = 0o600;
pub const DEFAULT_DIR_MODE: u32 = 0o700;

impl Default for FileStore {
    fn default() -> Self {
        Self::new(u64::MAX)
    }
}

impl FileStore {
    pub fn new(capacity: u64) -> Self {
        let mut inodes = HashMap::new();
        inodes.insert(
            1,
            Inode {
                node: Node::Dir { entries: BTreeMap::new() },
                mtime: VirtualTime::ZERO,
                mode: DEFAULT_DIR_MODE,
                version: 1,
            },
        );
        FileStore { inodes, next_ino: 2, root: 1, used: 0, capacity }
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    fn alloc(&mut self, node: Node, mtime: VirtualTime, mode: u32) -> Ino {
        let ino = self.next_ino;
        self.next_ino += 1;
        self.inodes.insert(ino, Inode { node, mtime, mode, version: 1 });
        ino
    }

    /// Resolve a path to an inode.
    pub fn resolve(&self, path: &str) -> Result<Ino, FsError> {
        let mut cur = self.root;
        for comp in vpath::components(path) {
            let inode = &self.inodes[&cur];
            match &inode.node {
                Node::Dir { entries } => {
                    cur = *entries.get(&comp).ok_or_else(|| FsError::NotFound(path.to_string()))?;
                }
                Node::File { .. } => return Err(FsError::NotADir(path.to_string())),
            }
        }
        Ok(cur)
    }

    pub fn exists(&self, path: &str) -> bool {
        self.resolve(path).is_ok()
    }

    fn resolve_parent(&self, path: &str) -> Result<(Ino, String), FsError> {
        let p = vpath::normalize(path);
        if p == "/" {
            return Err(FsError::Invalid("root has no parent".into()));
        }
        let parent = self.resolve(&vpath::parent(&p))?;
        if self.inodes[&parent].kind() != NodeKind::Dir {
            return Err(FsError::NotADir(vpath::parent(&p)));
        }
        Ok((parent, vpath::basename(&p)))
    }

    /// Stat by path.
    pub fn stat(&self, path: &str) -> Result<Attr, FsError> {
        let ino = self.resolve(path)?;
        Ok(self.stat_ino(ino))
    }

    pub fn stat_ino(&self, ino: Ino) -> Attr {
        let i = &self.inodes[&ino];
        Attr { ino, kind: i.kind(), size: i.size(), mtime: i.mtime, mode: i.mode, version: i.version }
    }

    /// Create an empty file. Fails if it exists.
    pub fn create(&mut self, path: &str, now: VirtualTime) -> Result<Ino, FsError> {
        let (parent, name) = self.resolve_parent(path)?;
        if self.dir_entries(parent)?.contains_key(&name) {
            return Err(FsError::Exists(path.to_string()));
        }
        let ino = self.alloc(Node::File { data: Vec::new() }, now, DEFAULT_FILE_MODE);
        self.link(parent, &name, ino, now)?;
        Ok(ino)
    }

    /// Create a directory. Fails if it exists.
    pub fn mkdir(&mut self, path: &str, now: VirtualTime) -> Result<Ino, FsError> {
        let (parent, name) = self.resolve_parent(path)?;
        if self.dir_entries(parent)?.contains_key(&name) {
            return Err(FsError::Exists(path.to_string()));
        }
        let ino = self.alloc(Node::Dir { entries: BTreeMap::new() }, now, DEFAULT_DIR_MODE);
        self.link(parent, &name, ino, now)?;
        Ok(ino)
    }

    /// `mkdir -p`.
    pub fn mkdir_p(&mut self, path: &str, now: VirtualTime) -> Result<Ino, FsError> {
        let mut cur = "/".to_string();
        let mut ino = self.root;
        for comp in vpath::components(path) {
            cur = vpath::join(&cur, &comp);
            ino = match self.resolve(&cur) {
                Ok(i) => {
                    if self.inodes[&i].kind() != NodeKind::Dir {
                        return Err(FsError::NotADir(cur));
                    }
                    i
                }
                Err(FsError::NotFound(_)) => self.mkdir(&cur, now)?,
                Err(e) => return Err(e),
            };
        }
        Ok(ino)
    }

    fn dir_entries(&self, ino: Ino) -> Result<&BTreeMap<String, Ino>, FsError> {
        match &self.inodes.get(&ino).ok_or(FsError::BadHandle)?.node {
            Node::Dir { entries } => Ok(entries),
            Node::File { .. } => Err(FsError::NotADir(format!("ino {ino}"))),
        }
    }

    fn link(&mut self, parent: Ino, name: &str, child: Ino, now: VirtualTime) -> Result<(), FsError> {
        match &mut self.inodes.get_mut(&parent).ok_or(FsError::BadHandle)?.node {
            Node::Dir { entries } => {
                entries.insert(name.to_string(), child);
            }
            Node::File { .. } => return Err(FsError::NotADir(name.to_string())),
        }
        let p = self.inodes.get_mut(&parent).unwrap();
        p.mtime = now;
        p.version += 1;
        Ok(())
    }

    /// List a directory (sorted names + attrs).
    pub fn readdir(&self, path: &str) -> Result<Vec<(String, Attr)>, FsError> {
        let ino = self.resolve(path)?;
        let entries = self.dir_entries(ino)?;
        Ok(entries.iter().map(|(n, &i)| (n.clone(), self.stat_ino(i))).collect())
    }

    /// Full file contents.
    pub fn read(&self, path: &str) -> Result<&[u8], FsError> {
        let ino = self.resolve(path)?;
        match &self.inodes[&ino].node {
            Node::File { data } => Ok(data),
            Node::Dir { .. } => Err(FsError::IsADir(path.to_string())),
        }
    }

    /// Ranged read; clamped to EOF.
    pub fn read_at(&self, path: &str, offset: u64, len: usize) -> Result<&[u8], FsError> {
        let data = self.read(path)?;
        let start = (offset as usize).min(data.len());
        let end = (start + len).min(data.len());
        Ok(&data[start..end])
    }

    /// Replace file contents entirely (creating the file if absent).
    pub fn write(&mut self, path: &str, content: &[u8], now: VirtualTime) -> Result<(), FsError> {
        if self.resolve(path).is_err() {
            self.create(path, now)?;
        }
        let ino = self.resolve(path)?;
        let old = self.inodes[&ino].size();
        let new = content.len() as u64;
        self.charge(old, new)?;
        let inode = self.inodes.get_mut(&ino).unwrap();
        match &mut inode.node {
            Node::File { data } => {
                data.clear();
                data.extend_from_slice(content);
            }
            Node::Dir { .. } => return Err(FsError::IsADir(path.to_string())),
        }
        inode.mtime = now;
        inode.version += 1;
        Ok(())
    }

    /// Ranged write (extends the file as needed). Offsets that cannot be
    /// materialized in the dense in-memory store are rejected, not
    /// panicked on — `pwrite` exposes arbitrary caller offsets (v2 Vfs).
    pub fn write_at(&mut self, path: &str, offset: u64, buf: &[u8], now: VirtualTime) -> Result<(), FsError> {
        let ino = self.resolve(path)?;
        let old = self.inodes[&ino].size();
        let end = offset
            .checked_add(buf.len() as u64)
            .filter(|&e| e <= MAX_FILE_BYTES && usize::try_from(e).is_ok())
            .ok_or_else(|| FsError::Invalid(format!("write_at offset {offset} out of range")))?;
        let new = old.max(end);
        self.charge(old, new)?;
        let inode = self.inodes.get_mut(&ino).unwrap();
        match &mut inode.node {
            Node::File { data } => {
                if data.len() < end as usize {
                    data.resize(end as usize, 0);
                }
                data[offset as usize..end as usize].copy_from_slice(buf);
            }
            Node::Dir { .. } => return Err(FsError::IsADir(path.to_string())),
        }
        inode.mtime = now;
        inode.version += 1;
        Ok(())
    }

    /// Truncate/extend to `size`.
    pub fn truncate(&mut self, path: &str, size: u64, now: VirtualTime) -> Result<(), FsError> {
        let ino = self.resolve(path)?;
        if size > MAX_FILE_BYTES {
            return Err(FsError::Invalid(format!("truncate size {size} out of range")));
        }
        let old = self.inodes[&ino].size();
        self.charge(old, size)?;
        let inode = self.inodes.get_mut(&ino).unwrap();
        match &mut inode.node {
            Node::File { data } => data.resize(size as usize, 0),
            Node::Dir { .. } => return Err(FsError::IsADir(path.to_string())),
        }
        inode.mtime = now;
        inode.version += 1;
        Ok(())
    }

    fn charge(&mut self, old: u64, new: u64) -> Result<(), FsError> {
        let next = self.used - old + new;
        if next > self.capacity {
            return Err(FsError::NoSpace);
        }
        self.used = next;
        Ok(())
    }

    /// chmod.
    pub fn set_mode(&mut self, path: &str, mode: u32, now: VirtualTime) -> Result<(), FsError> {
        let ino = self.resolve(path)?;
        let inode = self.inodes.get_mut(&ino).unwrap();
        inode.mode = mode;
        inode.mtime = now;
        inode.version += 1;
        Ok(())
    }

    /// Remove a file.
    pub fn unlink(&mut self, path: &str, now: VirtualTime) -> Result<(), FsError> {
        let ino = self.resolve(path)?;
        if self.inodes[&ino].kind() == NodeKind::Dir {
            return Err(FsError::IsADir(path.to_string()));
        }
        let (parent, name) = self.resolve_parent(path)?;
        let size = self.inodes[&ino].size();
        if let Node::Dir { entries } = &mut self.inodes.get_mut(&parent).unwrap().node {
            entries.remove(&name);
        }
        let p = self.inodes.get_mut(&parent).unwrap();
        p.mtime = now;
        p.version += 1;
        self.inodes.remove(&ino);
        self.used -= size;
        Ok(())
    }

    /// Remove an empty directory.
    pub fn rmdir(&mut self, path: &str, now: VirtualTime) -> Result<(), FsError> {
        let ino = self.resolve(path)?;
        match &self.inodes[&ino].node {
            Node::Dir { entries } if !entries.is_empty() => {
                return Err(FsError::NotEmpty(path.to_string()))
            }
            Node::Dir { .. } => {}
            Node::File { .. } => return Err(FsError::NotADir(path.to_string())),
        }
        if ino == self.root {
            return Err(FsError::Invalid("cannot remove root".into()));
        }
        let (parent, name) = self.resolve_parent(path)?;
        if let Node::Dir { entries } = &mut self.inodes.get_mut(&parent).unwrap().node {
            entries.remove(&name);
        }
        let p = self.inodes.get_mut(&parent).unwrap();
        p.mtime = now;
        p.version += 1;
        self.inodes.remove(&ino);
        Ok(())
    }

    /// Rename (file or directory). POSIX-style: replaces an existing file
    /// target; fails on non-empty directory target; refuses to move a
    /// directory under itself.
    pub fn rename(&mut self, from: &str, to: &str, now: VirtualTime) -> Result<(), FsError> {
        let from_n = vpath::normalize(from);
        let to_n = vpath::normalize(to);
        let ino = self.resolve(&from_n)?;
        if self.inodes[&ino].kind() == NodeKind::Dir && vpath::is_under(&to_n, &from_n) {
            return Err(FsError::Invalid("cannot move directory under itself".into()));
        }
        if let Ok(existing) = self.resolve(&to_n) {
            match self.inodes[&existing].kind() {
                NodeKind::File => self.unlink(&to_n, now)?,
                NodeKind::Dir => {
                    if !self.dir_entries(existing)?.is_empty() {
                        return Err(FsError::NotEmpty(to_n));
                    }
                    self.rmdir(&to_n, now)?;
                }
            }
        }
        let (old_parent, old_name) = self.resolve_parent(&from_n)?;
        let (new_parent, new_name) = self.resolve_parent(&to_n)?;
        if let Node::Dir { entries } = &mut self.inodes.get_mut(&old_parent).unwrap().node {
            entries.remove(&old_name);
        }
        let op = self.inodes.get_mut(&old_parent).unwrap();
        op.mtime = now;
        op.version += 1;
        self.link(new_parent, &new_name, ino, now)?;
        Ok(())
    }

    /// Depth-first walk of all paths under `root` (files and dirs),
    /// normalized, sorted within each directory.
    pub fn walk(&self, root: &str) -> Result<Vec<(String, Attr)>, FsError> {
        let root_n = vpath::normalize(root);
        let ino = self.resolve(&root_n)?;
        let mut out = Vec::new();
        let mut stack = vec![(root_n.clone(), ino)];
        while let Some((path, ino)) = stack.pop() {
            let inode = &self.inodes[&ino];
            if path != root_n {
                out.push((path.clone(), self.stat_ino(ino)));
            }
            if let Node::Dir { entries } = &inode.node {
                // push in reverse so iteration order is sorted
                for (name, &child) in entries.iter().rev() {
                    stack.push((vpath::join(&path, name), child));
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    #[test]
    fn create_write_read() {
        let mut fs = FileStore::default();
        fs.mkdir_p("/home/user", t(1.0)).unwrap();
        fs.write("/home/user/a.txt", b"hello", t(2.0)).unwrap();
        assert_eq!(fs.read("/home/user/a.txt").unwrap(), b"hello");
        let a = fs.stat("/home/user/a.txt").unwrap();
        assert_eq!(a.size, 5);
        assert_eq!(a.kind, NodeKind::File);
        assert_eq!(fs.used_bytes(), 5);
    }

    #[test]
    fn versions_bump_on_change() {
        let mut fs = FileStore::default();
        fs.write("/f", b"1", t(1.0)).unwrap();
        let v1 = fs.stat("/f").unwrap().version;
        fs.write("/f", b"22", t(2.0)).unwrap();
        let v2 = fs.stat("/f").unwrap().version;
        assert!(v2 > v1);
        fs.set_mode("/f", 0o644, t(3.0)).unwrap();
        assert!(fs.stat("/f").unwrap().version > v2);
    }

    #[test]
    fn parent_dir_version_bumps_on_link_unlink() {
        let mut fs = FileStore::default();
        fs.mkdir("/d", t(1.0)).unwrap();
        let v1 = fs.stat("/d").unwrap().version;
        fs.create("/d/x", t(2.0)).unwrap();
        let v2 = fs.stat("/d").unwrap().version;
        assert!(v2 > v1);
        fs.unlink("/d/x", t(3.0)).unwrap();
        assert!(fs.stat("/d").unwrap().version > v2);
    }

    #[test]
    fn write_at_extends() {
        let mut fs = FileStore::default();
        fs.create("/f", t(0.0)).unwrap();
        fs.write_at("/f", 4, b"abcd", t(1.0)).unwrap();
        assert_eq!(fs.read("/f").unwrap(), b"\0\0\0\0abcd");
        fs.write_at("/f", 0, b"zz", t(2.0)).unwrap();
        assert_eq!(fs.read("/f").unwrap(), b"zz\0\0abcd");
        assert_eq!(fs.used_bytes(), 8);
    }

    #[test]
    fn write_at_absurd_offset_errors_not_panics() {
        let mut fs = FileStore::default();
        fs.create("/f", t(0.0)).unwrap();
        // u64 overflow (offset + len wraps) must surface as an error
        assert!(matches!(
            fs.write_at("/f", u64::MAX, b"x", t(1.0)),
            Err(FsError::Invalid(_))
        ));
        // a non-overflowing but unmaterializable offset too (empty buf)
        assert!(matches!(
            fs.write_at("/f", MAX_FILE_BYTES + 1, b"", t(1.0)),
            Err(FsError::Invalid(_))
        ));
        // truncate is bounded the same way
        assert!(matches!(
            fs.truncate("/f", MAX_FILE_BYTES + 1, t(1.0)),
            Err(FsError::Invalid(_))
        ));
        // the file is untouched
        assert_eq!(fs.read("/f").unwrap(), b"");
    }

    #[test]
    fn read_at_clamps() {
        let mut fs = FileStore::default();
        fs.write("/f", b"0123456789", t(0.0)).unwrap();
        assert_eq!(fs.read_at("/f", 8, 10).unwrap(), b"89");
        assert_eq!(fs.read_at("/f", 20, 10).unwrap(), b"");
    }

    #[test]
    fn truncate_both_ways() {
        let mut fs = FileStore::default();
        fs.write("/f", b"0123456789", t(0.0)).unwrap();
        fs.truncate("/f", 4, t(1.0)).unwrap();
        assert_eq!(fs.read("/f").unwrap(), b"0123");
        fs.truncate("/f", 6, t(2.0)).unwrap();
        assert_eq!(fs.read("/f").unwrap(), b"0123\0\0");
        assert_eq!(fs.used_bytes(), 6);
    }

    #[test]
    fn unlink_and_rmdir() {
        let mut fs = FileStore::default();
        fs.mkdir("/d", t(0.0)).unwrap();
        fs.write("/d/f", b"xyz", t(0.0)).unwrap();
        assert_eq!(fs.rmdir("/d", t(0.5)), Err(FsError::NotEmpty("/d".into())));
        fs.unlink("/d/f", t(1.0)).unwrap();
        assert_eq!(fs.used_bytes(), 0);
        fs.rmdir("/d", t(2.0)).unwrap();
        assert!(!fs.exists("/d"));
    }

    #[test]
    fn rename_file_replaces_target() {
        let mut fs = FileStore::default();
        fs.write("/a", b"aaa", t(0.0)).unwrap();
        fs.write("/b", b"b", t(0.0)).unwrap();
        fs.rename("/a", "/b", t(1.0)).unwrap();
        assert!(!fs.exists("/a"));
        assert_eq!(fs.read("/b").unwrap(), b"aaa");
        assert_eq!(fs.used_bytes(), 3);
    }

    #[test]
    fn rename_dir_moves_subtree() {
        let mut fs = FileStore::default();
        fs.mkdir_p("/a/b", t(0.0)).unwrap();
        fs.write("/a/b/f", b"1", t(0.0)).unwrap();
        fs.mkdir("/c", t(0.0)).unwrap();
        fs.rename("/a/b", "/c/b", t(1.0)).unwrap();
        assert_eq!(fs.read("/c/b/f").unwrap(), b"1");
        assert!(!fs.exists("/a/b"));
    }

    #[test]
    fn rename_into_self_rejected() {
        let mut fs = FileStore::default();
        fs.mkdir_p("/a/b", t(0.0)).unwrap();
        assert!(matches!(fs.rename("/a", "/a/b/c", t(1.0)), Err(FsError::Invalid(_))));
    }

    #[test]
    fn capacity_enforced() {
        let mut fs = FileStore::new(10);
        fs.write("/f", b"0123456789", t(0.0)).unwrap();
        assert_eq!(fs.write("/g", b"x", t(1.0)), Err(FsError::NoSpace));
        // rewriting smaller frees space
        fs.write("/f", b"01234", t(2.0)).unwrap();
        fs.write("/g", b"x", t(3.0)).unwrap();
    }

    #[test]
    fn readdir_sorted_and_walk() {
        let mut fs = FileStore::default();
        fs.mkdir_p("/r/sub", t(0.0)).unwrap();
        fs.write("/r/b.txt", b"b", t(0.0)).unwrap();
        fs.write("/r/a.txt", b"a", t(0.0)).unwrap();
        fs.write("/r/sub/c.txt", b"c", t(0.0)).unwrap();
        let names: Vec<String> = fs.readdir("/r").unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a.txt", "b.txt", "sub"]);
        let walked: Vec<String> = fs.walk("/r").unwrap().into_iter().map(|(p, _)| p).collect();
        assert_eq!(walked, vec!["/r/a.txt", "/r/b.txt", "/r/sub", "/r/sub/c.txt"]);
    }

    #[test]
    fn resolve_errors() {
        let mut fs = FileStore::default();
        fs.write("/f", b"x", t(0.0)).unwrap();
        assert!(matches!(fs.stat("/missing"), Err(FsError::NotFound(_))));
        assert!(matches!(fs.readdir("/f"), Err(FsError::NotADir(_))));
        assert!(matches!(fs.read("/"), Err(FsError::IsADir(_))));
        assert!(matches!(fs.mkdir("/f/sub", t(1.0)), Err(FsError::NotADir(_))));
        assert!(matches!(fs.create("/f", t(1.0)), Err(FsError::Exists(_))));
    }
}
