//! The inode-based file store (see `homefs/mod.rs`).
//!
//! Since the meta/data split (DESIGN.md §2.8) the store runs in one of
//! two modes:
//!
//! * **Dense** (the default) — file bytes live inline in the inode, the
//!   PR ≤5 behavior byte for byte. Client cache disks, baselines and
//!   op-log backing stores stay dense: their access pattern is
//!   append-heavy positional I/O where chunk hashing buys nothing.
//! * **Chunked** ([`FileStore::enable_chunking`]) — file content lives
//!   in a content-addressed [`ChunkStore`] and inodes keep only an
//!   ordered digest list. Home servers run chunked: identical content
//!   across users dedups to one copy, snapshots pin chunks instead of
//!   copying bytes, and replication can ship references.
//!
//! Chunked mode adds **CoW snapshots**: [`FileStore::snapshot`] clones
//! the inode table (no content copies) and pins every referenced chunk;
//! the frozen namespace is readable through versioned paths — any path
//! component may carry an `@v<id>` suffix (`/proj@v42/data/x` reads
//! `/proj/data/x` as of snapshot 42). Snapshot views are strictly
//! read-only; a path whose `@v` id matches no live snapshot is treated
//! literally (files named `a@v2` stay legal).

use std::collections::{BTreeMap, HashMap};
use std::fmt;

use crate::chunkstore::{chunk_digest, digest_hex, ChunkGetError, ChunkStore, Digest};
use crate::metrics::Metrics;
use crate::simnet::VirtualTime;
use crate::util::path as vpath;

/// Inode number.
pub type Ino = u64;

/// Largest file the dense in-memory store will materialize: 32 GiB —
/// an order of magnitude above the biggest simulated workload file
/// (~2.6 GB, Table 1's top bucket) while keeping a stray `pwrite` at an
/// absurd offset an `FsError::Invalid` instead of a process-killing
/// allocation (the store is dense; bytes up to the write's end are
/// really allocated).
pub const MAX_FILE_BYTES: u64 = 32 << 30;

/// Default chunk size for chunked mode (matches the stripe block).
pub const DEFAULT_CHUNK_BYTES: usize = 64 * 1024;

/// Errors mirroring the POSIX cases the interposed libc calls surface.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FsError {
    NotFound(String),
    NotADir(String),
    IsADir(String),
    Exists(String),
    NotEmpty(String),
    BadHandle,
    NoSpace,
    Invalid(String),
    Disconnected,
    Perm(String),
    Stale(String),
    LockConflict(String),
    Protocol(String),
    /// A bulk transfer died mid-flight after part of it landed; a retry
    /// can resume from `resumed_from_block` instead of restarting (the
    /// typed context `client::LinkError::Interrupted` carries across the
    /// `FsError` surface).
    Interrupted { resumed_from_block: u64 },
    /// Stored bytes no longer match their recorded digest (bit rot,
    /// torn sector). The read is REFUSED — detection surfaces as this
    /// typed error (wire code 118), a repair, or a retry after repair;
    /// never as silently wrong data (invariant I5, DESIGN.md §2.10).
    Corrupted(String),
}

impl fmt::Display for FsError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsError::NotFound(p) => write!(f, "no such file or directory: {p}"),
            FsError::NotADir(p) => write!(f, "not a directory: {p}"),
            FsError::IsADir(p) => write!(f, "is a directory: {p}"),
            FsError::Exists(p) => write!(f, "file exists: {p}"),
            FsError::NotEmpty(p) => write!(f, "directory not empty: {p}"),
            FsError::BadHandle => write!(f, "bad file handle"),
            FsError::NoSpace => write!(f, "no space left on device"),
            FsError::Invalid(m) => write!(f, "invalid argument: {m}"),
            FsError::Disconnected => write!(f, "operation would block (disconnected)"),
            FsError::Perm(m) => write!(f, "permission denied: {m}"),
            FsError::Stale(m) => write!(f, "stale cache entry: {m}"),
            FsError::LockConflict(m) => write!(f, "lock held by another client: {m}"),
            FsError::Protocol(m) => write!(f, "protocol error: {m}"),
            FsError::Interrupted { resumed_from_block } => {
                write!(f, "transfer interrupted (resumable from block {resumed_from_block})")
            }
            FsError::Corrupted(m) => write!(f, "data integrity failure (refused): {m}"),
        }
    }
}

impl std::error::Error for FsError {}

/// What a directory entry points at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    File,
    Dir,
}

impl fmt::Display for NodeKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NodeKind::File => write!(f, "file"),
            NodeKind::Dir => write!(f, "dir"),
        }
    }
}

/// Stat attributes. `version` bumps on every content or attribute change
/// and is the token the callback-consistency protocol compares.
#[derive(Debug, Clone, PartialEq)]
pub struct Attr {
    pub ino: Ino,
    pub kind: NodeKind,
    pub size: u64,
    pub mtime: VirtualTime,
    pub mode: u32,
    pub version: u64,
}

/// File content, in whichever mode the store runs.
#[derive(Debug, Clone)]
enum FileData {
    /// Bytes inline in the inode (dense mode).
    Dense(Vec<u8>),
    /// An ordered chunk list into the store's [`ChunkStore`]; every
    /// chunk is exactly `chunk_size` bytes except a short final one.
    Chunked { size: u64, chunks: Vec<Digest> },
}

impl FileData {
    fn size(&self) -> u64 {
        match self {
            FileData::Dense(d) => d.len() as u64,
            FileData::Chunked { size, .. } => *size,
        }
    }
}

#[derive(Debug, Clone)]
enum Node {
    File { data: FileData },
    Dir { entries: BTreeMap<String, Ino> },
}

#[derive(Debug, Clone)]
struct Inode {
    node: Node,
    mtime: VirtualTime,
    mode: u32,
    version: u64,
}

impl Inode {
    fn kind(&self) -> NodeKind {
        match self.node {
            Node::File { .. } => NodeKind::File,
            Node::Dir { .. } => NodeKind::Dir,
        }
    }

    fn size(&self) -> u64 {
        match &self.node {
            Node::File { data } => data.size(),
            Node::Dir { entries } => entries.len() as u64,
        }
    }
}

/// A CoW snapshot: a frozen inode table whose chunked file nodes each
/// hold one pinned reference per chunk. No content is duplicated.
#[derive(Debug, Clone)]
struct Snapshot {
    inodes: HashMap<Ino, Inode>,
    root: Ino,
    created: VirtualTime,
}

/// The store. All paths are virtual (`util::path`), normalized internally.
#[derive(Debug, Clone)]
pub struct FileStore {
    inodes: HashMap<Ino, Inode>,
    next_ino: Ino,
    root: Ino,
    /// Logical bytes (chunked mode may physically store fewer).
    used: u64,
    capacity: u64,
    /// `Some` switches the store to chunked mode.
    chunks: Option<ChunkStore>,
    chunk_size: usize,
    snapshots: BTreeMap<u64, Snapshot>,
    next_snapshot: u64,
    snapshot_retention: usize,
    /// Content digests of dense files last written whole ([`Self::write`]):
    /// the integrity plane's coverage for dense mode. Positional writes
    /// and truncates invalidate the entry (append-heavy files like the
    /// op log carry their own per-record MACs instead); whole-file reads
    /// of a live file with a recorded sum re-verify it and refuse a
    /// mismatch as [`FsError::Corrupted`]. Keyed by ino (never reused).
    dense_sums: HashMap<Ino, Digest>,
}

pub const DEFAULT_FILE_MODE: u32 = 0o600;
pub const DEFAULT_DIR_MODE: u32 = 0o700;

impl Default for FileStore {
    fn default() -> Self {
        Self::new(u64::MAX)
    }
}

/// Parse a versioned read path: one component may carry an `@v<id>`
/// suffix. Returns the snapshot id and the path with the marker
/// stripped (`/proj@v42/x` -> `(42, "/proj/x")`; `/@v42/x` pins the
/// root -> `(42, "/x")`). The caller decides whether the id names a
/// live snapshot; if not, the original path is used literally.
fn parse_versioned(path: &str) -> Option<(u64, String)> {
    let mut id = None;
    let mut out = String::new();
    for comp in vpath::components(path) {
        let mut comp = comp;
        if id.is_none() {
            if let Some(at) = comp.rfind("@v") {
                let digits = comp[at + 2..].to_string();
                if !digits.is_empty() && digits.bytes().all(|b| b.is_ascii_digit()) {
                    if let Ok(v) = digits.parse::<u64>() {
                        id = Some(v);
                        comp.truncate(at);
                        if comp.is_empty() {
                            continue; // bare `@vN` component: the root itself
                        }
                    }
                }
            }
        }
        out.push('/');
        out.push_str(&comp);
    }
    id.map(|v| (v, if out.is_empty() { "/".to_string() } else { out }))
}

impl FileStore {
    pub fn new(capacity: u64) -> Self {
        let mut inodes = HashMap::new();
        inodes.insert(
            1,
            Inode {
                node: Node::Dir { entries: BTreeMap::new() },
                mtime: VirtualTime::ZERO,
                mode: DEFAULT_DIR_MODE,
                version: 1,
            },
        );
        FileStore {
            inodes,
            next_ino: 2,
            root: 1,
            used: 0,
            capacity,
            chunks: None,
            chunk_size: DEFAULT_CHUNK_BYTES,
            snapshots: BTreeMap::new(),
            next_snapshot: 1,
            snapshot_retention: 8,
            dense_sums: HashMap::new(),
        }
    }

    /// Switch to chunked mode: existing dense file content moves into a
    /// fresh [`ChunkStore`] (deduping as it goes). Idempotent.
    pub fn enable_chunking(&mut self, chunk_size: usize, snapshot_retention: usize) {
        if self.chunks.is_some() {
            return;
        }
        self.chunk_size = chunk_size.max(1);
        self.snapshot_retention = snapshot_retention.max(1);
        let mut cs = ChunkStore::new();
        for inode in self.inodes.values_mut() {
            if let Node::File { data } = &mut inode.node {
                if let FileData::Dense(bytes) = data {
                    let digests: Vec<Digest> =
                        bytes.chunks(self.chunk_size).map(|c| cs.put(c)).collect();
                    *data = FileData::Chunked { size: bytes.len() as u64, chunks: digests };
                }
            }
        }
        self.chunks = Some(cs);
        // chunked content is verified per-chunk; the dense side table retires
        self.dense_sums.clear();
    }

    pub fn is_chunked(&self) -> bool {
        self.chunks.is_some()
    }

    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// Point the chunk store's dedup/GC counters at a shared sink.
    pub fn attach_metrics(&mut self, metrics: &Metrics) {
        if let Some(cs) = self.chunks.as_mut() {
            cs.attach_metrics(metrics);
        }
    }

    /// Logical bytes of live file content.
    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    /// Physical bytes actually stored (equal to [`Self::used_bytes`] in
    /// dense mode; less under dedup in chunked mode).
    pub fn stored_bytes(&self) -> u64 {
        match &self.chunks {
            Some(cs) => cs.stored_bytes(),
            None => self.used,
        }
    }

    /// The chunk store, when in chunked mode (metrics / tests).
    pub fn chunkstore(&self) -> Option<&ChunkStore> {
        self.chunks.as_ref()
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    fn alloc(&mut self, node: Node, mtime: VirtualTime, mode: u32) -> Ino {
        let ino = self.next_ino;
        self.next_ino += 1;
        self.inodes.insert(ino, Inode { node, mtime, mode, version: 1 });
        ino
    }

    fn empty_file_data(&self) -> FileData {
        if self.chunks.is_some() {
            FileData::Chunked { size: 0, chunks: Vec::new() }
        } else {
            FileData::Dense(Vec::new())
        }
    }

    /// Reject mutations through a snapshot view. A path whose `@v` id
    /// matches no live snapshot falls through (treated literally).
    fn guard_live(&self, path: &str) -> Result<(), FsError> {
        if let Some((id, _)) = parse_versioned(path) {
            if self.snapshots.contains_key(&id) {
                return Err(FsError::Perm(format!("snapshot view is read-only: {path}")));
            }
        }
        Ok(())
    }

    /// Pick the inode table a path resolves against: the live namespace,
    /// or a snapshot's frozen table for `@v<id>` paths naming a live
    /// snapshot (with the marker stripped).
    fn view<'a>(&'a self, path: &str) -> (&'a HashMap<Ino, Inode>, Ino, String) {
        if let Some((id, clean)) = parse_versioned(path) {
            if let Some(s) = self.snapshots.get(&id) {
                return (&s.inodes, s.root, clean);
            }
        }
        (&self.inodes, self.root, path.to_string())
    }

    fn resolve_in(
        inodes: &HashMap<Ino, Inode>,
        root: Ino,
        path: &str,
    ) -> Result<Ino, FsError> {
        let mut cur = root;
        for comp in vpath::components(path) {
            let inode = &inodes[&cur];
            match &inode.node {
                Node::Dir { entries } => {
                    cur = *entries.get(&comp).ok_or_else(|| FsError::NotFound(path.to_string()))?;
                }
                Node::File { .. } => return Err(FsError::NotADir(path.to_string())),
            }
        }
        Ok(cur)
    }

    /// Resolve a path to an inode in the LIVE namespace (mutations and
    /// handles go through here; snapshot views are read-path only).
    pub fn resolve(&self, path: &str) -> Result<Ino, FsError> {
        Self::resolve_in(&self.inodes, self.root, path)
    }

    pub fn exists(&self, path: &str) -> bool {
        self.resolve(path).is_ok()
    }

    fn resolve_parent(&self, path: &str) -> Result<(Ino, String), FsError> {
        let p = vpath::normalize(path);
        if p == "/" {
            return Err(FsError::Invalid("root has no parent".into()));
        }
        let parent = self.resolve(&vpath::parent(&p))?;
        if self.inodes[&parent].kind() != NodeKind::Dir {
            return Err(FsError::NotADir(vpath::parent(&p)));
        }
        Ok((parent, vpath::basename(&p)))
    }

    fn stat_ino_in(inodes: &HashMap<Ino, Inode>, ino: Ino) -> Attr {
        let i = &inodes[&ino];
        Attr { ino, kind: i.kind(), size: i.size(), mtime: i.mtime, mode: i.mode, version: i.version }
    }

    /// Stat by path (snapshot views included).
    pub fn stat(&self, path: &str) -> Result<Attr, FsError> {
        let (inodes, root, p) = self.view(path);
        let ino = Self::resolve_in(inodes, root, &p)?;
        Ok(Self::stat_ino_in(inodes, ino))
    }

    pub fn stat_ino(&self, ino: Ino) -> Attr {
        Self::stat_ino_in(&self.inodes, ino)
    }

    /// Create an empty file. Fails if it exists.
    pub fn create(&mut self, path: &str, now: VirtualTime) -> Result<Ino, FsError> {
        self.guard_live(path)?;
        let (parent, name) = self.resolve_parent(path)?;
        if self.dir_entries(parent)?.contains_key(&name) {
            return Err(FsError::Exists(path.to_string()));
        }
        let data = self.empty_file_data();
        let ino = self.alloc(Node::File { data }, now, DEFAULT_FILE_MODE);
        self.link(parent, &name, ino, now)?;
        Ok(ino)
    }

    /// Create a directory. Fails if it exists.
    pub fn mkdir(&mut self, path: &str, now: VirtualTime) -> Result<Ino, FsError> {
        self.guard_live(path)?;
        let (parent, name) = self.resolve_parent(path)?;
        if self.dir_entries(parent)?.contains_key(&name) {
            return Err(FsError::Exists(path.to_string()));
        }
        let ino = self.alloc(Node::Dir { entries: BTreeMap::new() }, now, DEFAULT_DIR_MODE);
        self.link(parent, &name, ino, now)?;
        Ok(ino)
    }

    /// `mkdir -p`.
    pub fn mkdir_p(&mut self, path: &str, now: VirtualTime) -> Result<Ino, FsError> {
        self.guard_live(path)?;
        let mut cur = "/".to_string();
        let mut ino = self.root;
        for comp in vpath::components(path) {
            cur = vpath::join(&cur, &comp);
            ino = match self.resolve(&cur) {
                Ok(i) => {
                    if self.inodes[&i].kind() != NodeKind::Dir {
                        return Err(FsError::NotADir(cur));
                    }
                    i
                }
                Err(FsError::NotFound(_)) => self.mkdir(&cur, now)?,
                Err(e) => return Err(e),
            };
        }
        Ok(ino)
    }

    fn dir_entries(&self, ino: Ino) -> Result<&BTreeMap<String, Ino>, FsError> {
        match &self.inodes.get(&ino).ok_or(FsError::BadHandle)?.node {
            Node::Dir { entries } => Ok(entries),
            Node::File { .. } => Err(FsError::NotADir(format!("ino {ino}"))),
        }
    }

    fn link(&mut self, parent: Ino, name: &str, child: Ino, now: VirtualTime) -> Result<(), FsError> {
        match &mut self.inodes.get_mut(&parent).ok_or(FsError::BadHandle)?.node {
            Node::Dir { entries } => {
                entries.insert(name.to_string(), child);
            }
            Node::File { .. } => return Err(FsError::NotADir(name.to_string())),
        }
        let p = self.inodes.get_mut(&parent).unwrap();
        p.mtime = now;
        p.version += 1;
        Ok(())
    }

    /// List a directory (sorted names + attrs; snapshot views included).
    pub fn readdir(&self, path: &str) -> Result<Vec<(String, Attr)>, FsError> {
        let (inodes, root, p) = self.view(path);
        let ino = Self::resolve_in(inodes, root, &p)?;
        let entries = match &inodes.get(&ino).ok_or(FsError::BadHandle)?.node {
            Node::Dir { entries } => entries,
            Node::File { .. } => return Err(FsError::NotADir(path.to_string())),
        };
        Ok(entries.iter().map(|(n, &i)| (n.clone(), Self::stat_ino_in(inodes, i))).collect())
    }

    /// One VERIFIED chunk read (integrity plane): the digest is
    /// recomputed on the way out, so rotted bytes surface as a typed
    /// [`FsError::Corrupted`] refusal — never as wrong data.
    fn chunk_read<'a>(cs: &'a ChunkStore, d: &Digest, what: &str) -> Result<&'a [u8], FsError> {
        match cs.get_verified(d) {
            Ok(b) => Ok(b),
            Err(ChunkGetError::Missing) => {
                Err(FsError::Protocol(format!("missing chunk {} for {what}", digest_hex(d))))
            }
            Err(ChunkGetError::Corrupt) => {
                Err(FsError::Corrupted(format!("chunk {} for {what}", digest_hex(d))))
            }
        }
    }

    /// Assemble a file node's full content.
    fn file_bytes(&self, data: &FileData, path: &str) -> Result<Vec<u8>, FsError> {
        match data {
            FileData::Dense(d) => Ok(d.clone()),
            FileData::Chunked { size, chunks } => {
                let cs = self
                    .chunks
                    .as_ref()
                    .ok_or_else(|| FsError::Protocol(format!("chunked node, no chunk store: {path}")))?;
                let mut out = Vec::with_capacity(*size as usize);
                for d in chunks {
                    out.extend_from_slice(Self::chunk_read(cs, d, path)?);
                }
                Ok(out)
            }
        }
    }

    /// Full file contents (snapshot views included).
    pub fn read(&self, path: &str) -> Result<Vec<u8>, FsError> {
        let (inodes, root, p) = self.view(path);
        let ino = Self::resolve_in(inodes, root, &p)?;
        match &inodes[&ino].node {
            Node::File { data } => {
                let bytes = self.file_bytes(data, path)?;
                // dense integrity: live files last written whole carry a
                // recorded content sum — refuse silently flipped bits
                // (snapshot views share inos with live state only in
                // chunked mode, where dense_sums is empty)
                if matches!(data, FileData::Dense(_)) && std::ptr::eq(inodes, &self.inodes) {
                    if let Some(sum) = self.dense_sums.get(&ino) {
                        if chunk_digest(&bytes) != *sum {
                            return Err(FsError::Corrupted(format!("dense file {path}")));
                        }
                    }
                }
                Ok(bytes)
            }
            Node::Dir { .. } => Err(FsError::IsADir(path.to_string())),
        }
    }

    /// Ranged read; clamped to EOF. Chunked mode touches only the
    /// covering chunks (no whole-file materialization).
    pub fn read_at(&self, path: &str, offset: u64, len: usize) -> Result<Vec<u8>, FsError> {
        let (inodes, root, p) = self.view(path);
        let ino = Self::resolve_in(inodes, root, &p)?;
        let data = match &inodes[&ino].node {
            Node::File { data } => data,
            Node::Dir { .. } => return Err(FsError::IsADir(path.to_string())),
        };
        match data {
            FileData::Dense(d) => {
                let start = (offset as usize).min(d.len());
                let end = (start + len).min(d.len());
                Ok(d[start..end].to_vec())
            }
            FileData::Chunked { size, chunks } => {
                let start = offset.min(*size);
                let end = offset.saturating_add(len as u64).min(*size);
                if start >= end {
                    return Ok(Vec::new());
                }
                let cb = self.chunk_size as u64;
                let cs = self
                    .chunks
                    .as_ref()
                    .ok_or_else(|| FsError::Protocol(format!("chunked node, no chunk store: {path}")))?;
                let mut out = Vec::with_capacity((end - start) as usize);
                for ci in start / cb..end.div_ceil(cb) {
                    let bytes = Self::chunk_read(cs, &chunks[ci as usize], path)?;
                    let cstart = ci * cb;
                    let s = start.saturating_sub(cstart) as usize;
                    let e = ((end - cstart) as usize).min(bytes.len());
                    out.extend_from_slice(&bytes[s..e]);
                }
                Ok(out)
            }
        }
    }

    /// Replace file contents entirely (creating the file if absent).
    pub fn write(&mut self, path: &str, content: &[u8], now: VirtualTime) -> Result<(), FsError> {
        self.guard_live(path)?;
        if self.resolve(path).is_err() {
            self.create(path, now)?;
        }
        let ino = self.resolve(path)?;
        if self.inodes[&ino].kind() == NodeKind::Dir {
            return Err(FsError::IsADir(path.to_string()));
        }
        let old = self.inodes[&ino].size();
        let new = content.len() as u64;
        self.charge(old, new)?;
        let new_data = match self.chunks.as_mut() {
            Some(cs) => {
                let digests: Vec<Digest> =
                    content.chunks(self.chunk_size).map(|c| cs.put(c)).collect();
                FileData::Chunked { size: new, chunks: digests }
            }
            None => {
                self.dense_sums.insert(ino, chunk_digest(content));
                FileData::Dense(content.to_vec())
            }
        };
        let inode = self.inodes.get_mut(&ino).unwrap();
        let old_data = match &mut inode.node {
            Node::File { data } => std::mem::replace(data, new_data),
            Node::Dir { .. } => unreachable!("kind checked above"),
        };
        inode.mtime = now;
        inode.version += 1;
        if let (Some(cs), FileData::Chunked { chunks, .. }) = (self.chunks.as_mut(), &old_data) {
            for d in chunks {
                cs.decref(d);
            }
        }
        Ok(())
    }

    /// Ranged write (extends the file as needed). Offsets that cannot be
    /// materialized in the dense in-memory store are rejected, not
    /// panicked on — `pwrite` exposes arbitrary caller offsets (v2 Vfs).
    pub fn write_at(&mut self, path: &str, offset: u64, buf: &[u8], now: VirtualTime) -> Result<(), FsError> {
        self.guard_live(path)?;
        let ino = self.resolve(path)?;
        if self.inodes[&ino].kind() == NodeKind::Dir {
            return Err(FsError::IsADir(path.to_string()));
        }
        let old = self.inodes[&ino].size();
        let end = offset
            .checked_add(buf.len() as u64)
            .filter(|&e| e <= MAX_FILE_BYTES && usize::try_from(e).is_ok())
            .ok_or_else(|| FsError::Invalid(format!("write_at offset {offset} out of range")))?;
        let new = old.max(end);
        self.charge(old, new)?;
        if self.chunks.is_some() {
            return self.write_at_chunked(ino, offset, buf, now, old, new);
        }
        // positional mutation: the whole-file sum (if any) no longer applies
        self.dense_sums.remove(&ino);
        let inode = self.inodes.get_mut(&ino).unwrap();
        match &mut inode.node {
            Node::File { data: FileData::Dense(data) } => {
                if data.len() < end as usize {
                    data.resize(end as usize, 0);
                }
                data[offset as usize..end as usize].copy_from_slice(buf);
            }
            _ => return Err(FsError::Protocol(format!("mixed-mode node: {path}"))),
        }
        inode.mtime = now;
        inode.version += 1;
        Ok(())
    }

    /// Chunked positional write: rebuild only the chunk range the write
    /// touches. A growing write also rebuilds from the old trailing
    /// (possibly short) chunk, whose bytes move to an interior,
    /// full-sized position. Untouched chunks keep their digests — this
    /// is what keeps GiB-scale append workloads O(bytes written), not
    /// O(file size).
    fn write_at_chunked(
        &mut self,
        ino: Ino,
        offset: u64,
        buf: &[u8],
        now: VirtualTime,
        old_size: u64,
        new_size: u64,
    ) -> Result<(), FsError> {
        let cb = self.chunk_size as u64;
        let end = offset + buf.len() as u64;
        let old_chunks: Vec<Digest> = match &self.inodes[&ino].node {
            Node::File { data: FileData::Chunked { chunks, .. } } => chunks.clone(),
            _ => return Err(FsError::Protocol(format!("mixed-mode node: ino {ino}"))),
        };
        let grows = end > old_size;
        let lo = if grows {
            let old_last = if old_size == 0 { 0 } else { (old_size - 1) / cb };
            (offset / cb).min(old_last)
        } else {
            offset / cb
        };
        let hi = if grows { old_chunks.len() as u64 } else { end.div_ceil(cb) };
        // materialize the affected byte range [lo*cb, hi's end)
        let mut patch = Vec::new();
        {
            // VERIFIED reads: a rotted neighboring chunk must refuse the
            // write, not launder its bad bytes into fresh digests
            let cs = self.chunks.as_ref().expect("chunked mode");
            for ci in lo..hi {
                let what = format!("ino {ino}");
                patch.extend_from_slice(Self::chunk_read(cs, &old_chunks[ci as usize], &what)?);
            }
        }
        if grows {
            patch.resize((new_size - lo * cb) as usize, 0);
        }
        let rel = (offset - lo * cb) as usize;
        patch[rel..rel + buf.len()].copy_from_slice(buf);
        let cs = self.chunks.as_mut().expect("chunked mode");
        let new_digests: Vec<Digest> = patch.chunks(cb as usize).map(|c| cs.put(c)).collect();
        for ci in lo..hi {
            cs.decref(&old_chunks[ci as usize]);
        }
        let mut chunks = Vec::with_capacity(lo as usize + new_digests.len());
        chunks.extend_from_slice(&old_chunks[..lo as usize]);
        chunks.extend_from_slice(&new_digests);
        if !grows {
            chunks.extend_from_slice(&old_chunks[hi as usize..]);
        }
        let inode = self.inodes.get_mut(&ino).unwrap();
        if let Node::File { data } = &mut inode.node {
            *data = FileData::Chunked { size: new_size, chunks };
        }
        inode.mtime = now;
        inode.version += 1;
        Ok(())
    }

    /// Truncate/extend to `size`.
    pub fn truncate(&mut self, path: &str, size: u64, now: VirtualTime) -> Result<(), FsError> {
        self.guard_live(path)?;
        let ino = self.resolve(path)?;
        if size > MAX_FILE_BYTES {
            return Err(FsError::Invalid(format!("truncate size {size} out of range")));
        }
        if self.inodes[&ino].kind() == NodeKind::Dir {
            return Err(FsError::IsADir(path.to_string()));
        }
        let old = self.inodes[&ino].size();
        self.charge(old, size)?;
        if self.chunks.is_none() {
            self.dense_sums.remove(&ino);
            let inode = self.inodes.get_mut(&ino).unwrap();
            if let Node::File { data: FileData::Dense(data) } = &mut inode.node {
                data.resize(size as usize, 0);
            }
            inode.mtime = now;
            inode.version += 1;
            return Ok(());
        }
        if size > old {
            // zero-extension is a growing write of nothing at `size`
            return self.write_at_chunked(ino, size, &[], now, old, size);
        }
        // shrink: drop whole trailing chunks; trim the boundary chunk
        let cb = self.chunk_size as u64;
        let old_chunks: Vec<Digest> = match &self.inodes[&ino].node {
            Node::File { data: FileData::Chunked { chunks, .. } } => chunks.clone(),
            _ => return Err(FsError::Protocol(format!("mixed-mode node: ino {ino}"))),
        };
        let keep = size.div_ceil(cb) as usize;
        let tail = size % cb;
        let mut chunks = old_chunks[..keep].to_vec();
        if tail != 0 {
            let trimmed = {
                let cs = self.chunks.as_ref().expect("chunked mode");
                let bytes = Self::chunk_read(cs, &old_chunks[keep - 1], path)?;
                bytes[..tail as usize].to_vec()
            };
            let cs = self.chunks.as_mut().expect("chunked mode");
            let nd = cs.put(&trimmed);
            cs.decref(&old_chunks[keep - 1]);
            chunks[keep - 1] = nd;
        }
        let cs = self.chunks.as_mut().expect("chunked mode");
        for d in &old_chunks[keep..] {
            cs.decref(d);
        }
        let inode = self.inodes.get_mut(&ino).unwrap();
        if let Node::File { data } = &mut inode.node {
            *data = FileData::Chunked { size, chunks };
        }
        inode.mtime = now;
        inode.version += 1;
        Ok(())
    }

    fn charge(&mut self, old: u64, new: u64) -> Result<(), FsError> {
        let next = self.used - old + new;
        if next > self.capacity {
            return Err(FsError::NoSpace);
        }
        self.used = next;
        Ok(())
    }

    /// chmod.
    pub fn set_mode(&mut self, path: &str, mode: u32, now: VirtualTime) -> Result<(), FsError> {
        self.guard_live(path)?;
        let ino = self.resolve(path)?;
        let inode = self.inodes.get_mut(&ino).unwrap();
        inode.mode = mode;
        inode.mtime = now;
        inode.version += 1;
        Ok(())
    }

    /// Remove a file.
    pub fn unlink(&mut self, path: &str, now: VirtualTime) -> Result<(), FsError> {
        self.guard_live(path)?;
        let ino = self.resolve(path)?;
        if self.inodes[&ino].kind() == NodeKind::Dir {
            return Err(FsError::IsADir(path.to_string()));
        }
        let (parent, name) = self.resolve_parent(path)?;
        let size = self.inodes[&ino].size();
        if let Node::Dir { entries } = &mut self.inodes.get_mut(&parent).unwrap().node {
            entries.remove(&name);
        }
        let p = self.inodes.get_mut(&parent).unwrap();
        p.mtime = now;
        p.version += 1;
        let removed = self.inodes.remove(&ino);
        self.dense_sums.remove(&ino);
        if let (Some(cs), Some(Inode { node: Node::File { data: FileData::Chunked { chunks, .. } }, .. })) =
            (self.chunks.as_mut(), &removed)
        {
            // the namespace reference is gone; snapshots/logs holding
            // their own pins keep the chunks alive past this decref
            for d in chunks {
                cs.decref(d);
            }
        }
        self.used -= size;
        Ok(())
    }

    /// Remove an empty directory.
    pub fn rmdir(&mut self, path: &str, now: VirtualTime) -> Result<(), FsError> {
        self.guard_live(path)?;
        let ino = self.resolve(path)?;
        match &self.inodes[&ino].node {
            Node::Dir { entries } if !entries.is_empty() => {
                return Err(FsError::NotEmpty(path.to_string()))
            }
            Node::Dir { .. } => {}
            Node::File { .. } => return Err(FsError::NotADir(path.to_string())),
        }
        if ino == self.root {
            return Err(FsError::Invalid("cannot remove root".into()));
        }
        let (parent, name) = self.resolve_parent(path)?;
        if let Node::Dir { entries } = &mut self.inodes.get_mut(&parent).unwrap().node {
            entries.remove(&name);
        }
        let p = self.inodes.get_mut(&parent).unwrap();
        p.mtime = now;
        p.version += 1;
        self.inodes.remove(&ino);
        Ok(())
    }

    /// Rename (file or directory). POSIX-style: replaces an existing file
    /// target; fails on non-empty directory target; refuses to move a
    /// directory under itself. In chunked mode this is PURE metadata —
    /// the moved inode keeps its chunk list, no content moves or
    /// re-hashes (only a replaced target releases its references).
    pub fn rename(&mut self, from: &str, to: &str, now: VirtualTime) -> Result<(), FsError> {
        self.guard_live(from)?;
        self.guard_live(to)?;
        let from_n = vpath::normalize(from);
        let to_n = vpath::normalize(to);
        let ino = self.resolve(&from_n)?;
        if self.inodes[&ino].kind() == NodeKind::Dir && vpath::is_under(&to_n, &from_n) {
            return Err(FsError::Invalid("cannot move directory under itself".into()));
        }
        if let Ok(existing) = self.resolve(&to_n) {
            match self.inodes[&existing].kind() {
                NodeKind::File => self.unlink(&to_n, now)?,
                NodeKind::Dir => {
                    if !self.dir_entries(existing)?.is_empty() {
                        return Err(FsError::NotEmpty(to_n));
                    }
                    self.rmdir(&to_n, now)?;
                }
            }
        }
        let (old_parent, old_name) = self.resolve_parent(&from_n)?;
        let (new_parent, new_name) = self.resolve_parent(&to_n)?;
        if let Node::Dir { entries } = &mut self.inodes.get_mut(&old_parent).unwrap().node {
            entries.remove(&old_name);
        }
        let op = self.inodes.get_mut(&old_parent).unwrap();
        op.mtime = now;
        op.version += 1;
        self.link(new_parent, &new_name, ino, now)?;
        Ok(())
    }

    /// Depth-first walk of all paths under `root` (files and dirs),
    /// normalized, sorted within each directory.
    pub fn walk(&self, root: &str) -> Result<Vec<(String, Attr)>, FsError> {
        let root_n = vpath::normalize(root);
        let ino = self.resolve(&root_n)?;
        let mut out = Vec::new();
        let mut stack = vec![(root_n.clone(), ino)];
        while let Some((path, ino)) = stack.pop() {
            let inode = &self.inodes[&ino];
            if path != root_n {
                out.push((path.clone(), self.stat_ino(ino)));
            }
            if let Node::Dir { entries } = &inode.node {
                // push in reverse so iteration order is sorted
                for (name, &child) in entries.iter().rev() {
                    stack.push((vpath::join(&path, name), child));
                }
            }
        }
        Ok(out)
    }

    // ---- chunked-mode surface (server replication / snapshots) ----

    /// Size + ordered chunk digests of a live file (chunked mode only).
    pub fn file_chunks(&self, path: &str) -> Result<(u64, Vec<Digest>), FsError> {
        let ino = self.resolve(path)?;
        match &self.inodes[&ino].node {
            Node::File { data: FileData::Chunked { size, chunks } } => Ok((*size, chunks.clone())),
            Node::File { data: FileData::Dense(_) } => {
                Err(FsError::Invalid(format!("dense file has no chunk refs: {path}")))
            }
            Node::Dir { .. } => Err(FsError::IsADir(path.to_string())),
        }
    }

    pub fn has_chunk(&self, d: &Digest) -> bool {
        self.chunks.as_ref().map(|cs| cs.contains(d)).unwrap_or(false)
    }

    /// Chunk bytes for replication shipping / repair fills — VERIFIED:
    /// a chunk whose stored bytes have rotted is as good as absent here
    /// (shipping it would launder the rot onto the peer; the receiver's
    /// digest check would refuse it anyway).
    pub fn chunk_data(&self, d: &Digest) -> Option<Vec<u8>> {
        self.chunks.as_ref().and_then(|cs| cs.get_verified(d).ok().map(|b| b.to_vec()))
    }

    /// Insert a chunk delivered out of band (replica `ChunkPush`); the
    /// caller owns one reference (its "staged" pin).
    pub fn insert_chunk(&mut self, bytes: &[u8]) -> Result<Digest, FsError> {
        match self.chunks.as_mut() {
            Some(cs) => Ok(cs.put(bytes)),
            None => Err(FsError::Invalid("chunk push into a dense store".into())),
        }
    }

    /// Pin a chunk (e.g. while an un-shipped replication record refers
    /// to it). Returns `false` if unknown.
    pub fn incref_chunk(&mut self, d: &Digest) -> bool {
        self.chunks.as_mut().map(|cs| cs.incref(d)).unwrap_or(false)
    }

    /// Release a pin taken with [`Self::incref_chunk`]/[`Self::insert_chunk`].
    pub fn decref_chunk(&mut self, d: &Digest) {
        if let Some(cs) = self.chunks.as_mut() {
            cs.decref(d);
        }
    }

    /// Sweep dead chunks. Returns (chunks, bytes) collected.
    pub fn gc(&mut self) -> (u64, u64) {
        match self.chunks.as_mut() {
            Some(cs) => cs.gc(),
            None => (0, 0),
        }
    }

    // ---- integrity plane (DESIGN.md §2.10) ----

    /// Scrub a bounded slice of the chunk table (server op cadence):
    /// returns the next cursor and the digests newly quarantined. Dense
    /// stores have nothing to scrub here (their rot surfaces on read).
    pub fn scrub_chunks(&mut self, cursor: usize, limit: usize) -> (usize, Vec<Digest>) {
        match self.chunks.as_mut() {
            Some(cs) => cs.scrub_slice(cursor, limit),
            None => (0, Vec::new()),
        }
    }

    /// Quarantine a chunk a read path just refused (so the repair loop
    /// picks it up without waiting for the scrub cursor).
    pub fn quarantine_chunk(&mut self, d: &Digest) -> bool {
        self.chunks.as_mut().map(|cs| cs.quarantine(d)).unwrap_or(false)
    }

    /// Heal a quarantined chunk from replica-fetched bytes (digest
    /// re-verified inside). Returns the repaired digest on success.
    pub fn repair_chunk(&mut self, bytes: &[u8]) -> Option<Digest> {
        self.chunks.as_mut().and_then(|cs| cs.repair(bytes))
    }

    /// Digests awaiting repair, sorted.
    pub fn quarantined_chunks(&self) -> Vec<Digest> {
        self.chunks.as_ref().map(|cs| cs.quarantined()).unwrap_or_default()
    }

    /// All resident chunk digests, sorted (scrub drivers and the fault
    /// explorer's pick-a-shared-chunk logic).
    pub fn chunk_digests(&self) -> Vec<Digest> {
        self.chunks.as_ref().map(|cs| cs.digests()).unwrap_or_default()
    }

    /// Fault injection (bit-rot modeling): flip one byte of one stored
    /// chunk, selected deterministically from `sel`.
    pub fn corrupt_chunk_byte(&mut self, sel: u64) -> Option<Digest> {
        self.chunks.as_mut().and_then(|cs| cs.corrupt_byte(sel))
    }

    /// Directed fault injection on a specific chunk.
    pub fn corrupt_chunk_at(&mut self, d: &Digest, off: u64) -> bool {
        self.chunks.as_mut().map(|cs| cs.corrupt_chunk(d, off)).unwrap_or(false)
    }

    /// Fault injection for dense stores (client cache disks, op-log
    /// backing stores): flip one byte of one non-empty dense file,
    /// file and offset both selected deterministically from `sel`.
    /// Silent — no version/mtime bump, exactly like real bit rot.
    pub fn corrupt_dense_byte(&mut self, sel: u64) -> Option<Ino> {
        let mut files: Vec<Ino> = self
            .inodes
            .iter()
            .filter(|(_, i)| matches!(&i.node, Node::File { data: FileData::Dense(d) } if !d.is_empty()))
            .map(|(&ino, _)| ino)
            .collect();
        files.sort_unstable();
        if files.is_empty() {
            return None;
        }
        let ino = files[(sel % files.len() as u64) as usize];
        if let Some(Inode { node: Node::File { data: FileData::Dense(d) }, .. }) =
            self.inodes.get_mut(&ino)
        {
            let at = ((sel >> 16) % d.len() as u64) as usize;
            d[at] ^= 0x40;
        }
        Some(ino)
    }

    /// Directed fault injection on one file's stored bytes (`off` wraps):
    /// dense bytes are flipped in place; a chunked file rots the chunk
    /// covering the offset. Returns `false` for missing/empty files.
    pub fn corrupt_file_byte(&mut self, path: &str, off: u64) -> bool {
        let Ok(ino) = self.resolve(path) else { return false };
        let chunk = match self.inodes.get_mut(&ino) {
            Some(Inode { node: Node::File { data: FileData::Dense(d) }, .. }) if !d.is_empty() => {
                let at = (off % d.len() as u64) as usize;
                d[at] ^= 0x40;
                return true;
            }
            Some(Inode { node: Node::File { data: FileData::Chunked { size, chunks } }, .. })
                if *size > 0 =>
            {
                chunks[((off % *size) / self.chunk_size as u64) as usize]
            }
            _ => return false,
        };
        self.corrupt_chunk_at(&chunk, off)
    }

    // ---- snapshots ----

    /// Take a CoW snapshot of the live namespace: clone the inode table
    /// and pin every referenced chunk — O(metadata), no content copies.
    /// Read it back through `@v<id>` paths. Snapshots beyond the
    /// retention bound evict oldest-first (releasing their pins).
    pub fn snapshot(&mut self, now: VirtualTime) -> Result<u64, FsError> {
        let Some(cs) = self.chunks.as_mut() else {
            return Err(FsError::Invalid("snapshots need the chunked store".into()));
        };
        for inode in self.inodes.values() {
            if let Node::File { data: FileData::Chunked { chunks, .. } } = &inode.node {
                for d in chunks {
                    cs.incref(d);
                }
            }
        }
        let id = self.next_snapshot;
        self.next_snapshot += 1;
        self.snapshots
            .insert(id, Snapshot { inodes: self.inodes.clone(), root: self.root, created: now });
        while self.snapshots.len() > self.snapshot_retention {
            let oldest = *self.snapshots.keys().next().expect("non-empty");
            self.drop_snapshot(oldest);
        }
        Ok(id)
    }

    /// Drop a snapshot, releasing its chunk pins. Returns `false` if the
    /// id names no live snapshot.
    pub fn drop_snapshot(&mut self, id: u64) -> bool {
        let Some(snap) = self.snapshots.remove(&id) else {
            return false;
        };
        if let Some(cs) = self.chunks.as_mut() {
            for inode in snap.inodes.values() {
                if let Node::File { data: FileData::Chunked { chunks, .. } } = &inode.node {
                    for d in chunks {
                        cs.decref(d);
                    }
                }
            }
        }
        true
    }

    /// Live snapshot ids, oldest first.
    pub fn snapshot_ids(&self) -> Vec<u64> {
        self.snapshots.keys().copied().collect()
    }

    /// When a snapshot was taken.
    pub fn snapshot_created(&self, id: u64) -> Option<VirtualTime> {
        self.snapshots.get(&id).map(|s| s.created)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs(s)
    }

    /// A store in chunked mode with a tiny chunk so tests cross chunk
    /// boundaries with small payloads.
    fn chunked(chunk: usize) -> FileStore {
        let mut fs = FileStore::default();
        fs.enable_chunking(chunk, 8);
        fs
    }

    #[test]
    fn create_write_read() {
        let mut fs = FileStore::default();
        fs.mkdir_p("/home/user", t(1.0)).unwrap();
        fs.write("/home/user/a.txt", b"hello", t(2.0)).unwrap();
        assert_eq!(fs.read("/home/user/a.txt").unwrap(), b"hello");
        let a = fs.stat("/home/user/a.txt").unwrap();
        assert_eq!(a.size, 5);
        assert_eq!(a.kind, NodeKind::File);
        assert_eq!(fs.used_bytes(), 5);
    }

    #[test]
    fn versions_bump_on_change() {
        let mut fs = FileStore::default();
        fs.write("/f", b"1", t(1.0)).unwrap();
        let v1 = fs.stat("/f").unwrap().version;
        fs.write("/f", b"22", t(2.0)).unwrap();
        let v2 = fs.stat("/f").unwrap().version;
        assert!(v2 > v1);
        fs.set_mode("/f", 0o644, t(3.0)).unwrap();
        assert!(fs.stat("/f").unwrap().version > v2);
    }

    #[test]
    fn parent_dir_version_bumps_on_link_unlink() {
        let mut fs = FileStore::default();
        fs.mkdir("/d", t(1.0)).unwrap();
        let v1 = fs.stat("/d").unwrap().version;
        fs.create("/d/x", t(2.0)).unwrap();
        let v2 = fs.stat("/d").unwrap().version;
        assert!(v2 > v1);
        fs.unlink("/d/x", t(3.0)).unwrap();
        assert!(fs.stat("/d").unwrap().version > v2);
    }

    #[test]
    fn write_at_extends() {
        let mut fs = FileStore::default();
        fs.create("/f", t(0.0)).unwrap();
        fs.write_at("/f", 4, b"abcd", t(1.0)).unwrap();
        assert_eq!(fs.read("/f").unwrap(), b"\0\0\0\0abcd");
        fs.write_at("/f", 0, b"zz", t(2.0)).unwrap();
        assert_eq!(fs.read("/f").unwrap(), b"zz\0\0abcd");
        assert_eq!(fs.used_bytes(), 8);
    }

    #[test]
    fn write_at_absurd_offset_errors_not_panics() {
        let mut fs = FileStore::default();
        fs.create("/f", t(0.0)).unwrap();
        // u64 overflow (offset + len wraps) must surface as an error
        assert!(matches!(
            fs.write_at("/f", u64::MAX, b"x", t(1.0)),
            Err(FsError::Invalid(_))
        ));
        // a non-overflowing but unmaterializable offset too (empty buf)
        assert!(matches!(
            fs.write_at("/f", MAX_FILE_BYTES + 1, b"", t(1.0)),
            Err(FsError::Invalid(_))
        ));
        // truncate is bounded the same way
        assert!(matches!(
            fs.truncate("/f", MAX_FILE_BYTES + 1, t(1.0)),
            Err(FsError::Invalid(_))
        ));
        // the file is untouched
        assert_eq!(fs.read("/f").unwrap(), b"");
    }

    #[test]
    fn read_at_clamps() {
        let mut fs = FileStore::default();
        fs.write("/f", b"0123456789", t(0.0)).unwrap();
        assert_eq!(fs.read_at("/f", 8, 10).unwrap(), b"89");
        assert_eq!(fs.read_at("/f", 20, 10).unwrap(), b"");
    }

    #[test]
    fn truncate_both_ways() {
        let mut fs = FileStore::default();
        fs.write("/f", b"0123456789", t(0.0)).unwrap();
        fs.truncate("/f", 4, t(1.0)).unwrap();
        assert_eq!(fs.read("/f").unwrap(), b"0123");
        fs.truncate("/f", 6, t(2.0)).unwrap();
        assert_eq!(fs.read("/f").unwrap(), b"0123\0\0");
        assert_eq!(fs.used_bytes(), 6);
    }

    #[test]
    fn unlink_and_rmdir() {
        let mut fs = FileStore::default();
        fs.mkdir("/d", t(0.0)).unwrap();
        fs.write("/d/f", b"xyz", t(0.0)).unwrap();
        assert_eq!(fs.rmdir("/d", t(0.5)), Err(FsError::NotEmpty("/d".into())));
        fs.unlink("/d/f", t(1.0)).unwrap();
        assert_eq!(fs.used_bytes(), 0);
        fs.rmdir("/d", t(2.0)).unwrap();
        assert!(!fs.exists("/d"));
    }

    #[test]
    fn rename_file_replaces_target() {
        let mut fs = FileStore::default();
        fs.write("/a", b"aaa", t(0.0)).unwrap();
        fs.write("/b", b"b", t(0.0)).unwrap();
        fs.rename("/a", "/b", t(1.0)).unwrap();
        assert!(!fs.exists("/a"));
        assert_eq!(fs.read("/b").unwrap(), b"aaa");
        assert_eq!(fs.used_bytes(), 3);
    }

    #[test]
    fn rename_dir_moves_subtree() {
        let mut fs = FileStore::default();
        fs.mkdir_p("/a/b", t(0.0)).unwrap();
        fs.write("/a/b/f", b"1", t(0.0)).unwrap();
        fs.mkdir("/c", t(0.0)).unwrap();
        fs.rename("/a/b", "/c/b", t(1.0)).unwrap();
        assert_eq!(fs.read("/c/b/f").unwrap(), b"1");
        assert!(!fs.exists("/a/b"));
    }

    #[test]
    fn rename_into_self_rejected() {
        let mut fs = FileStore::default();
        fs.mkdir_p("/a/b", t(0.0)).unwrap();
        assert!(matches!(fs.rename("/a", "/a/b/c", t(1.0)), Err(FsError::Invalid(_))));
    }

    #[test]
    fn capacity_enforced() {
        let mut fs = FileStore::new(10);
        fs.write("/f", b"0123456789", t(0.0)).unwrap();
        assert_eq!(fs.write("/g", b"x", t(1.0)), Err(FsError::NoSpace));
        // rewriting smaller frees space
        fs.write("/f", b"01234", t(2.0)).unwrap();
        fs.write("/g", b"x", t(3.0)).unwrap();
    }

    #[test]
    fn readdir_sorted_and_walk() {
        let mut fs = FileStore::default();
        fs.mkdir_p("/r/sub", t(0.0)).unwrap();
        fs.write("/r/b.txt", b"b", t(0.0)).unwrap();
        fs.write("/r/a.txt", b"a", t(0.0)).unwrap();
        fs.write("/r/sub/c.txt", b"c", t(0.0)).unwrap();
        let names: Vec<String> = fs.readdir("/r").unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a.txt", "b.txt", "sub"]);
        let walked: Vec<String> = fs.walk("/r").unwrap().into_iter().map(|(p, _)| p).collect();
        assert_eq!(walked, vec!["/r/a.txt", "/r/b.txt", "/r/sub", "/r/sub/c.txt"]);
    }

    #[test]
    fn resolve_errors() {
        let mut fs = FileStore::default();
        fs.write("/f", b"x", t(0.0)).unwrap();
        assert!(matches!(fs.stat("/missing"), Err(FsError::NotFound(_))));
        assert!(matches!(fs.readdir("/f"), Err(FsError::NotADir(_))));
        assert!(matches!(fs.read("/"), Err(FsError::IsADir(_))));
        assert!(matches!(fs.mkdir("/f/sub", t(1.0)), Err(FsError::NotADir(_))));
        assert!(matches!(fs.create("/f", t(1.0)), Err(FsError::Exists(_))));
    }

    // ---- chunked mode ----

    #[test]
    fn chunked_matches_dense_on_random_ops() {
        // same op sequence against both modes must read identically
        let mut dense = FileStore::default();
        let mut ch = chunked(7); // deliberately odd chunk size
        let mut seed = 0x1234_5678_9abc_def0u64;
        let mut rng = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for s in [&mut dense, &mut ch] {
            s.mkdir_p("/w", t(0.0)).unwrap();
        }
        for step in 0..400u64 {
            let r = rng();
            let path = format!("/w/f{}", r % 5);
            let now = t(step as f64);
            match r % 5 {
                0 => {
                    let data = vec![(r >> 8) as u8; (r % 61) as usize];
                    dense.write(&path, &data, now).unwrap();
                    ch.write(&path, &data, now).unwrap();
                }
                1 => {
                    if dense.exists(&path) {
                        let off = r % 40;
                        let buf = vec![(r >> 16) as u8; (r % 23) as usize];
                        assert_eq!(
                            dense.write_at(&path, off, &buf, now),
                            ch.write_at(&path, off, &buf, now)
                        );
                    }
                }
                2 => {
                    if dense.exists(&path) {
                        let size = r % 70;
                        assert_eq!(
                            dense.truncate(&path, size, now),
                            ch.truncate(&path, size, now)
                        );
                    }
                }
                3 => {
                    if dense.exists(&path) {
                        assert_eq!(dense.unlink(&path, now), ch.unlink(&path, now));
                    }
                }
                _ => {
                    assert_eq!(dense.read(&path).ok(), ch.read(&path).ok(), "step {step} {path}");
                    let off = r % 50;
                    let len = (r % 30) as usize;
                    assert_eq!(
                        dense.read_at(&path, off, len).ok(),
                        ch.read_at(&path, off, len).ok()
                    );
                }
            }
            assert_eq!(dense.used_bytes(), ch.used_bytes(), "step {step}");
        }
        for (p, a) in dense.walk("/").unwrap() {
            if a.kind == NodeKind::File {
                assert_eq!(dense.read(&p).unwrap(), ch.read(&p).unwrap(), "{p}");
            }
        }
    }

    #[test]
    fn identical_content_dedups() {
        let mut fs = chunked(8);
        let blob = vec![0xABu8; 64];
        fs.write("/u1/tool", &blob, t(0.0)).map_err(|_| ()).ok();
        fs.mkdir_p("/u1", t(0.0)).unwrap();
        fs.mkdir_p("/u2", t(0.0)).unwrap();
        fs.write("/u1/tool", &blob, t(1.0)).unwrap();
        fs.write("/u2/tool", &blob, t(2.0)).unwrap();
        assert_eq!(fs.used_bytes(), 128, "logical bytes double-count");
        assert_eq!(fs.stored_bytes(), 64, "physical bytes stored once");
        assert!(fs.chunkstore().unwrap().dedup_hits() >= 8);
    }

    #[test]
    fn unlink_then_gc_frees_unshared_chunks() {
        let mut fs = chunked(4);
        fs.write("/a", b"unique-a", t(0.0)).unwrap();
        fs.write("/b", b"unique-b", t(0.0)).unwrap();
        fs.unlink("/a", t(1.0)).unwrap();
        assert_eq!(fs.stored_bytes(), 16, "dead bytes retained until sweep");
        let (n, bytes) = fs.gc();
        assert!(n >= 1);
        assert_eq!(bytes, 4, "only /a's unshared chunk freed ('uniq' prefix is shared)");
        assert_eq!(fs.read("/b").unwrap(), b"unique-b");
    }

    #[test]
    fn snapshot_isolates_reads_from_live_mutations() {
        let mut fs = chunked(4);
        fs.mkdir_p("/proj", t(0.0)).unwrap();
        fs.write("/proj/data", b"version-one", t(1.0)).unwrap();
        let id = fs.snapshot(t(2.0)).unwrap();
        fs.write("/proj/data", b"version-TWO!", t(3.0)).unwrap();
        fs.truncate("/proj/data", 7, t(4.0)).unwrap();
        // live sees the mutation, the snapshot view the frozen content
        assert_eq!(fs.read("/proj/data").unwrap(), b"version");
        let vpath = format!("/proj@v{id}/data");
        assert_eq!(fs.read(&vpath).unwrap(), b"version-one");
        assert_eq!(fs.stat(&vpath).unwrap().size, 11);
        assert_eq!(fs.read_at(&vpath, 8, 3).unwrap(), b"one");
        // readdir through the view too
        let names: Vec<String> =
            fs.readdir(&format!("/proj@v{id}")).unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["data"]);
        // gc never collects snapshot-pinned chunks
        fs.gc();
        assert_eq!(fs.read(&vpath).unwrap(), b"version-one");
    }

    #[test]
    fn snapshot_survives_unlink_and_drop_releases() {
        let mut fs = chunked(4);
        fs.write("/f", b"pinned-by-snap", t(0.0)).unwrap();
        let id = fs.snapshot(t(1.0)).unwrap();
        fs.unlink("/f", t(2.0)).unwrap();
        fs.gc();
        assert_eq!(fs.read(&format!("/f@v{id}")).unwrap(), b"pinned-by-snap");
        assert!(fs.drop_snapshot(id));
        let (n, _) = fs.gc();
        assert!(n >= 1, "dropping the last pin frees the chunks");
        assert!(fs.read(&format!("/f@v{id}")).is_err(), "dropped snapshot id is literal");
    }

    #[test]
    fn snapshot_views_are_read_only() {
        let mut fs = chunked(4);
        fs.write("/f", b"frozen", t(0.0)).unwrap();
        let id = fs.snapshot(t(1.0)).unwrap();
        let vp = format!("/f@v{id}");
        assert!(matches!(fs.write(&vp, b"x", t(2.0)), Err(FsError::Perm(_))));
        assert!(matches!(fs.unlink(&vp, t(2.0)), Err(FsError::Perm(_))));
        assert!(matches!(fs.truncate(&vp, 0, t(2.0)), Err(FsError::Perm(_))));
        assert!(matches!(
            fs.rename(&vp, "/g", t(2.0)),
            Err(FsError::Perm(_))
        ));
        // an id that names no snapshot is a literal path component
        fs.write("/f@v999", b"literal", t(3.0)).unwrap();
        assert_eq!(fs.read("/f@v999").unwrap(), b"literal");
    }

    #[test]
    fn snapshot_retention_evicts_oldest() {
        let mut fs = FileStore::default();
        fs.enable_chunking(4, 2);
        fs.write("/f", b"aaaa", t(0.0)).unwrap();
        let s1 = fs.snapshot(t(1.0)).unwrap();
        let s2 = fs.snapshot(t(2.0)).unwrap();
        let s3 = fs.snapshot(t(3.0)).unwrap();
        assert_eq!(fs.snapshot_ids(), vec![s2, s3]);
        assert!(fs.snapshot_created(s1).is_none());
        assert!(fs.snapshot_created(s3).is_some());
    }

    #[test]
    fn rename_is_pure_metadata_in_chunked_mode() {
        let mut fs = chunked(4);
        fs.mkdir_p("/a", t(0.0)).unwrap();
        fs.mkdir_p("/b", t(0.0)).unwrap();
        fs.write("/a/big", &vec![7u8; 1000], t(1.0)).unwrap();
        let (size_before, digests_before) = fs.file_chunks("/a/big").unwrap();
        let stored = fs.stored_bytes();
        let hits = fs.chunkstore().unwrap().dedup_hits();
        fs.rename("/a/big", "/b/big", t(2.0)).unwrap();
        let (size_after, digests_after) = fs.file_chunks("/b/big").unwrap();
        assert_eq!((size_before, &digests_before), (size_after, &digests_after));
        assert_eq!(fs.stored_bytes(), stored, "no bytes moved");
        assert_eq!(fs.chunkstore().unwrap().dedup_hits(), hits, "no re-chunking");
        assert_eq!(fs.read("/b/big").unwrap(), vec![7u8; 1000]);
    }

    #[test]
    fn chunk_surface_for_replication() {
        let mut fs = chunked(4);
        fs.write("/f", b"abcdefgh", t(0.0)).unwrap();
        let (size, digests) = fs.file_chunks("/f").unwrap();
        assert_eq!(size, 8);
        assert_eq!(digests.len(), 2);
        assert!(fs.has_chunk(&digests[0]));
        assert_eq!(fs.chunk_data(&digests[0]).unwrap(), b"abcd");
        // a log pin keeps a chunk past unlink+gc
        assert!(fs.incref_chunk(&digests[1]));
        fs.unlink("/f", t(1.0)).unwrap();
        fs.gc();
        assert!(!fs.has_chunk(&digests[0]));
        assert!(fs.has_chunk(&digests[1]), "pinned chunk survives");
        fs.decref_chunk(&digests[1]);
        fs.gc();
        assert!(!fs.has_chunk(&digests[1]));
    }

    #[test]
    fn snapshots_require_chunked_mode() {
        let mut fs = FileStore::default();
        assert!(matches!(fs.snapshot(t(0.0)), Err(FsError::Invalid(_))));
    }

    #[test]
    fn versioned_path_parsing() {
        assert_eq!(parse_versioned("/proj@v42/data/x"), Some((42, "/proj/data/x".into())));
        assert_eq!(parse_versioned("/@v7"), Some((7, "/".into())));
        assert_eq!(parse_versioned("/@v7/x"), Some((7, "/x".into())));
        assert_eq!(parse_versioned("/f@v0"), Some((0, "/f".into())));
        assert_eq!(parse_versioned("/plain/path"), None);
        assert_eq!(parse_versioned("/odd@vx/path"), None);
        assert_eq!(parse_versioned("/trailing@v"), None);
    }
}
