//! Deterministic PRNG (SplitMix64 + xoshiro256**).
//!
//! The offline crate set has no `rand`; everything stochastic in the
//! simulator (workload generation, jitter, fault injection, property tests)
//! goes through this module so runs are reproducible from a single seed.

/// xoshiro256** seeded via SplitMix64. Fast, high-quality, `Copy`-cheap.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Random alphanumeric string of length `n`.
    pub fn alnum(&mut self, n: usize) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789";
        (0..n).map(|_| CHARS[self.below(CHARS.len() as u64) as usize] as char).collect()
    }

    /// Choose a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let n = r.range(1, 1000);
            assert!(r.below(n) < n);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut hist = [0usize; 10];
        for _ in 0..100_000 {
            hist[r.below(10) as usize] += 1;
        }
        for &h in &hist {
            assert!((8_000..12_000).contains(&h), "{hist:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn log_normal_positive() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.log_normal(10.0, 2.0) > 0.0);
        }
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(13);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
