//! Minimal JSON: an emitter and a recursive-descent parser.
//!
//! `serde`/`serde_json` are not in the offline crate set; the runtime only
//! needs to read `artifacts/manifest.json` and the bench harness needs to
//! emit machine-readable reports, so a small self-contained implementation
//! is used instead (full JSON grammar, no trailing commas, `\uXXXX` escapes
//! supported on input, basic escapes on output).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"digest_base":1000003,"variants":[{"blocks":64,"file":"a.hlo.txt","kind":"plan","lanes":16384,"name":"plan","stripes":12}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.to_string(), src);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""Ab""#).unwrap(), Json::Str("Ab".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn builder_api() {
        let j = Json::obj().set("x", 3i64).set("y", "z");
        assert_eq!(j.to_string(), r#"{"x":3,"y":"z"}"#);
        assert_eq!(j.get("x").unwrap().as_i64(), Some(3));
    }

    #[test]
    fn parses_real_manifest() {
        // shape of artifacts/manifest.json emitted by python/compile/aot.py
        let src = r#"{
  "digest_base": 1000003,
  "variants": [
    {"name": "plan_64x16384_s12", "file": "plan_64x16384_s12.hlo.txt",
     "kind": "plan", "blocks": 64, "lanes": 16384, "stripes": 12}
  ]
}"#;
        let j = Json::parse(src).unwrap();
        let v = &j.get("variants").unwrap().as_arr().unwrap()[0];
        assert_eq!(v.get("blocks").unwrap().as_i64(), Some(64));
        assert_eq!(v.get("kind").unwrap().as_str(), Some("plan"));
    }
}
