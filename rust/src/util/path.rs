//! Virtual path handling for the XUFS name space.
//!
//! XUFS paths are absolute, `/`-separated, rooted at a mount. They never
//! touch the host file system, so `std::path` (platform-dependent) is not
//! used; this module provides normalization, join, split and ancestry
//! helpers with precise semantics the cache/metaq layers rely on
//! (normalized form is the canonical cache key).

/// Normalize a virtual path: collapse `//`, resolve `.` and `..`
/// lexically, ensure a single leading `/`, strip trailing `/` (except root).
pub fn normalize(path: &str) -> String {
    let mut parts: Vec<&str> = Vec::new();
    for seg in path.split('/') {
        match seg {
            "" | "." => {}
            ".." => {
                parts.pop();
            }
            s => parts.push(s),
        }
    }
    if parts.is_empty() {
        "/".to_string()
    } else {
        format!("/{}", parts.join("/"))
    }
}

/// Join a base path and a (possibly relative) component, then normalize.
pub fn join(base: &str, rel: &str) -> String {
    if rel.starts_with('/') {
        normalize(rel)
    } else {
        normalize(&format!("{base}/{rel}"))
    }
}

/// Parent directory of a normalized path (`/` has parent `/`).
pub fn parent(path: &str) -> String {
    let p = normalize(path);
    match p.rfind('/') {
        Some(0) | None => "/".to_string(),
        Some(i) => p[..i].to_string(),
    }
}

/// Final component of a normalized path (empty for root).
pub fn basename(path: &str) -> String {
    let p = normalize(path);
    if p == "/" {
        String::new()
    } else {
        p.rsplit('/').next().unwrap_or("").to_string()
    }
}

/// Iterate the components of a normalized path.
pub fn components(path: &str) -> Vec<String> {
    let p = normalize(path);
    if p == "/" {
        vec![]
    } else {
        p[1..].split('/').map(|s| s.to_string()).collect()
    }
}

/// True if `ancestor` is `descendant` or a path prefix of it
/// (component-wise, so `/a/b` is NOT under `/a/bc`).
pub fn is_under(descendant: &str, ancestor: &str) -> bool {
    let d = normalize(descendant);
    let a = normalize(ancestor);
    if a == "/" {
        return true;
    }
    d == a || d.starts_with(&format!("{a}/"))
}

/// Hidden attribute-file name XUFS stores next to each directory entry
/// (paper §3.1: "stores the directory entry attributes in hidden files
/// alongside the initial empty file entries").
pub fn attr_file_name(entry: &str) -> String {
    format!(".xufs.attr.{entry}")
}

/// True if the name is XUFS cache metadata (hidden from readdir).
pub fn is_hidden_meta(name: &str) -> bool {
    name.starts_with(".xufs.")
}

/// Shadow-file name for an open write handle (paper §3.1: writes land in an
/// internal shadow file, flushed on close).
pub fn shadow_file_name(entry: &str, handle: u64) -> String {
    format!(".xufs.shadow.{handle}.{entry}")
}

/// True if the name is a write-handle shadow file (an orphan of a crash
/// between `pwrite` and `close` — cleaned up by cache recovery; its
/// unmerged bytes are gone per POSIX un-closed-write semantics).
pub fn is_shadow_file(name: &str) -> bool {
    name.starts_with(".xufs.shadow.")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_cases() {
        assert_eq!(normalize("/a/b/c"), "/a/b/c");
        assert_eq!(normalize("a/b"), "/a/b");
        assert_eq!(normalize("/a//b/"), "/a/b");
        assert_eq!(normalize("/a/./b"), "/a/b");
        assert_eq!(normalize("/a/../b"), "/b");
        assert_eq!(normalize("/../.."), "/");
        assert_eq!(normalize(""), "/");
        assert_eq!(normalize("/"), "/");
    }

    #[test]
    fn join_cases() {
        assert_eq!(join("/a/b", "c"), "/a/b/c");
        assert_eq!(join("/a/b", "/x"), "/x");
        assert_eq!(join("/a/b", "../c"), "/a/c");
        assert_eq!(join("/", "x"), "/x");
    }

    #[test]
    fn parent_basename() {
        assert_eq!(parent("/a/b/c"), "/a/b");
        assert_eq!(parent("/a"), "/");
        assert_eq!(parent("/"), "/");
        assert_eq!(basename("/a/b/c"), "c");
        assert_eq!(basename("/"), "");
    }

    #[test]
    fn components_split() {
        assert_eq!(components("/a/b"), vec!["a", "b"]);
        assert!(components("/").is_empty());
    }

    #[test]
    fn under() {
        assert!(is_under("/a/b/c", "/a/b"));
        assert!(is_under("/a/b", "/a/b"));
        assert!(!is_under("/a/bc", "/a/b"));
        assert!(is_under("/anything", "/"));
        assert!(!is_under("/a", "/a/b"));
    }

    #[test]
    fn meta_names() {
        assert_eq!(attr_file_name("f.c"), ".xufs.attr.f.c");
        assert!(is_hidden_meta(".xufs.attr.f.c"));
        assert!(is_hidden_meta(".xufs.shadow.3.f.c"));
        assert!(!is_hidden_meta(".hidden"));
        assert_eq!(shadow_file_name("f.c", 3), ".xufs.shadow.3.f.c");
    }
}
