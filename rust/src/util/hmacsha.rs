//! SHA-256 and HMAC-SHA256 (FIPS 180-4 / RFC 2104), dependency-free.
//!
//! The offline crate set has no `sha2`/`hmac`, so the USSH challenge-
//! response proof ([`crate::auth`]) uses this implementation. Pinned by
//! the FIPS/RFC known-answer vectors in the tests below.

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

fn compress(state: &mut [u32; 8], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut w = [0u32; 64];
    for (i, four) in block.chunks_exact(4).enumerate() {
        w[i] = u32::from_be_bytes([four[0], four[1], four[2], four[3]]);
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h.wrapping_add(s1).wrapping_add(ch).wrapping_add(K[i]).wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
    state[5] = state[5].wrapping_add(f);
    state[6] = state[6].wrapping_add(g);
    state[7] = state[7].wrapping_add(h);
}

/// SHA-256 over the concatenation of `parts`.
pub fn sha256_parts(parts: &[&[u8]]) -> [u8; 32] {
    let mut state = H0;
    let mut buf = [0u8; 64];
    let mut buffered = 0usize;
    let mut total = 0u64;
    for part in parts {
        total += part.len() as u64;
        let mut rest: &[u8] = part;
        if buffered > 0 {
            let take = rest.len().min(64 - buffered);
            buf[buffered..buffered + take].copy_from_slice(&rest[..take]);
            buffered += take;
            rest = &rest[take..];
            if buffered == 64 {
                compress(&mut state, &buf);
                buffered = 0;
            }
            if rest.is_empty() {
                // the whole part fit in the buffer; keep it buffered
                continue;
            }
            // rest is non-empty, so the buffer filled and flushed above
            debug_assert_eq!(buffered, 0);
        }
        let mut chunks = rest.chunks_exact(64);
        for block in &mut chunks {
            compress(&mut state, block);
        }
        let tail = chunks.remainder();
        buf[..tail.len()].copy_from_slice(tail);
        buffered = tail.len();
    }
    // padding: 0x80, zeros, 64-bit big-endian bit length
    let bit_len = total.wrapping_mul(8);
    buf[buffered] = 0x80;
    buffered += 1;
    if buffered > 56 {
        buf[buffered..].fill(0);
        compress(&mut state, &buf);
        buffered = 0;
    }
    buf[buffered..56].fill(0);
    buf[56..].copy_from_slice(&bit_len.to_be_bytes());
    compress(&mut state, &buf);

    let mut out = [0u8; 32];
    for (i, word) in state.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// SHA-256 of one buffer.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    sha256_parts(&[data])
}

/// HMAC-SHA256 of the concatenation of `parts` under `key`.
pub fn hmac_sha256(key: &[u8], parts: &[&[u8]]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0u8; 64];
    let mut opad = [0u8; 64];
    for i in 0..64 {
        ipad[i] = k[i] ^ 0x36;
        opad[i] = k[i] ^ 0x5c;
    }
    let mut inner_parts: Vec<&[u8]> = Vec::with_capacity(parts.len() + 1);
    inner_parts.push(&ipad);
    inner_parts.extend_from_slice(parts);
    let inner = sha256_parts(&inner_parts);
    sha256_parts(&[&opad, &inner])
}

/// Constant-time byte-slice equality (length leak only).
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut diff = 0u8;
    for (x, y) in a.iter().zip(b) {
        diff |= x ^ y;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn fips_known_answers() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq")),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn multi_part_equals_concatenation() {
        let whole = sha256(b"hello world, this spans several parts");
        let parts = sha256_parts(&[b"hello ", b"world, ", b"this spans", b" several parts"]);
        assert_eq!(whole, parts);
        // part boundaries that straddle the 64-byte block boundary
        let a = vec![0xABu8; 61];
        let b = vec![0xCDu8; 130];
        let mut cat = a.clone();
        cat.extend_from_slice(&b);
        assert_eq!(sha256(&cat), sha256_parts(&[&a, &b]));
    }

    #[test]
    fn rfc4231_hmac_case1() {
        let key = [0x0bu8; 20];
        let mac = hmac_sha256(&key, &[b"Hi There"]);
        assert_eq!(
            hex(&mac),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn rfc4231_hmac_case2() {
        let mac = hmac_sha256(b"Jefe", &[b"what do ya want ", b"for nothing?"]);
        assert_eq!(
            hex(&mac),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn long_key_is_hashed_first() {
        let key = vec![0xAAu8; 131];
        // RFC 4231 test case 6
        let mac = hmac_sha256(&key, &[b"Test Using Larger Than Block-Size Key - Hash Key First"]);
        assert_eq!(
            hex(&mac),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn ct_eq_basic() {
        assert!(ct_eq(b"abc", b"abc"));
        assert!(!ct_eq(b"abc", b"abd"));
        assert!(!ct_eq(b"abc", b"ab"));
        assert!(ct_eq(b"", b""));
    }
}
