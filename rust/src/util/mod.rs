//! Shared utilities: deterministic PRNG, minimal JSON, statistics, virtual
//! path handling, SHA-256/HMAC, and a property-test harness (offline
//! stand-ins for `rand`, `serde_json`, `sha2`/`hmac`, and `proptest`,
//! which are unavailable in the vendored crate set — see DESIGN.md §7).

pub mod hmacsha;
pub mod json;
pub mod path;
pub mod prop;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
