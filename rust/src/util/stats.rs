//! Small statistics helpers used by benches and metrics: mean, stddev,
//! percentiles, throughput formatting, and a fixed-boundary histogram.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy. `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Human-readable byte size ("1.5 GiB").
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable duration from seconds ("2m03s", "57.0s", "1.2ms").
pub fn human_secs(s: f64) -> String {
    if s >= 60.0 {
        let m = (s / 60.0).floor();
        format!("{}m{:04.1}s", m as u64, s - m * 60.0)
    } else if s >= 1.0 {
        format!("{s:.1}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Throughput in MiB/s given bytes and seconds.
pub fn mib_per_sec(bytes: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    bytes as f64 / (1024.0 * 1024.0) / secs
}

/// Fixed-boundary histogram (used by metrics for latency distributions).
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
}

impl Histogram {
    /// `bounds` are upper edges (ascending); an overflow bucket is implicit.
    pub fn new(bounds: Vec<f64>) -> Self {
        let n = bounds.len();
        Histogram { bounds, counts: vec![0; n + 1], total: 0, sum: 0.0 }
    }

    /// Exponential boundaries `start * factor^i` for `n` buckets.
    pub fn exponential(start: f64, factor: f64, n: usize) -> Self {
        let mut b = Vec::with_capacity(n);
        let mut v = start;
        for _ in 0..n {
            b.push(v);
            v *= factor;
        }
        Self::new(b)
    }

    pub fn record(&mut self, x: f64) {
        let idx = self.bounds.partition_point(|&b| b < x);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    *self.bounds.last().unwrap_or(&0.0)
                };
            }
        }
        *self.bounds.last().unwrap_or(&0.0)
    }

    pub fn buckets(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.bounds.iter().copied().zip(self.counts.iter().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn humanize() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(1 << 30), "1.00 GiB");
        assert_eq!(human_secs(125.0), "2m05.0s");
        assert_eq!(human_secs(57.0), "57.0s");
        assert_eq!(human_secs(0.0012), "1.2ms");
    }

    #[test]
    fn throughput() {
        assert!((mib_per_sec(1 << 20, 1.0) - 1.0).abs() < 1e-12);
        assert_eq!(mib_per_sec(100, 0.0), 0.0);
    }

    #[test]
    fn histogram_basics() {
        let mut h = Histogram::exponential(1.0, 2.0, 8); // 1,2,4,...,128
        for x in [0.5, 1.5, 3.0, 100.0, 1000.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() > 0.0);
        assert!(h.quantile(0.5) >= 1.0);
        let total: u64 = h.buckets().map(|(_, c)| c).sum();
        assert_eq!(total + 1, 5); // one value in the overflow bucket
    }
}
