//! A miniature property-testing harness.
//!
//! `proptest` is not in the offline crate set, so coordinator invariants are
//! checked with this harness instead: run a predicate over many seeded
//! random cases; on failure, retry with progressively simpler size hints
//! (a lightweight stand-in for shrinking) and report the *seed* so the case
//! is exactly reproducible.
//!
//! ```ignore
//! prop::check(256, |rng, size| {
//!     let n = rng.range(1, size as u64) as usize;
//!     /* build a case of complexity n, return Err(msg) on violation */
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` seeded cases of property `f`. `f` receives a fresh RNG and a
/// size hint that grows from small to large across the run (so early cases
/// are naturally "shrunk"). Panics with the failing seed + message.
pub fn check<F>(cases: usize, mut f: F)
where
    F: FnMut(&mut Rng, usize) -> CaseResult,
{
    check_seeded(0xDEAD_BEEF, cases, &mut f);
}

/// As [`check`] but with an explicit base seed (to pin a reproduction).
pub fn check_seeded<F>(base_seed: u64, cases: usize, f: &mut F)
where
    F: FnMut(&mut Rng, usize) -> CaseResult,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // size ramps 1..=64 over the run; later cases are bigger
        let size = 1 + (case * 64) / cases.max(1);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng, size) {
            // "shrink": replay the same seed at smaller size hints and report
            // the smallest size that still fails.
            let mut min_fail = size;
            for s in (1..size).rev() {
                let mut r2 = Rng::new(seed);
                if f(&mut r2, s).is_err() {
                    min_fail = s;
                } else {
                    break;
                }
            }
            panic!(
                "property failed (case {case}, seed {seed:#x}, size {size}, min failing size {min_fail}): {msg}"
            );
        }
    }
}

/// Assert-like helper producing the Err(String) the harness expects.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Assert two values are equal, reporting both on failure.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(50, |rng, size| {
            n += 1;
            let x = rng.range(0, size as u64);
            prop_assert!(x <= size as u64);
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(50, |rng, _| {
            let x = rng.below(100);
            prop_assert!(x < 90, "x={x}");
            Ok(())
        });
    }

    #[test]
    fn size_hint_ramps() {
        let mut sizes = Vec::new();
        check(64, |_, size| {
            sizes.push(size);
            Ok(())
        });
        assert!(sizes.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*sizes.first().unwrap(), 1);
        assert!(*sizes.last().unwrap() >= 60);
    }
}
