//! USSH security framework (paper §3.2).
//!
//! When the user logs into a site, USSH generates a short-lived secret
//! `<key, phrase>` pair, starts the personal file server, and plants the
//! pair in the remote session environment. Every subsequent TCP connection
//! authenticates with an **encrypted challenge string**: the server sends
//! a random nonce, the client proves knowledge of the phrase with
//! HMAC-SHA256(phrase, nonce ‖ key-id), and the server verifies in
//! constant time. Nonces are single-use (replay defense); pairs expire.

use crate::simnet::VirtualTime;
use crate::util::{hmacsha, Rng};

/// A short-lived `<key, phrase>` credential (paper: generated per login).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KeyPair {
    /// Public identifier presented in AuthHello.
    pub key_id: String,
    /// Secret phrase; never crosses the wire.
    pub phrase: [u8; 32],
    /// Expiry; servers refuse expired pairs.
    pub expires: VirtualTime,
}

impl KeyPair {
    /// Generate a fresh pair valid for `ttl_s` seconds from `now`.
    pub fn generate(rng: &mut Rng, now: VirtualTime, ttl_s: f64) -> KeyPair {
        let mut phrase = [0u8; 32];
        rng.fill_bytes(&mut phrase);
        KeyPair { key_id: format!("ussh-{}", rng.alnum(16)), phrase, expires: now.add_secs(ttl_s) }
    }
}

/// Compute the client-side proof for a challenge.
pub fn prove(phrase: &[u8; 32], key_id: &str, nonce: &[u8]) -> Vec<u8> {
    hmacsha::hmac_sha256(phrase, &[nonce, key_id.as_bytes()]).to_vec()
}

/// Constant-time proof verification.
pub fn verify(phrase: &[u8; 32], key_id: &str, nonce: &[u8], proof: &[u8]) -> bool {
    let expect = hmacsha::hmac_sha256(phrase, &[nonce, key_id.as_bytes()]);
    hmacsha::ct_eq(&expect, proof)
}

/// Server-side authenticator: issues single-use challenges and validates
/// proofs against the registered key pair.
#[derive(Debug)]
pub struct Authenticator {
    pair: KeyPair,
    rng: Rng,
    /// Outstanding nonces (single-use).
    pending: Vec<Vec<u8>>,
    next_session: u64,
}

impl Authenticator {
    pub fn new(pair: KeyPair, seed: u64) -> Self {
        Authenticator { pair, rng: Rng::new(seed), pending: Vec::new(), next_session: 1 }
    }

    pub fn key_id(&self) -> &str {
        &self.pair.key_id
    }

    /// Step 1: issue a 32-byte nonce for `key_id` (any id gets a nonce so
    /// probing can't distinguish valid ids).
    pub fn challenge(&mut self, _key_id: &str) -> Vec<u8> {
        let mut nonce = vec![0u8; 32];
        self.rng.fill_bytes(&mut nonce);
        self.pending.push(nonce.clone());
        nonce
    }

    /// Step 2: validate the proof. Consumes the nonce whether or not the
    /// proof verifies (single-use). Returns a session id on success.
    pub fn verify_proof(&mut self, key_id: &str, proof: &[u8], now: VirtualTime) -> Option<u64> {
        if now > self.pair.expires || key_id != self.pair.key_id {
            // still consume one pending nonce to keep behaviour uniform
            self.pending.pop();
            return None;
        }
        // find the nonce this proof matches; remove it regardless
        let mut matched = None;
        for (i, nonce) in self.pending.iter().enumerate() {
            if verify(&self.pair.phrase, key_id, nonce, proof) {
                matched = Some(i);
                break;
            }
        }
        match matched {
            Some(i) => {
                self.pending.remove(i);
                let s = self.next_session;
                self.next_session += 1;
                Some(s)
            }
            None => {
                self.pending.pop();
                None
            }
        }
    }

    /// Number of outstanding challenges (test/diagnostic).
    pub fn pending_challenges(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> KeyPair {
        let mut rng = Rng::new(1);
        KeyPair::generate(&mut rng, VirtualTime::ZERO, 3600.0)
    }

    #[test]
    fn happy_path() {
        let p = pair();
        let mut auth = Authenticator::new(p.clone(), 2);
        let nonce = auth.challenge(&p.key_id);
        let proof = prove(&p.phrase, &p.key_id, &nonce);
        let session = auth.verify_proof(&p.key_id, &proof, VirtualTime::from_secs(1.0));
        assert!(session.is_some());
        assert_eq!(auth.pending_challenges(), 0);
    }

    #[test]
    fn wrong_phrase_rejected() {
        let p = pair();
        let mut auth = Authenticator::new(p.clone(), 2);
        let nonce = auth.challenge(&p.key_id);
        let mut bad = p.phrase;
        bad[0] ^= 1;
        let proof = prove(&bad, &p.key_id, &nonce);
        assert!(auth.verify_proof(&p.key_id, &proof, VirtualTime::from_secs(1.0)).is_none());
    }

    #[test]
    fn wrong_key_id_rejected() {
        let p = pair();
        let mut auth = Authenticator::new(p.clone(), 2);
        let nonce = auth.challenge("ussh-intruder");
        let proof = prove(&p.phrase, "ussh-intruder", &nonce);
        assert!(auth.verify_proof("ussh-intruder", &proof, VirtualTime::from_secs(1.0)).is_none());
    }

    #[test]
    fn nonce_single_use() {
        let p = pair();
        let mut auth = Authenticator::new(p.clone(), 2);
        let nonce = auth.challenge(&p.key_id);
        let proof = prove(&p.phrase, &p.key_id, &nonce);
        assert!(auth.verify_proof(&p.key_id, &proof, VirtualTime::from_secs(1.0)).is_some());
        // replaying the same proof fails: nonce was consumed
        assert!(auth.verify_proof(&p.key_id, &proof, VirtualTime::from_secs(1.0)).is_none());
    }

    #[test]
    fn expired_pair_rejected() {
        let p = pair(); // ttl 3600s
        let mut auth = Authenticator::new(p.clone(), 2);
        let nonce = auth.challenge(&p.key_id);
        let proof = prove(&p.phrase, &p.key_id, &nonce);
        assert!(auth.verify_proof(&p.key_id, &proof, VirtualTime::from_secs(4000.0)).is_none());
    }

    #[test]
    fn sessions_unique() {
        let p = pair();
        let mut auth = Authenticator::new(p.clone(), 2);
        let mut sessions = Vec::new();
        for _ in 0..3 {
            let nonce = auth.challenge(&p.key_id);
            let proof = prove(&p.phrase, &p.key_id, &nonce);
            sessions.push(auth.verify_proof(&p.key_id, &proof, VirtualTime::ZERO).unwrap());
        }
        sessions.dedup();
        assert_eq!(sessions.len(), 3);
    }

    #[test]
    fn distinct_pairs_distinct_ids() {
        let mut rng = Rng::new(5);
        let a = KeyPair::generate(&mut rng, VirtualTime::ZERO, 10.0);
        let b = KeyPair::generate(&mut rng, VirtualTime::ZERO, 10.0);
        assert_ne!(a.key_id, b.key_id);
        assert_ne!(a.phrase, b.phrase);
    }
}
