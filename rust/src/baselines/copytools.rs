//! The copy commands of Table 2: TGCP (a GridFTP client — striped,
//! unencrypted) and SCP (single TCP stream, cipher-rate-bound). Both model
//! "copy the file to local disk, then operate on it locally", which is
//! what the paper's users did before XUFS (§1: SCP was the most important
//! data management tool on the 2005 TeraGrid).

use std::sync::Arc;

use crate::config::StripeConfig;
use crate::simnet::{Clock, SimClock, TransferKind, Wan};
use crate::vdisk::DiskModel;

/// TGCP: GridFTP-style striped copy. Same stripe policy as XUFS but no
/// cache bookkeeping, no digest verification, no metadata materialization
/// — the lower bound for moving bytes with striping.
pub struct Tgcp {
    pub wan: Arc<Wan>,
    pub clock: Arc<SimClock>,
    pub local_disk: DiskModel,
    pub stripe: StripeConfig,
}

impl Tgcp {
    pub fn new(wan: Arc<Wan>, clock: Arc<SimClock>, local_disk: DiskModel, stripe: StripeConfig) -> Self {
        Tgcp { wan, clock, local_disk, stripe }
    }

    /// Copy `bytes` from the remote site to local disk; returns elapsed
    /// seconds.
    pub fn copy(&self, bytes: u64) -> f64 {
        let t0 = self.clock.now();
        // control-channel setup + auth (GridFTP control connection)
        self.wan.connect(self.clock.as_ref());
        self.wan.rpc(self.clock.as_ref(), 256, 256);
        let stripes = crate::transfer::stripes_for(bytes, &self.stripe);
        self.wan.transfer(self.clock.as_ref(), bytes, stripes, TransferKind::NewConnections);
        // land it on the local parallel FS
        self.local_disk.io(self.clock.as_ref(), bytes);
        self.clock.now().saturating_sub(t0).as_secs()
    }
}

/// SCP: one TCP stream and a CPU-bound cipher. The paper measured 2100 s
/// for 1 GiB — a ~0.5 MiB/s effective rate (encryption + no striping).
pub struct Scp {
    pub wan: Arc<Wan>,
    pub clock: Arc<SimClock>,
    pub local_disk: DiskModel,
    /// Cipher throughput cap (2005-era 3DES/AES on a workstation).
    pub cipher_bps: f64,
}

impl Scp {
    pub fn new(wan: Arc<Wan>, clock: Arc<SimClock>, local_disk: DiskModel, cipher_bps: f64) -> Self {
        Scp { wan, clock, local_disk, cipher_bps }
    }

    /// Copy `bytes`; returns elapsed seconds. Rate = min(single-stream
    /// TCP bound, cipher rate).
    pub fn copy(&self, bytes: u64) -> f64 {
        let t0 = self.clock.now();
        self.wan.connect(self.clock.as_ref());
        // ssh key exchange: a few round trips
        self.wan.rpc(self.clock.as_ref(), 512, 512);
        self.wan.rpc(self.clock.as_ref(), 256, 256);
        let stream_bps = self.wan.stream_rate(1);
        let effective = stream_bps.min(self.cipher_bps);
        self.clock.advance_secs(bytes as f64 / effective);
        self.local_disk.io(self.clock.as_ref(), bytes);
        self.clock.now().saturating_sub(t0).as_secs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{WanConfig, XufsConfig};

    fn rig() -> (Arc<SimClock>, Arc<Wan>, DiskModel) {
        let clock = Arc::new(SimClock::new());
        let wan = Arc::new(Wan::new(WanConfig::default(), (*clock).clone()));
        (clock, wan, DiskModel::new(400.0 * 1024.0 * 1024.0, 0.002))
    }

    #[test]
    fn tgcp_1gib_near_paper_49s() {
        let (clock, wan, disk) = rig();
        let t = Tgcp::new(wan, clock, disk, StripeConfig::default());
        let secs = t.copy(1 << 30);
        // paper Table 2: 49 s
        assert!((42.0..55.0).contains(&secs), "secs={secs}");
    }

    #[test]
    fn scp_1gib_near_paper_2100s() {
        let (clock, wan, disk) = rig();
        let s = Scp::new(wan, clock, disk, XufsConfig::scp_cipher_bps());
        let secs = s.copy(1 << 30);
        // paper Table 2: 2100 s
        assert!((1900.0..2300.0).contains(&secs), "secs={secs}");
    }

    #[test]
    fn tgcp_beats_scp_by_40x() {
        let (clock, wan, disk) = rig();
        let t = Tgcp::new(wan.clone(), clock.clone(), disk.clone(), StripeConfig::default());
        let s = Scp::new(wan, clock, disk, XufsConfig::scp_cipher_bps());
        let ratio = s.copy(256 << 20) / t.copy(256 << 20);
        assert!(ratio > 30.0, "ratio={ratio}");
    }
}
