//! Local parallel-FS baseline: direct access to a site file system (the
//! "local GPFS" series in Figs. 4–5). No WAN anywhere — this is the
//! upper bound every distributed system chases.

use std::collections::HashMap;
use std::sync::Arc;

use crate::client::{Fd, OpenFlags, Vfs};
use crate::homefs::{FileStore, FsError};
use crate::proto::{LockKind, WireAttr};
use crate::simnet::{Clock, VirtualTime};
use crate::util::path as vpath;
use crate::vdisk::DiskModel;

#[derive(Debug)]
struct OpenFile {
    path: String,
    /// Sequential cursor backing the `read`/`write` defaults.
    pos: u64,
    flags: OpenFlags,
}

/// A [`Vfs`] straight onto a [`FileStore`] + [`DiskModel`].
pub struct LocalFs {
    pub fs: FileStore,
    disk: DiskModel,
    clock: Arc<dyn Clock>,
    fds: HashMap<u64, OpenFile>,
    locks: HashMap<String, (u64, LockKind)>,
    next_fd: u64,
    cwd: String,
}

impl LocalFs {
    pub fn new(fs: FileStore, disk: DiskModel, clock: Arc<dyn Clock>) -> Self {
        LocalFs { fs, disk, clock, fds: HashMap::new(), locks: HashMap::new(), next_fd: 3, cwd: "/".into() }
    }

    fn abs(&self, path: &str) -> String {
        vpath::join(&self.cwd, path)
    }
}

impl Vfs for LocalFs {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd, FsError> {
        let flags = flags.validate()?;
        let p = self.abs(path);
        let now = self.clock.now();
        self.disk.op(self.clock.as_ref());
        if !self.fs.exists(&p) {
            if !flags.is_create() {
                return Err(FsError::NotFound(p));
            }
            self.fs.mkdir_p(&vpath::parent(&p), now)?;
            self.fs.create(&p, now)?;
        } else if flags.is_truncate() {
            self.fs.truncate(&p, 0, now)?;
        }
        let pos = if flags.is_append() { self.fs.stat(&p)?.size } else { 0 };
        let fd = self.next_fd;
        self.next_fd += 1;
        self.fds.insert(fd, OpenFile { path: p, pos, flags });
        Ok(Fd(fd))
    }

    fn pread(&mut self, fd: Fd, buf: &mut [u8], off: u64) -> Result<usize, FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        let n = {
            let data = self.fs.read_at(&f.path, off, buf.len())?;
            buf[..data.len()].copy_from_slice(&data);
            data.len()
        };
        self.disk.io(self.clock.as_ref(), n as u64);
        Ok(n)
    }

    fn pwrite(&mut self, fd: Fd, buf: &[u8], off: u64) -> Result<usize, FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        if !f.flags.is_write() {
            return Err(FsError::Perm("fd not open for writing".into()));
        }
        let path = f.path.clone();
        let now = self.clock.now();
        self.fs.write_at(&path, off, buf, now)?;
        self.disk.io(self.clock.as_ref(), buf.len() as u64);
        Ok(buf.len())
    }

    fn seek(&mut self, fd: Fd, pos: u64) -> Result<(), FsError> {
        self.fds.get_mut(&fd.0).ok_or(FsError::BadHandle)?.pos = pos;
        Ok(())
    }

    fn tell(&self, fd: Fd) -> Result<u64, FsError> {
        self.fds.get(&fd.0).map(|f| f.pos).ok_or(FsError::BadHandle)
    }

    fn close(&mut self, fd: Fd) -> Result<(), FsError> {
        let f = self.fds.remove(&fd.0).ok_or(FsError::BadHandle)?;
        self.locks.retain(|_, (lfd, _)| *lfd != fd.0);
        self.disk.op(self.clock.as_ref());
        let _ = f;
        Ok(())
    }

    fn stat(&mut self, path: &str) -> Result<WireAttr, FsError> {
        let p = self.abs(path);
        self.disk.op(self.clock.as_ref());
        Ok(WireAttr::from_attr(&self.fs.stat(&p)?))
    }

    fn readdir(&mut self, path: &str) -> Result<Vec<(String, WireAttr)>, FsError> {
        let p = self.abs(path);
        self.disk.op(self.clock.as_ref());
        Ok(self
            .fs
            .readdir(&p)?
            .into_iter()
            .map(|(n, a)| (n, WireAttr::from_attr(&a)))
            .collect())
    }

    fn chdir(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        self.disk.op(self.clock.as_ref());
        match self.fs.stat(&p)?.kind {
            crate::homefs::NodeKind::Dir => {
                self.cwd = p;
                Ok(())
            }
            _ => Err(FsError::NotADir(p)),
        }
    }

    fn mkdir(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.disk.op(self.clock.as_ref());
        self.fs.mkdir_p(&p, now).map(|_| ())
    }

    fn unlink(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.disk.op(self.clock.as_ref());
        self.fs.unlink(&p, now)
    }

    fn rename(&mut self, from: &str, to: &str) -> Result<(), FsError> {
        let (f, t) = (self.abs(from), self.abs(to));
        let now = self.clock.now();
        self.disk.op(self.clock.as_ref());
        self.fs.rename(&f, &t, now)
    }

    fn truncate(&mut self, path: &str, size: u64) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.disk.op(self.clock.as_ref());
        self.fs.truncate(&p, size, now)
    }

    fn lock(&mut self, fd: Fd, kind: LockKind) -> Result<(), FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        let path = f.path.clone();
        if let Some((ofd, okind)) = self.locks.get(&path) {
            let compatible = *ofd == fd.0
                || (matches!(okind, LockKind::Shared) && matches!(kind, LockKind::Shared));
            if !compatible {
                return Err(FsError::LockConflict(path));
            }
        }
        self.locks.insert(path, (fd.0, kind));
        Ok(())
    }

    fn unlock(&mut self, fd: Fd) -> Result<(), FsError> {
        self.locks.retain(|_, (lfd, _)| *lfd != fd.0);
        Ok(())
    }

    fn fsync(&mut self) -> Result<(), FsError> {
        Ok(())
    }

    fn now(&self) -> VirtualTime {
        self.clock.now()
    }

    fn think(&mut self, secs: f64) {
        self.clock.advance_secs(secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::SimClock;

    fn local() -> LocalFs {
        let clock = Arc::new(SimClock::new());
        LocalFs::new(FileStore::default(), DiskModel::new(400.0e6, 0.002), clock)
    }

    #[test]
    fn write_read_roundtrip() {
        let mut l = local();
        l.write_file("/a/b.txt", b"hello", 4).unwrap();
        assert_eq!(l.scan_file("/a/b.txt", 2).unwrap(), 5);
        assert_eq!(l.stat("/a/b.txt").unwrap().size, 5);
    }

    #[test]
    fn timing_is_local_speed() {
        let mut l = local();
        l.write_file("/big", &vec![0u8; 100 << 20], 1 << 20).unwrap();
        let t0 = l.now();
        l.scan_file("/big", 1 << 20).unwrap();
        let dt = l.now().saturating_sub(t0).as_secs();
        // 100 MiB at 400 MB/s + per-op costs: well under a second
        assert!(dt < 1.0, "dt={dt}");
    }

    #[test]
    fn locks_conflict_locally() {
        let mut l = local();
        l.write_file("/f", b"x", 4).unwrap();
        let fd1 = l.open("/f", OpenFlags::rdwr()).unwrap();
        let fd2 = l.open("/f", OpenFlags::rdwr()).unwrap();
        l.lock(fd1, LockKind::Exclusive).unwrap();
        assert!(matches!(l.lock(fd2, LockKind::Exclusive), Err(FsError::LockConflict(_))));
        l.close(fd1).unwrap();
        l.lock(fd2, LockKind::Exclusive).unwrap();
    }

    #[test]
    fn chdir_relative_paths() {
        let mut l = local();
        l.mkdir("/w/src").unwrap();
        l.chdir("/w/src").unwrap();
        l.write_file("main.c", b"int main;", 64).unwrap();
        assert!(l.fs.exists("/w/src/main.c"));
    }
}
