//! NFS/Jade-style check-on-open client (paper §5): instead of callback
//! invalidation, the client revalidates content versions with the server
//! on **every open** — the consistency protocol XUFS explicitly rejects.
//! Used by the `ablations` bench to quantify what the callback protocol
//! saves in WAN round trips for open-heavy workloads (builds).

use std::collections::HashMap;
use std::sync::Arc;

use crate::client::{Fd, OpenFlags, Vfs};
use crate::homefs::{FileStore, FsError, NodeKind};
use crate::proto::{LockKind, WireAttr};
use crate::simnet::{Clock, SimClock, VirtualTime, Wan};
use crate::vdisk::DiskModel;
use crate::util::path as vpath;

#[derive(Debug)]
struct OpenFile {
    path: String,
    pos: u64,
    flags: OpenFlags,
    dirty: bool,
}

#[derive(Debug, Clone)]
struct CacheRec {
    version: u64,
}

/// Check-on-open whole-file-caching client.
pub struct NfsClient {
    /// Authoritative remote store.
    pub remote: FileStore,
    /// Local whole-file cache (like XUFS's cache space).
    cache: FileStore,
    cache_meta: HashMap<String, CacheRec>,
    clock: Arc<SimClock>,
    wan: Arc<Wan>,
    disk: DiskModel,
    stripes: usize,
    fds: HashMap<u64, OpenFile>,
    next_fd: u64,
    cwd: String,
    /// WAN round trips spent on open-time revalidation (the ablation
    /// metric).
    pub revalidation_rpcs: u64,
}

impl NfsClient {
    pub fn new(remote: FileStore, clock: Arc<SimClock>, wan: Arc<Wan>, disk: DiskModel, stripes: usize) -> Self {
        NfsClient {
            remote,
            cache: FileStore::default(),
            cache_meta: HashMap::new(),
            clock,
            wan,
            disk,
            stripes,
            fds: HashMap::new(),
            next_fd: 3,
            cwd: "/".into(),
            revalidation_rpcs: 0,
        }
    }

    fn abs(&self, path: &str) -> String {
        vpath::join(&self.cwd, path)
    }

    fn revalidate(&mut self, path: &str) -> Result<Option<u64>, FsError> {
        // GETATTR on every open — the protocol cost under study
        self.wan.rpc(self.clock.as_ref(), 64, 96);
        self.revalidation_rpcs += 1;
        match self.remote.stat(path) {
            Ok(a) => Ok(Some(a.version)),
            Err(FsError::NotFound(_)) => Ok(None),
            Err(e) => Err(e),
        }
    }
}

impl Vfs for NfsClient {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd, FsError> {
        let flags = flags.validate()?;
        let p = self.abs(path);
        let now = self.clock.now();
        let remote_version = self.revalidate(&p)?;
        match remote_version {
            None => {
                if !flags.is_create() {
                    return Err(FsError::NotFound(p));
                }
                self.remote.mkdir_p(&vpath::parent(&p), now)?;
                self.remote.create(&p, now)?;
                self.cache.mkdir_p(&vpath::parent(&p), now)?;
                self.cache.write(&p, &[], now)?;
                self.cache_meta.insert(p.clone(), CacheRec { version: 1 });
            }
            Some(v) => {
                let cached_ok =
                    self.cache_meta.get(&p).map(|r| r.version == v).unwrap_or(false);
                if !cached_ok && !flags.is_truncate() {
                    // fetch whole file, striped
                    let data = self.remote.read(&p)?.to_vec();
                    self.wan.transfer(
                        self.clock.as_ref(),
                        data.len() as u64,
                        self.stripes,
                        crate::simnet::TransferKind::NewConnections,
                    );
                    self.disk.io(self.clock.as_ref(), data.len() as u64);
                    self.cache.mkdir_p(&vpath::parent(&p), now)?;
                    self.cache.write(&p, &data, now)?;
                    self.cache_meta.insert(p.clone(), CacheRec { version: v });
                } else if flags.is_truncate() {
                    self.cache.mkdir_p(&vpath::parent(&p), now)?;
                    self.cache.write(&p, &[], now)?;
                    self.cache_meta.insert(p.clone(), CacheRec { version: v });
                }
            }
        }
        let pos = if flags.is_append() { self.cache.stat(&p)?.size } else { 0 };
        let fd = self.next_fd;
        self.next_fd += 1;
        self.fds.insert(fd, OpenFile { path: p, pos, flags, dirty: false });
        Ok(Fd(fd))
    }

    fn pread(&mut self, fd: Fd, buf: &mut [u8], off: u64) -> Result<usize, FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        let path = f.path.clone();
        let n = {
            let data = self.cache.read_at(&path, off, buf.len())?;
            buf[..data.len()].copy_from_slice(&data);
            data.len()
        };
        self.disk.io(self.clock.as_ref(), n as u64);
        Ok(n)
    }

    fn pwrite(&mut self, fd: Fd, buf: &[u8], off: u64) -> Result<usize, FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        if !f.flags.is_write() {
            return Err(FsError::Perm("fd not open for writing".into()));
        }
        let path = f.path.clone();
        let now = self.clock.now();
        self.cache.write_at(&path, off, buf, now)?;
        self.disk.io(self.clock.as_ref(), buf.len() as u64);
        self.fds.get_mut(&fd.0).unwrap().dirty = true;
        Ok(buf.len())
    }

    fn seek(&mut self, fd: Fd, pos: u64) -> Result<(), FsError> {
        self.fds.get_mut(&fd.0).ok_or(FsError::BadHandle)?.pos = pos;
        Ok(())
    }

    fn tell(&self, fd: Fd) -> Result<u64, FsError> {
        self.fds.get(&fd.0).map(|f| f.pos).ok_or(FsError::BadHandle)
    }

    fn close(&mut self, fd: Fd) -> Result<(), FsError> {
        let f = self.fds.remove(&fd.0).ok_or(FsError::BadHandle)?;
        if f.dirty {
            // write back whole file on close (NFS close-to-open)
            let data = self.cache.read(&f.path)?.to_vec();
            let now = self.clock.now();
            self.wan.transfer(
                self.clock.as_ref(),
                data.len() as u64,
                self.stripes,
                crate::simnet::TransferKind::NewConnections,
            );
            self.remote.mkdir_p(&vpath::parent(&f.path), now)?;
            self.remote.write(&f.path, &data, now)?;
            let v = self.remote.stat(&f.path)?.version;
            self.cache_meta.insert(f.path.clone(), CacheRec { version: v });
        }
        Ok(())
    }

    fn stat(&mut self, path: &str) -> Result<WireAttr, FsError> {
        let p = self.abs(path);
        // attribute cache: NFS-style 3s TTL would apply; the ablation runs
        // are longer than the TTL, so model every stat as a GETATTR
        self.wan.rpc(self.clock.as_ref(), 64, 96);
        self.revalidation_rpcs += 1;
        Ok(WireAttr::from_attr(&self.remote.stat(&p)?))
    }

    fn readdir(&mut self, path: &str) -> Result<Vec<(String, WireAttr)>, FsError> {
        let p = self.abs(path);
        self.wan.rpc(self.clock.as_ref(), 64, 4096);
        Ok(self
            .remote
            .readdir(&p)?
            .into_iter()
            .map(|(n, a)| (n, WireAttr::from_attr(&a)))
            .collect())
    }

    fn chdir(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        self.wan.rpc(self.clock.as_ref(), 64, 96);
        match self.remote.stat(&p)?.kind {
            NodeKind::Dir => {
                self.cwd = p;
                Ok(())
            }
            _ => Err(FsError::NotADir(p)),
        }
    }

    fn mkdir(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.wan.rpc(self.clock.as_ref(), 64, 64);
        self.cache.mkdir_p(&p, now)?;
        self.remote.mkdir_p(&p, now).map(|_| ())
    }

    fn unlink(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.wan.rpc(self.clock.as_ref(), 64, 64);
        let _ = self.cache.unlink(&p, now);
        self.cache_meta.remove(&p);
        self.remote.unlink(&p, now)
    }

    fn rename(&mut self, from: &str, to: &str) -> Result<(), FsError> {
        let (f, t) = (self.abs(from), self.abs(to));
        let now = self.clock.now();
        self.wan.rpc(self.clock.as_ref(), 96, 64);
        let _ = self.cache.rename(&f, &t, now);
        self.cache_meta.remove(&f);
        self.remote.rename(&f, &t, now)
    }

    fn truncate(&mut self, path: &str, size: u64) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.wan.rpc(self.clock.as_ref(), 64, 64);
        let _ = self.cache.truncate(&p, size, now);
        self.remote.truncate(&p, size, now)
    }

    fn lock(&mut self, _fd: Fd, _kind: LockKind) -> Result<(), FsError> {
        self.wan.rpc(self.clock.as_ref(), 64, 64);
        Ok(())
    }

    fn unlock(&mut self, _fd: Fd) -> Result<(), FsError> {
        self.wan.rpc(self.clock.as_ref(), 64, 64);
        Ok(())
    }

    fn fsync(&mut self) -> Result<(), FsError> {
        Ok(())
    }

    fn now(&self) -> VirtualTime {
        self.clock.now()
    }

    fn think(&mut self, secs: f64) {
        self.clock.advance_secs(secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WanConfig;

    fn nfs_with(data: &[(&str, usize)]) -> NfsClient {
        let clock = Arc::new(SimClock::new());
        let wan = Arc::new(Wan::new(WanConfig::default(), (*clock).clone()));
        let mut fs = FileStore::default();
        for (p, n) in data {
            fs.mkdir_p(&vpath::parent(p), VirtualTime::ZERO).unwrap();
            fs.write(p, &vec![3u8; *n], VirtualTime::ZERO).unwrap();
        }
        NfsClient::new(fs, clock, wan, DiskModel::new(400.0e6, 0.002), 1)
    }

    #[test]
    fn every_open_costs_a_round_trip() {
        let mut n = nfs_with(&[("/f", 1000)]);
        n.scan_file("/f", 512).unwrap();
        n.scan_file("/f", 512).unwrap();
        n.scan_file("/f", 512).unwrap();
        assert_eq!(n.revalidation_rpcs, 3, "one GETATTR per open");
    }

    #[test]
    fn unchanged_file_not_refetched() {
        let mut n = nfs_with(&[("/f", 4 << 20)]);
        let t0 = n.now();
        n.scan_file("/f", 1 << 20).unwrap();
        let cold = n.now().saturating_sub(t0).as_secs();
        let t1 = n.now();
        n.scan_file("/f", 1 << 20).unwrap();
        let warm = n.now().saturating_sub(t1).as_secs();
        assert!(warm < cold / 3.0, "cached but revalidated: warm={warm} cold={cold}");
        assert!(warm > 0.03, "still pays the open round trip");
    }

    #[test]
    fn changed_file_refetched() {
        let mut n = nfs_with(&[("/f", 1 << 20)]);
        n.scan_file("/f", 1 << 20).unwrap();
        n.remote.write("/f", &vec![9u8; 1 << 20], VirtualTime::from_secs(100.0)).unwrap();
        let fd = n.open("/f", OpenFlags::rdonly()).unwrap();
        let mut d = [0u8; 16];
        assert_eq!(n.read(fd, &mut d).unwrap(), 16);
        n.close(fd).unwrap();
        assert_eq!(d, [9u8; 16]);
    }

    #[test]
    fn write_back_on_close() {
        let mut n = nfs_with(&[]);
        n.write_file("/out.txt", b"result", 64).unwrap();
        assert_eq!(n.remote.read("/out.txt").unwrap(), b"result");
    }
}
