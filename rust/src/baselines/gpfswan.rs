//! GPFS-WAN baseline (paper §1, §4): the production wide-area parallel
//! file system XUFS is evaluated against.
//!
//! Behavioural model (DESIGN.md §2): block-granular remote access over the
//! WAN with server-side parallel stripe service (effective ~31 MiB/s in
//! the paper's testbed — 1 GiB scans take a constant ~33 s), a client
//! memory page pool with write-behind that absorbs small writes at memory
//! speed (the paper's Fig. 2 spike at 1 MiB), and token-based consistency
//! (a token RPC on open, cache demoted on close when tokens are released).
//! There is **no whole-file on-disk cache** — every fresh open reads
//! blocks over the WAN again, which is exactly the behaviour Fig. 5
//! exposes against XUFS's cache-local re-reads.

use std::collections::HashMap;
use std::sync::Arc;

use crate::client::{Fd, OpenFlags, Vfs};
use crate::homefs::{FileStore, FsError, NodeKind};
use crate::proto::{LockKind, WireAttr};
use crate::simnet::{Clock, SimClock, VirtualTime};
use crate::util::path as vpath;

/// Model parameters (defaults = DESIGN.md §5 calibration).
#[derive(Debug, Clone)]
pub struct GpfsWanParams {
    /// Effective WAN block-read throughput (parallel block streams).
    pub read_bps: f64,
    /// Effective WAN write-behind drain throughput.
    pub write_bps: f64,
    /// Client page-pool absorb rate (memory speed).
    pub mem_bps: f64,
    /// Page-pool capacity: writes up to this much are absorbed before the
    /// drain rate throttles the application.
    pub pagepool: u64,
    /// GPFS block size.
    pub block: u64,
    /// Metadata / token RPC cost (one WAN round trip).
    pub rtt_s: f64,
}

impl Default for GpfsWanParams {
    fn default() -> Self {
        GpfsWanParams {
            read_bps: 31.0 * 1024.0 * 1024.0,
            write_bps: 31.0 * 1024.0 * 1024.0,
            mem_bps: 600.0 * 1024.0 * 1024.0,
            pagepool: 64 << 20,
            block: 256 * 1024,
            rtt_s: 0.032,
        }
    }
}

#[derive(Debug)]
struct OpenFile {
    path: String,
    pos: u64,
    flags: OpenFlags,
    /// Bytes absorbed by write-behind not yet drained (flushed on close).
    undrained: u64,
}

/// The GPFS-WAN client model.
pub struct GpfsWan {
    /// Authoritative store at the remote home site (SDSC in the paper).
    pub remote: FileStore,
    params: GpfsWanParams,
    clock: Arc<SimClock>,
    fds: HashMap<u64, OpenFile>,
    /// Per-path cached block access-sequence numbers (0 = not cached).
    /// True LRU: sequential re-scans of a file larger than the pool
    /// thrash (each new block evicts the block the scan needs next).
    page_cache: HashMap<String, Vec<u64>>,
    cached_bytes: u64,
    access_seq: u64,
    next_fd: u64,
    cwd: String,
}

impl GpfsWan {
    pub fn new(remote: FileStore, params: GpfsWanParams, clock: Arc<SimClock>) -> Self {
        GpfsWan {
            remote,
            params,
            clock,
            fds: HashMap::new(),
            page_cache: HashMap::new(),
            cached_bytes: 0,
            access_seq: 0,
            next_fd: 3,
            cwd: "/".into(),
        }
    }

    fn abs(&self, path: &str) -> String {
        vpath::join(&self.cwd, path)
    }

    fn rpc(&self) {
        self.clock.advance_secs(self.params.rtt_s);
    }

    /// Read `len` bytes at `pos`: cached blocks at memory speed, misses
    /// over the WAN at the effective block rate.
    fn timed_read(&mut self, path: &str, pos: u64, len: usize) -> Result<Vec<u8>, FsError> {
        let data = self.remote.read_at(path, pos, len)?.to_vec();
        if data.is_empty() {
            return Ok(data);
        }
        let block = self.params.block;
        let first = pos / block;
        let last = (pos + data.len() as u64 - 1) / block;
        let mut miss_bytes = 0u64;
        let mut hit_bytes = 0u64;
        for b in first..=last {
            let bi = b as usize;
            self.access_seq += 1;
            let seq = self.access_seq;
            let cache = self.page_cache.entry(path.to_string()).or_default();
            if cache.len() <= bi {
                cache.resize(bi + 1, 0);
            }
            if cache[bi] != 0 {
                hit_bytes += block;
                cache[bi] = seq;
            } else {
                miss_bytes += block;
                cache[bi] = seq;
                self.cached_bytes += block;
                self.evict_lru();
            }
        }
        self.clock.advance_secs(miss_bytes as f64 / self.params.read_bps);
        self.clock.advance_secs(hit_bytes as f64 / self.params.mem_bps);
        Ok(data)
    }

    /// Global LRU eviction across the page pool.
    fn evict_lru(&mut self) {
        while self.cached_bytes > self.params.pagepool {
            let mut victim: Option<(String, usize, u64)> = None;
            for (p, c) in &self.page_cache {
                for (i, &seq) in c.iter().enumerate() {
                    if seq != 0 && victim.as_ref().map(|v| seq < v.2).unwrap_or(true) {
                        victim = Some((p.clone(), i, seq));
                    }
                }
            }
            match victim {
                Some((p, i, _)) => {
                    self.page_cache.get_mut(&p).unwrap()[i] = 0;
                    self.cached_bytes = self.cached_bytes.saturating_sub(self.params.block);
                }
                None => break,
            }
        }
    }
}

impl Vfs for GpfsWan {
    fn open(&mut self, path: &str, flags: OpenFlags) -> Result<Fd, FsError> {
        let flags = flags.validate()?;
        let p = self.abs(path);
        let now = self.clock.now();
        // metadata + token acquisition: one WAN round trip
        self.rpc();
        if !self.remote.exists(&p) {
            if !flags.is_create() {
                return Err(FsError::NotFound(p));
            }
            self.remote.mkdir_p(&vpath::parent(&p), now)?;
            self.remote.create(&p, now)?;
        } else if flags.is_truncate() {
            self.remote.truncate(&p, 0, now)?;
            self.page_cache.remove(&p);
        }
        if flags.is_write() {
            // write token revokes other cached copies: extra round trip
            self.rpc();
        }
        let pos = if flags.is_append() { self.remote.stat(&p)?.size } else { 0 };
        let fd = self.next_fd;
        self.next_fd += 1;
        self.fds.insert(fd, OpenFile { path: p, pos, flags, undrained: 0 });
        Ok(Fd(fd))
    }

    fn pread(&mut self, fd: Fd, buf: &mut [u8], off: u64) -> Result<usize, FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        let path = f.path.clone();
        let data = self.timed_read(&path, off, buf.len())?;
        buf[..data.len()].copy_from_slice(&data);
        Ok(data.len())
    }

    fn pwrite(&mut self, fd: Fd, buf: &[u8], off: u64) -> Result<usize, FsError> {
        let f = self.fds.get(&fd.0).ok_or(FsError::BadHandle)?;
        if !f.flags.is_write() {
            return Err(FsError::Perm("fd not open for writing".into()));
        }
        let (path, undrained) = (f.path.clone(), f.undrained);
        let now = self.clock.now();
        self.remote.write_at(&path, off, buf, now)?;
        // write-behind: absorb at memory speed while the page pool has
        // room, then the application throttles at the drain rate
        if undrained + (buf.len() as u64) <= self.params.pagepool {
            self.clock.advance_secs(buf.len() as f64 / self.params.mem_bps);
            self.fds.get_mut(&fd.0).unwrap().undrained += buf.len() as u64;
        } else {
            self.clock.advance_secs(buf.len() as f64 / self.params.write_bps);
        }
        Ok(buf.len())
    }

    fn seek(&mut self, fd: Fd, pos: u64) -> Result<(), FsError> {
        self.fds.get_mut(&fd.0).ok_or(FsError::BadHandle)?.pos = pos;
        Ok(())
    }

    fn tell(&self, fd: Fd) -> Result<u64, FsError> {
        self.fds.get(&fd.0).map(|f| f.pos).ok_or(FsError::BadHandle)
    }

    fn close(&mut self, fd: Fd) -> Result<(), FsError> {
        let f = self.fds.remove(&fd.0).ok_or(FsError::BadHandle)?;
        // close drains the write-behind buffer over the WAN (the paper's
        // measurements include close for exactly this reason) and
        // releases tokens: the file's pages are demoted
        if f.undrained > 0 {
            self.clock.advance_secs(f.undrained as f64 / self.params.write_bps);
        }
        self.rpc(); // token release
        if f.flags.is_write() {
            if let Some(c) = self.page_cache.remove(&f.path) {
                let freed = c.iter().filter(|&&x| x != 0).count() as u64 * self.params.block;
                self.cached_bytes = self.cached_bytes.saturating_sub(freed);
            }
        }
        Ok(())
    }

    fn stat(&mut self, path: &str) -> Result<WireAttr, FsError> {
        let p = self.abs(path);
        self.rpc();
        Ok(WireAttr::from_attr(&self.remote.stat(&p)?))
    }

    fn readdir(&mut self, path: &str) -> Result<Vec<(String, WireAttr)>, FsError> {
        let p = self.abs(path);
        self.rpc();
        Ok(self
            .remote
            .readdir(&p)?
            .into_iter()
            .map(|(n, a)| (n, WireAttr::from_attr(&a)))
            .collect())
    }

    fn chdir(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        self.rpc();
        match self.remote.stat(&p)?.kind {
            NodeKind::Dir => {
                self.cwd = p;
                Ok(())
            }
            _ => Err(FsError::NotADir(p)),
        }
    }

    fn mkdir(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.rpc();
        self.remote.mkdir_p(&p, now).map(|_| ())
    }

    fn unlink(&mut self, path: &str) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.rpc();
        self.page_cache.remove(&p);
        self.remote.unlink(&p, now)
    }

    fn rename(&mut self, from: &str, to: &str) -> Result<(), FsError> {
        let (f, t) = (self.abs(from), self.abs(to));
        let now = self.clock.now();
        self.rpc();
        self.page_cache.remove(&f);
        self.remote.rename(&f, &t, now)
    }

    fn truncate(&mut self, path: &str, size: u64) -> Result<(), FsError> {
        let p = self.abs(path);
        let now = self.clock.now();
        self.rpc();
        self.remote.truncate(&p, size, now)
    }

    fn lock(&mut self, _fd: Fd, _kind: LockKind) -> Result<(), FsError> {
        // token-based byte-range locks: one round trip, always granted in
        // the single-client scenarios we benchmark
        self.rpc();
        Ok(())
    }

    fn unlock(&mut self, _fd: Fd) -> Result<(), FsError> {
        self.rpc();
        Ok(())
    }

    fn fsync(&mut self) -> Result<(), FsError> {
        // drain all open write-behind buffers
        let total: u64 = self.fds.values().map(|f| f.undrained).sum();
        if total > 0 {
            self.clock.advance_secs(total as f64 / self.params.write_bps);
            for f in self.fds.values_mut() {
                f.undrained = 0;
            }
        }
        Ok(())
    }

    fn now(&self) -> VirtualTime {
        self.clock.now()
    }

    fn think(&mut self, secs: f64) {
        self.clock.advance_secs(secs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpfs_with(data: &[(&str, usize)]) -> GpfsWan {
        let clock = Arc::new(SimClock::new());
        let mut fs = FileStore::default();
        for (p, n) in data {
            fs.mkdir_p(&vpath::parent(p), VirtualTime::ZERO).unwrap();
            fs.write(p, &vec![7u8; *n], VirtualTime::ZERO).unwrap();
        }
        GpfsWan::new(fs, GpfsWanParams::default(), clock)
    }

    #[test]
    fn gib_scan_is_constant_33s() {
        let mut g = gpfs_with(&[("/scratch/big", 1 << 30)]);
        for run in 0..3 {
            let t0 = g.now();
            assert_eq!(g.scan_file("/scratch/big", 1 << 20).unwrap(), 1 << 30);
            let dt = g.now().saturating_sub(t0).as_secs();
            // paper Fig. 5: ~33 s every run — no whole-file cache (the
            // 1 GiB file blows through the 64 MiB page pool each scan)
            assert!((30.0..37.0).contains(&dt), "run {run}: dt={dt}");
        }
    }

    #[test]
    fn small_write_absorbed_by_pagepool() {
        let mut g = gpfs_with(&[]);
        let t0 = g.now();
        g.write_file("/scratch/small.dat", &vec![1u8; 1 << 20], 256 * 1024).unwrap();
        let dt = g.now().saturating_sub(t0).as_secs();
        // paper Fig. 2: GPFS-WAN far better than XUFS at 1 MiB — but close
        // still drains the buffer over the WAN
        let drain = (1u64 << 20) as f64 / GpfsWanParams::default().write_bps;
        assert!(dt >= drain, "close must include the flush ({dt} >= {drain})");
        assert!(dt < 0.35, "dt={dt}");
    }

    #[test]
    fn reread_within_open_hits_pages() {
        let mut g = gpfs_with(&[("/f", 4 << 20)]);
        let fd = g.open("/f", OpenFlags::rdonly()).unwrap();
        let mut buf = vec![0u8; 1 << 20];
        let t0 = g.now();
        while g.read(fd, &mut buf).unwrap() > 0 {}
        let cold = g.now().saturating_sub(t0).as_secs();
        g.seek(fd, 0).unwrap();
        let t1 = g.now();
        while g.read(fd, &mut buf).unwrap() > 0 {}
        let warm = g.now().saturating_sub(t1).as_secs();
        g.close(fd).unwrap();
        assert!(warm < cold / 5.0, "warm={warm} cold={cold}");
        // but after a write-open/close cycle the pages are demoted
    }

    #[test]
    fn every_fresh_scan_pays_wan_for_large_files() {
        let mut g = gpfs_with(&[("/f", 256 << 20)]);
        let t0 = g.now();
        g.scan_file("/f", 1 << 20).unwrap();
        let first = g.now().saturating_sub(t0).as_secs();
        let t1 = g.now();
        g.scan_file("/f", 1 << 20).unwrap();
        let second = g.now().saturating_sub(t1).as_secs();
        // 256 MiB >> 64 MiB pool: the second scan is still mostly WAN
        assert!(second > first * 0.5, "first={first} second={second}");
    }

    #[test]
    fn metadata_ops_cost_round_trips() {
        let mut g = gpfs_with(&[("/d/f", 10)]);
        let t0 = g.now();
        g.stat("/d/f").unwrap();
        g.readdir("/d").unwrap();
        let dt = g.now().saturating_sub(t0).as_secs();
        assert!((0.06..0.08).contains(&dt), "2 RTTs expected, dt={dt}");
    }
}
