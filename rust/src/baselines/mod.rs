//! Comparators from the paper's evaluation (§4): the GPFS-WAN distributed
//! parallel file system, a plain local parallel FS, an NFS-style
//! check-on-open client (consistency-protocol ablation), and the TGCP /
//! SCP copy commands of Table 2. All file systems implement the same
//! [`Vfs`](crate::client::Vfs) the workloads drive, over the same WAN/disk models as XUFS —
//! only the protocol behaviour differs (DESIGN.md §2).

mod gpfswan;
mod localfs;
mod nfs;
mod copytools;

pub use copytools::{Scp, Tgcp};
pub use gpfswan::{GpfsWan, GpfsWanParams};
pub use localfs::LocalFs;
pub use nfs::NfsClient;
