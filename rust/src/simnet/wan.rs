//! Analytic WAN transfer model (see module docs in `simnet/mod.rs`).

use std::sync::Mutex;

use crate::config::WanConfig;
use crate::simnet::clock::{Clock, SimClock};

/// The named heterogeneous link profiles (transport v2, DESIGN.md
/// §2.12) — the transport bench sweeps all of them.
pub const PROFILES: &[&str] = &["fat", "thin", "lossy", "asymmetric"];

/// A named WAN profile as a full [`WanConfig`]:
///
/// - `fat`: metro-area fat pipe — short RTT, fast per-stream windows;
///   striping barely matters, a sanity floor for the tuner.
/// - `thin`: long-haul thin pipe — high RTT, modest per-stream rate
///   under an ample aggregate (the paper's 2005 WAN, stretched).
/// - `lossy`: loss-limited streams — tiny per-stream goodput (loss
///   caps the congestion window) under a huge aggregate, slow-start
///   heavy; parallel streams are the only lever (the GridFTP case).
/// - `asymmetric`: decent per-stream rate but the aggregate binds at a
///   handful of streams — over-striping buys nothing, overlap does.
pub fn profile(name: &str) -> Option<WanConfig> {
    let mib = 1024.0 * 1024.0;
    match name {
        "fat" => Some(WanConfig {
            rtt_s: 0.004,
            per_stream_bps: 40.0 * mib,
            agg_bps: 10.0e9 / 8.0,
            setup_rtts: 3.0,
            slow_start_rtts: 2.0,
        }),
        "thin" => Some(WanConfig {
            rtt_s: 0.120,
            per_stream_bps: 1.0 * mib,
            agg_bps: 1.0e9 / 8.0,
            setup_rtts: 3.0,
            slow_start_rtts: 4.0,
        }),
        "lossy" => Some(WanConfig {
            rtt_s: 0.120,
            per_stream_bps: 0.5 * mib,
            agg_bps: 1.0e9 / 8.0,
            setup_rtts: 3.0,
            slow_start_rtts: 8.0,
        }),
        "asymmetric" => Some(WanConfig {
            rtt_s: 0.060,
            per_stream_bps: 4.0 * mib,
            agg_bps: 16.0 * mib,
            setup_rtts: 3.0,
            slow_start_rtts: 4.0,
        }),
        _ => None,
    }
}

/// Whether a transfer rides existing warm connections or must set up new
/// ones (connection setup + slow-start RTTs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    NewConnections,
    WarmConnections,
}

/// Aggregate WAN accounting (bytes moved, RPC count) for reports.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WanStats {
    pub bytes: u64,
    pub rpcs: u64,
    pub connects: u64,
}

/// The wide-area link between the client site and the home space.
///
/// Thread-safe; the real-TCP deployment shares one `Wan` across stripe
/// threads purely for accounting, while the simulated deployment also uses
/// it to advance the [`SimClock`].
#[derive(Debug)]
pub struct Wan {
    cfg: WanConfig,
    stats: Mutex<WanStats>,
}

impl Wan {
    /// The clock parameter pins the Wan to a deployment's timeline; time
    /// is advanced through the explicit `clock` argument of each call so
    /// the same Wan also serves pure duration queries (`*_secs`).
    pub fn new(cfg: WanConfig, clock: SimClock) -> Self {
        let _ = clock;
        Wan { cfg, stats: Mutex::new(WanStats::default()) }
    }

    pub fn config(&self) -> &WanConfig {
        &self.cfg
    }

    pub fn stats(&self) -> WanStats {
        *self.stats.lock().unwrap()
    }

    pub fn reset_stats(&self) {
        *self.stats.lock().unwrap() = WanStats::default();
    }

    /// Effective per-stream rate when `streams` run concurrently: each
    /// stream is window/RTT-bound, and together they cannot exceed the
    /// aggregate link share.
    pub fn stream_rate(&self, streams: usize) -> f64 {
        let streams = streams.max(1) as f64;
        self.cfg.per_stream_bps.min(self.cfg.agg_bps / streams)
    }

    /// Closed-form duration of moving `bytes` over `streams` parallel TCP
    /// connections. Setup and slow-start apply per the [`TransferKind`];
    /// stripes are balanced so the duration is driven by the largest share
    /// (ceil division).
    pub fn transfer_secs(&self, bytes: u64, streams: usize, kind: TransferKind) -> f64 {
        let streams = streams.max(1);
        let mut t = match kind {
            TransferKind::NewConnections => {
                (self.cfg.setup_rtts + self.cfg.slow_start_rtts) * self.cfg.rtt_s
            }
            TransferKind::WarmConnections => 0.0,
        };
        if bytes > 0 {
            let share = bytes.div_ceil(streams as u64);
            t += share as f64 / self.stream_rate(streams);
            // half an RTT for the final ack of each wave
            t += 0.5 * self.cfg.rtt_s;
        }
        t
    }

    /// Execute (account + advance clock) a striped transfer.
    pub fn transfer(&self, clock: &dyn Clock, bytes: u64, streams: usize, kind: TransferKind) -> f64 {
        let t = self.transfer_secs(bytes, streams, kind);
        {
            let mut s = self.stats.lock().unwrap();
            s.bytes += bytes;
            if kind == TransferKind::NewConnections {
                s.connects += streams as u64;
            }
        }
        clock.advance_secs(t);
        t
    }

    /// Account a striped transfer WITHOUT advancing the clock: the
    /// pipelined-readahead path (DESIGN.md §2.12) charges wall time
    /// when the hint is issued/consumed, but the bytes still crossed
    /// the link and belong in the stats.
    pub fn account_transfer(&self, bytes: u64, streams: usize, kind: TransferKind) {
        let mut s = self.stats.lock().unwrap();
        s.bytes += bytes;
        if kind == TransferKind::NewConnections {
            s.connects += streams as u64;
        }
    }

    /// A request/response RPC over a warm control connection: one RTT plus
    /// serialization of both messages at stream rate.
    pub fn rpc_secs(&self, req_bytes: u64, resp_bytes: u64) -> f64 {
        self.cfg.rtt_s + (req_bytes + resp_bytes) as f64 / self.stream_rate(1)
    }

    /// Execute (account + advance clock) an RPC.
    pub fn rpc(&self, clock: &dyn Clock, req_bytes: u64, resp_bytes: u64) -> f64 {
        let t = self.rpc_secs(req_bytes, resp_bytes);
        {
            let mut s = self.stats.lock().unwrap();
            s.bytes += req_bytes + resp_bytes;
            s.rpcs += 1;
        }
        clock.advance_secs(t);
        t
    }

    /// Connection establishment alone (control channel, callback channel).
    pub fn connect(&self, clock: &dyn Clock) -> f64 {
        let t = self.cfg.setup_rtts * self.cfg.rtt_s;
        self.stats.lock().unwrap().connects += 1;
        clock.advance_secs(t);
        t
    }

    /// Duration of fetching `files` (sizes in bytes) with `parallelism`
    /// concurrent single-stream fetches — the paper's small-file pre-fetch
    /// pattern (§3.3). Files are processed in waves; each wave lasts as
    /// long as its largest member. Connections are warm after the first
    /// wave (the pre-fetcher reuses its thread-local connections).
    pub fn batch_fetch_secs(&self, files: &[u64], parallelism: usize) -> f64 {
        if files.is_empty() {
            return 0.0;
        }
        let parallelism = parallelism.max(1);
        let rate = self.stream_rate(parallelism.min(files.len()));
        let mut total = 0.0;
        for (w, wave) in files.chunks(parallelism).enumerate() {
            let kind = if w == 0 { TransferKind::NewConnections } else { TransferKind::WarmConnections };
            let setup = match kind {
                TransferKind::NewConnections => {
                    (self.cfg.setup_rtts + self.cfg.slow_start_rtts) * self.cfg.rtt_s
                }
                TransferKind::WarmConnections => 0.0,
            };
            let biggest = *wave.iter().max().unwrap();
            // one RTT of request latency per file is pipelined across the
            // wave; the wave lasts for its largest transfer
            total += setup + self.cfg.rtt_s + biggest as f64 / rate;
        }
        total
    }

    /// Execute (account + advance clock) a batched parallel fetch.
    pub fn batch_fetch(&self, clock: &dyn Clock, files: &[u64], parallelism: usize) -> f64 {
        let t = self.batch_fetch_secs(files, parallelism);
        {
            let mut s = self.stats.lock().unwrap();
            s.bytes += files.iter().sum::<u64>();
            s.rpcs += files.len() as u64;
            s.connects += parallelism.min(files.len()) as u64;
        }
        clock.advance_secs(t);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::clock::SimClock;

    fn wan() -> (SimClock, Wan) {
        let c = SimClock::new();
        (c.clone(), Wan::new(WanConfig::default(), c))
    }

    #[test]
    fn striping_scales_until_agg_cap() {
        let (_, w) = wan();
        let t1 = w.transfer_secs(100 << 20, 1, TransferKind::WarmConnections);
        let t12 = w.transfer_secs(100 << 20, 12, TransferKind::WarmConnections);
        assert!(t1 / t12 > 11.0 && t1 / t12 < 13.0, "ratio {}", t1 / t12);
        // aggregate cap binds eventually: per-stream rate falls once
        // streams * per_stream > agg (would need ~1800 streams at 30 Gbps)
        assert_eq!(w.stream_rate(1), w.stream_rate(12));
        assert!(w.stream_rate(10_000) < w.stream_rate(12));
    }

    #[test]
    fn warm_cheaper_than_cold() {
        let (_, w) = wan();
        let cold = w.transfer_secs(1 << 20, 4, TransferKind::NewConnections);
        let warm = w.transfer_secs(1 << 20, 4, TransferKind::WarmConnections);
        assert!(cold > warm);
        let cfg = WanConfig::default();
        assert!((cold - warm - (cfg.setup_rtts + cfg.slow_start_rtts) * cfg.rtt_s).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_transfer_costs_setup_only() {
        let (_, w) = wan();
        assert_eq!(w.transfer_secs(0, 12, TransferKind::WarmConnections), 0.0);
        assert!(w.transfer_secs(0, 12, TransferKind::NewConnections) > 0.0);
    }

    #[test]
    fn stats_account_bytes_and_rpcs() {
        let (c, w) = wan();
        w.transfer(&c, 1000, 2, TransferKind::NewConnections);
        w.rpc(&c, 100, 200);
        let s = w.stats();
        assert_eq!(s.bytes, 1300);
        assert_eq!(s.rpcs, 1);
        assert_eq!(s.connects, 2);
        w.reset_stats();
        assert_eq!(w.stats(), WanStats::default());
    }

    #[test]
    fn named_profiles_cover_the_transport_matrix() {
        for name in PROFILES {
            let cfg = profile(name).expect(name);
            assert!(cfg.rtt_s > 0.0 && cfg.per_stream_bps > 0.0 && cfg.agg_bps > 0.0);
            // every profile admits at least one stripe at full rate
            let w = Wan::new(cfg, SimClock::new());
            assert!(w.stream_rate(1) > 0.0);
        }
        assert!(profile("dialup").is_none());
        // the profiles are genuinely heterogeneous: striping 12-wide pays
        // off big on lossy, barely on asymmetric (the aggregate binds)
        let lossy = Wan::new(profile("lossy").unwrap(), SimClock::new());
        let asym = Wan::new(profile("asymmetric").unwrap(), SimClock::new());
        let gain = |w: &Wan| {
            w.transfer_secs(8 << 20, 1, TransferKind::WarmConnections)
                / w.transfer_secs(8 << 20, 12, TransferKind::WarmConnections)
        };
        assert!(gain(&lossy) > 8.0, "lossy gain {}", gain(&lossy));
        assert!(gain(&asym) < 6.0, "asymmetric gain {}", gain(&asym));
    }

    #[test]
    fn account_transfer_books_bytes_without_time() {
        let (c, w) = wan();
        let before = c.now();
        w.account_transfer(4096, 3, TransferKind::NewConnections);
        assert_eq!(c.now(), before, "accounting must not advance the clock");
        let s = w.stats();
        assert_eq!(s.bytes, 4096);
        assert_eq!(s.connects, 3);
    }

    #[test]
    fn batch_fetch_waves() {
        let (_, w) = wan();
        // 24 files of 32 KiB with 12 threads = 2 waves
        let files = vec![32 * 1024u64; 24];
        let t = w.batch_fetch_secs(&files, 12);
        let one_by_one: f64 = files
            .iter()
            .map(|&b| w.transfer_secs(b, 1, TransferKind::NewConnections))
            .sum();
        assert!(t < one_by_one / 4.0, "batch {t} vs serial {one_by_one}");
        assert_eq!(w.batch_fetch_secs(&[], 12), 0.0);
    }

    #[test]
    fn batch_fetch_advances_clock() {
        let (c, w) = wan();
        let before = c.now();
        w.batch_fetch(&c, &[1024, 2048], 12);
        assert!(c.now() > before);
        assert_eq!(w.stats().bytes, 3072);
    }
}
