//! Virtual and real clocks behind one trait.
//!
//! The simulated deployment advances a [`SimClock`] analytically; the
//! real-TCP deployment (integration tests, e2e example) uses [`RealClock`].
//! All timestamps are [`VirtualTime`] nanoseconds so the two are
//! interchangeable throughout the client/server/lease code.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Nanoseconds since deployment start.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct VirtualTime(pub u64);

impl VirtualTime {
    pub const ZERO: VirtualTime = VirtualTime(0);

    pub fn from_secs(s: f64) -> Self {
        VirtualTime((s.max(0.0) * 1e9).round() as u64)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 / 1e9
    }

    /// Fractional seconds, named like `Duration::as_secs_f64` so latency
    /// call sites can't be confused with an integer-truncating getter
    /// (the `vfs.op_latency` histogram takes fractional seconds — a
    /// whole-second reading records every sub-second op as 0.0).
    pub fn as_secs_f64(self) -> f64 {
        self.as_secs()
    }

    pub fn saturating_sub(self, other: VirtualTime) -> VirtualTime {
        VirtualTime(self.0.saturating_sub(other.0))
    }

    pub fn add_secs(self, s: f64) -> VirtualTime {
        VirtualTime(self.0 + (s.max(0.0) * 1e9).round() as u64)
    }
}

/// A clock the deployment reads and (if simulated) advances.
pub trait Clock: Send + Sync {
    fn now(&self) -> VirtualTime;
    /// Advance by `secs`. Real clocks sleep; sim clocks jump.
    fn advance_secs(&self, secs: f64);
}

/// Shared virtual clock: advancing is O(1), reads are atomic.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    ns: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Move the clock to `t` if `t` is later (used when joining parallel
    /// analytic activities: the end time is the max of the branches).
    pub fn advance_to(&self, t: VirtualTime) {
        self.ns.fetch_max(t.0, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now(&self) -> VirtualTime {
        VirtualTime(self.ns.load(Ordering::SeqCst))
    }

    fn advance_secs(&self, secs: f64) {
        self.ns.fetch_add((secs.max(0.0) * 1e9).round() as u64, Ordering::SeqCst);
    }
}

/// Wall-clock implementation for the real-TCP deployment.
#[derive(Debug, Clone)]
pub struct RealClock {
    start: Instant,
}

impl Default for RealClock {
    fn default() -> Self {
        RealClock { start: Instant::now() }
    }
}

impl RealClock {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Clock for RealClock {
    fn now(&self) -> VirtualTime {
        VirtualTime(self.start.elapsed().as_nanos() as u64)
    }

    fn advance_secs(&self, secs: f64) {
        std::thread::sleep(std::time::Duration::from_secs_f64(secs.max(0.0)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_time_conversions() {
        let t = VirtualTime::from_secs(1.25);
        assert_eq!(t.0, 1_250_000_000);
        assert!((t.as_secs() - 1.25).abs() < 1e-12);
        assert_eq!(t.add_secs(0.75).as_secs(), 2.0);
        assert_eq!(VirtualTime::from_secs(-1.0), VirtualTime::ZERO);
    }

    #[test]
    fn sim_clock_advance_to_is_monotonic() {
        let c = SimClock::new();
        c.advance_to(VirtualTime::from_secs(5.0));
        c.advance_to(VirtualTime::from_secs(3.0)); // earlier: no-op
        assert_eq!(c.now().as_secs(), 5.0);
    }

    #[test]
    fn shared_between_clones() {
        let c = SimClock::new();
        let c2 = c.clone();
        c2.advance_secs(2.0);
        assert_eq!(c.now().as_secs(), 2.0);
    }

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn saturating_sub() {
        let a = VirtualTime::from_secs(1.0);
        let b = VirtualTime::from_secs(2.0);
        assert_eq!(a.saturating_sub(b), VirtualTime::ZERO);
        assert_eq!(b.saturating_sub(a).as_secs(), 1.0);
    }
}
