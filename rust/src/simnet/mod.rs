//! WAN / time simulation substrate.
//!
//! The paper's evaluation runs between SDSC and NCSA over the 30 Gbps
//! TeraGrid WAN; we reproduce the *behavioural* network properties that
//! XUFS's design exploits (DESIGN.md §2):
//!
//! * per-TCP-stream throughput is window/RTT-bound (~2 MiB/s with 2005-era
//!   64 KiB default windows over 32 ms RTT) — which is exactly why XUFS
//!   stripes across up to 12 connections;
//! * connection setup and small RPCs cost round trips — which is why XUFS
//!   pre-fetches small files in parallel and serves stats from cache;
//! * aggregate capacity (30 Gbps) is effectively never the binding
//!   constraint for a single user.
//!
//! Everything runs against a virtual [`SimClock`], so benches are
//! deterministic and report simulated seconds. The model is analytic
//! (transfer durations computed in closed form) rather than packet-level:
//! the quantities the paper's figures depend on are RTT counts and
//! stream-capped bandwidth shares, both of which the closed form captures.

mod clock;
mod fault;
mod wan;

pub use clock::{Clock, RealClock, SimClock, VirtualTime};
pub use fault::{CorruptArtifact, FaultAction, FaultEvent, FaultPlan, StepOutcome};
pub use wan::{profile as wan_profile, TransferKind, Wan, WanStats, PROFILES as WAN_PROFILES};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WanConfig;

    #[test]
    fn sim_clock_advances() {
        let c = SimClock::new();
        assert_eq!(c.now().as_secs(), 0.0);
        c.advance_secs(1.5);
        assert!((c.now().as_secs() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn single_stream_1gib_is_window_bound() {
        // 1 GiB over one 2 MiB/s stream ≈ 512 s (plus setup) — the reason
        // plain SCP-era single-stream copies crawl on the TeraGrid WAN.
        let clock = SimClock::new();
        let wan = Wan::new(WanConfig::default(), clock.clone());
        let t = wan.transfer_secs(1 << 30, 1, TransferKind::NewConnections);
        assert!(t > 500.0 && t < 530.0, "t={t}");
    }

    #[test]
    fn twelve_stripes_match_paper_fetch_time() {
        // Paper Table 2: XUFS moves 1 GiB in ~57 s; the raw striped
        // transfer is ~43-46 s with 12 streams (cache-write and digest
        // overhead make up the rest — accounted by the client layers).
        let clock = SimClock::new();
        let wan = Wan::new(WanConfig::default(), clock.clone());
        let t = wan.transfer_secs(1 << 30, 12, TransferKind::NewConnections);
        assert!(t > 40.0 && t < 50.0, "t={t}");
    }

    #[test]
    fn rpc_costs_one_rtt() {
        let clock = SimClock::new();
        let wan = Wan::new(WanConfig::default(), clock.clone());
        let before = clock.now().as_secs();
        wan.rpc(&clock, 256, 256);
        let dt = clock.now().as_secs() - before;
        assert!(dt >= 0.032 && dt < 0.04, "dt={dt}");
    }
}
