//! Deterministic fault plane (DESIGN.md §2.5).
//!
//! A seeded [`FaultPlan`] decides, for every WAN interaction a
//! [`crate::client::ServerLink`] attempts, whether and how that
//! interaction fails: packets dropped before or after the server saw
//! them, duplicated deliveries, extra queueing delay, torn bulk
//! transfers, multi-step partitions, and server crash/restart schedules.
//! The plan is pure state + a seeded [`Rng`], so a failing schedule
//! reproduces from its seed alone — the property the schedule explorer
//! in `tests/fault_properties.rs` leans on.
//!
//! The plan advances one **step** per interaction attempt (including
//! attempts that fail because of a partition, so a retrying client always
//! makes schedule progress and every partition ends). Client crashes
//! cannot be performed by a link, so they surface as harness events via
//! [`FaultPlan::take_harness_events`].

use crate::config::FaultConfig;
use crate::util::Rng;

/// What the fault plane does to one WAN interaction (clean delivery is
/// `None` in [`StepOutcome::action`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// The request is lost before reaching the server (client observes a
    /// timeout; the server never saw the op).
    DropRequest,
    /// The server processes the request but the reply is lost (client
    /// observes a timeout; the op DID land — the idempotent-replay case).
    DropReply,
    /// The request reaches the server twice (network-level duplication).
    Duplicate,
    /// Extra queueing delay before normal delivery, in milliseconds.
    Delay { ms: u32 },
    /// A bulk transfer is torn mid-flight; the link must resume or
    /// surface `FsError::Interrupted` with the resume block.
    Interrupt,
}

/// Which durable artifact a [`FaultEvent::CorruptByte`] rots
/// (DESIGN.md §2.10). The harness maps each onto its topology: `Chunk`
/// targets the primary's chunk store, `Cache` a client's cache-space
/// files, `Oplog` a client's durable meta-op log.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorruptArtifact {
    Chunk,
    Cache,
    Oplog,
}

/// Control-plane events the harness (not the link) must act on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultEvent {
    /// Crash-and-recover the numbered client (snapshot its cache space,
    /// drop the process, rebuild via `XufsClient::recover`).
    ClientCrash { client: u8 },
    /// The schedule decided this primary crash warrants a failover:
    /// drain the replication log to the secondary and promote it
    /// (DESIGN.md §2.7). Ignored by unreplicated topologies. The
    /// crashed primary still restarts on schedule — fenced.
    PromoteSecondary,
    /// Bit rot (DESIGN.md §2.10): flip one byte of one durable
    /// artifact, selected deterministically from `sel` (which byte of
    /// which chunk/file/record is the harness's mapping). The integrity
    /// invariant I5 demands the rot is DETECTED — surfaced as a repair,
    /// a typed `Corrupted` refusal, or a re-fetch — never served.
    CorruptByte { artifact: CorruptArtifact, sel: u64 },
}

/// The plan's verdict for one interaction step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StepOutcome {
    pub action: Option<FaultAction>,
    /// The link is partitioned for this step: sever and fail the call.
    pub partitioned: bool,
    /// Crash the server process before handling this interaction.
    pub server_crash: bool,
    /// Restart the server process before handling this interaction.
    pub server_restart: bool,
}

/// Seeded, deterministic fault schedule shared by every link of a
/// deployment (wrap in `Arc<Mutex<..>>`).
#[derive(Debug)]
pub struct FaultPlan {
    rng: Rng,
    cfg: FaultConfig,
    step: u64,
    /// Interactions left in the current partition.
    partition_left: u32,
    /// Step at which a crashed server restarts.
    restart_at: Option<u64>,
    events: Vec<FaultEvent>,
    injected: u64,
    partitions: u64,
}

impl FaultPlan {
    pub fn new(seed: u64, cfg: FaultConfig) -> Self {
        FaultPlan {
            rng: Rng::new(seed ^ 0xFA17_FA17_FA17_FA17),
            cfg,
            step: 0,
            partition_left: 0,
            restart_at: None,
            events: Vec::new(),
            injected: 0,
            partitions: 0,
        }
    }

    /// Total interactions stepped so far.
    pub fn steps(&self) -> u64 {
        self.step
    }

    /// Faults injected so far (anything other than clean delivery).
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Partitions started so far.
    pub fn partitions(&self) -> u64 {
        self.partitions
    }

    /// Is a partition currently in force?
    pub fn partitioned(&self) -> bool {
        self.cfg.enabled && self.partition_left > 0
    }

    /// Is a server restart still pending (crash happened, restart step
    /// not yet reached)?
    pub fn restart_pending(&self) -> bool {
        self.restart_at.is_some()
    }

    /// Stop injecting anything new and release standing conditions: the
    /// quiesce phase of a schedule. A pending server restart is surfaced
    /// once more through the next `step()` so the link can restart it.
    pub fn quiesce(&mut self) {
        self.cfg.enabled = false;
        self.partition_left = 0;
        if let Some(at) = self.restart_at {
            // fire at the next step regardless of the original schedule
            self.restart_at = Some(at.min(self.step + 1));
        }
    }

    /// Drain pending harness-level events (client crashes).
    pub fn take_harness_events(&mut self) -> Vec<FaultEvent> {
        std::mem::take(&mut self.events)
    }

    /// Advance one interaction and decide its fate. Dice are rolled in a
    /// fixed order so a schedule depends only on (seed, step sequence).
    pub fn step(&mut self) -> StepOutcome {
        self.step += 1;
        let mut out = StepOutcome::default();
        // a scheduled restart fires even while quiesced or partitioned
        if let Some(at) = self.restart_at {
            if self.step >= at {
                out.server_restart = true;
                self.restart_at = None;
            }
        }
        if !self.cfg.enabled {
            return out;
        }
        if self.partition_left > 0 {
            self.partition_left -= 1;
            self.injected += 1;
            out.partitioned = true;
            return out;
        }
        if self.cfg.partition_p > 0.0 && self.rng.chance(self.cfg.partition_p) {
            self.partition_left = self.rng.range(1, self.cfg.partition_max_steps.max(1) as u64) as u32;
            self.partitions += 1;
            self.injected += 1;
            out.partitioned = true;
            return out;
        }
        if self.cfg.server_crash_p > 0.0
            && self.restart_at.is_none()
            && !out.server_restart
            && self.rng.chance(self.cfg.server_crash_p)
        {
            out.server_crash = true;
            self.restart_at =
                Some(self.step + self.rng.range(1, self.cfg.server_crash_max_steps.max(1) as u64));
            // primary-crash/promote schedule events (DESIGN.md §2.7):
            // some crashes escalate to a failover decision the harness
            // acts on. With `promote_after_crash_p = 0` (the default) no
            // die is rolled, so pre-replica schedules reproduce
            // byte-identically from their seeds.
            if self.cfg.promote_after_crash_p > 0.0
                && self.rng.chance(self.cfg.promote_after_crash_p)
            {
                self.events.push(FaultEvent::PromoteSecondary);
            }
            self.injected += 1;
            return out;
        }
        if self.cfg.client_crash_p > 0.0 && self.rng.chance(self.cfg.client_crash_p) {
            // which client the harness should crash (harness maps the
            // index onto its mounted clients)
            let client = self.rng.below(256) as u8;
            self.events.push(FaultEvent::ClientCrash { client });
            self.injected += 1;
            // the interaction itself still proceeds normally
        }
        if self.cfg.corrupt_p > 0.0 && self.rng.chance(self.cfg.corrupt_p) {
            // bit rot in a durable artifact (DESIGN.md §2.10). With
            // `corrupt_p = 0` (the default) no die is rolled, so
            // pre-integrity schedules reproduce byte-identically.
            let artifact = match self.rng.below(3) {
                0 => CorruptArtifact::Chunk,
                1 => CorruptArtifact::Cache,
                _ => CorruptArtifact::Oplog,
            };
            let sel = self.rng.next_u64();
            self.events.push(FaultEvent::CorruptByte { artifact, sel });
            self.injected += 1;
            // the interaction itself still proceeds normally
        }
        let action = if self.rng.chance(self.cfg.drop_request_p) {
            Some(FaultAction::DropRequest)
        } else if self.rng.chance(self.cfg.drop_reply_p) {
            Some(FaultAction::DropReply)
        } else if self.rng.chance(self.cfg.duplicate_p) {
            Some(FaultAction::Duplicate)
        } else if self.rng.chance(self.cfg.interrupt_p) {
            Some(FaultAction::Interrupt)
        } else if self.rng.chance(self.cfg.delay_p) {
            Some(FaultAction::Delay {
                ms: self.rng.range(1, self.cfg.delay_max_ms.max(1) as u64) as u32,
            })
        } else {
            None
        };
        if action.is_some() {
            self.injected += 1;
        }
        out.action = action;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chaos_cfg() -> FaultConfig {
        FaultConfig {
            enabled: true,
            drop_request_p: 0.1,
            drop_reply_p: 0.1,
            duplicate_p: 0.1,
            delay_p: 0.1,
            delay_max_ms: 200,
            interrupt_p: 0.1,
            partition_p: 0.05,
            partition_max_steps: 12,
            server_crash_p: 0.02,
            server_crash_max_steps: 20,
            client_crash_p: 0.01,
            promote_after_crash_p: 0.25,
            corrupt_p: 0.02,
        }
    }

    #[test]
    fn same_seed_same_schedule() {
        let mut a = FaultPlan::new(42, chaos_cfg());
        let mut b = FaultPlan::new(42, chaos_cfg());
        for _ in 0..500 {
            assert_eq!(a.step(), b.step());
        }
        assert_eq!(a.take_harness_events(), b.take_harness_events());
        assert_eq!(a.injected(), b.injected());
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = FaultPlan::new(1, chaos_cfg());
        let mut b = FaultPlan::new(2, chaos_cfg());
        let diverged = (0..200).any(|_| a.step() != b.step());
        assert!(diverged);
    }

    #[test]
    fn partitions_end_and_crashes_restart() {
        let mut p = FaultPlan::new(7, chaos_cfg());
        let mut saw_partition = false;
        let mut saw_crash = false;
        let mut saw_restart = false;
        let mut server_up = true;
        for _ in 0..5000 {
            let o = p.step();
            if o.server_restart {
                saw_restart = true;
                server_up = true;
            }
            if o.server_crash {
                saw_crash = true;
                server_up = false;
            }
            saw_partition |= o.partitioned;
        }
        assert!(saw_partition && saw_crash && saw_restart);
        // every crash schedules a restart, so a long run cannot end with
        // the server wedged down once quiesced
        p.quiesce();
        for _ in 0..3 {
            if p.step().server_restart {
                server_up = true;
            }
        }
        assert!(server_up, "quiesce must release a pending restart");
        assert!(!p.partitioned());
    }

    #[test]
    fn disabled_plan_is_transparent() {
        let mut cfg = chaos_cfg();
        cfg.enabled = false;
        let mut p = FaultPlan::new(3, cfg);
        for _ in 0..100 {
            assert_eq!(p.step(), StepOutcome::default());
        }
        assert_eq!(p.injected(), 0);
    }

    #[test]
    fn quiesce_stops_new_faults() {
        let mut p = FaultPlan::new(11, chaos_cfg());
        for _ in 0..50 {
            p.step();
        }
        p.quiesce();
        // drain a possible pending restart, then everything is clean
        let _ = p.step();
        for _ in 0..100 {
            let o = p.step();
            assert!(!o.partitioned && o.action.is_none() && !o.server_crash && !o.server_restart);
        }
    }
}
