//! Delta-writeback payload compression (transport v2, DESIGN.md §2.12).
//!
//! `WriteDelta` block payloads are the WAN's hottest writeback bytes, and
//! HPC outputs (logs, zero-padded records, append-mostly tables) compress
//! well with two cheap in-tree codecs: byte run-length encoding and a
//! rolling-hash LZ (greedy LZ77 over a 4-byte hash window). The wire
//! framing is self-describing and backward compatible:
//!
//! - A block whose index has [`COMPRESSED_IDX_BIT`] clear carries raw
//!   bytes — exactly the legacy frame, byte for byte.
//! - A block whose index has the bit set carries `[flag, body…]` where
//!   `flag` is [`FLAG_RAW`], [`FLAG_RLE`] or [`FLAG_LZ`].
//!
//! The compressor only frames a block when the framed form is strictly
//! smaller than the raw payload, so incompressible (e.g. random) blocks
//! ship in the legacy form with zero overhead and old decoders keep
//! working on everything an old client sends. The decoder is bounded
//! (`max_out`) and total: malformed input yields `None`, never a panic.

use crate::metrics::{names, Metrics};
use crate::proto::MetaOp;

/// Set in a `WriteDelta` block index when the payload is compression-
/// framed. Block indices are block numbers within a file (≤ file size /
/// 64 KiB), so bit 31 is free by a wide margin.
pub const COMPRESSED_IDX_BIT: u32 = 1 << 31;

/// Framed payload is the raw bytes (used only by foreign encoders; our
/// compressor never frames a block it couldn't shrink).
pub const FLAG_RAW: u8 = 0;
/// Framed payload is `(count, byte)` run pairs.
pub const FLAG_RLE: u8 = 1;
/// Framed payload is the rolling-hash LZ stream.
pub const FLAG_LZ: u8 = 2;

const MIN_MATCH: usize = 4;
const HASH_BITS: u32 = 12;

/// Compress `data`, returning the self-describing framed payload
/// (`[flag, body…]`) only when it is strictly smaller than the raw
/// bytes; `None` means "ship raw".
pub fn compress(data: &[u8]) -> Option<Vec<u8>> {
    if data.len() < 2 {
        return None;
    }
    let rle = rle_encode(data);
    let lz = lz_encode(data);
    let (flag, body) = if rle.len() <= lz.len() { (FLAG_RLE, rle) } else { (FLAG_LZ, lz) };
    if body.len() + 1 >= data.len() {
        return None;
    }
    let mut framed = Vec::with_capacity(body.len() + 1);
    framed.push(flag);
    framed.extend_from_slice(&body);
    Some(framed)
}

/// Decode a framed payload back to raw bytes. Total and bounded: any
/// malformed frame, unknown flag, or output past `max_out` is `None`.
pub fn decompress(framed: &[u8], max_out: usize) -> Option<Vec<u8>> {
    let (&flag, body) = framed.split_first()?;
    match flag {
        FLAG_RAW => (body.len() <= max_out).then(|| body.to_vec()),
        FLAG_RLE => rle_decode(body, max_out),
        FLAG_LZ => lz_decode(body, max_out),
        _ => None,
    }
}

/// Compress the block payloads of a `WriteDelta` in place (no-op for
/// every other op). Blocks that shrink get the framed payload and their
/// index bit; the rest keep the legacy raw form.
pub fn compress_delta_op(op: &mut MetaOp, metrics: &Metrics) {
    let MetaOp::WriteDelta { blocks, .. } = op else {
        return;
    };
    let mut saved = 0u64;
    for (idx, payload) in blocks.iter_mut() {
        if *idx & COMPRESSED_IDX_BIT != 0 {
            continue; // already framed
        }
        if let Some(framed) = compress(payload) {
            saved += (payload.len() - framed.len()) as u64;
            *idx |= COMPRESSED_IDX_BIT;
            *payload = framed;
        }
    }
    if saved > 0 {
        metrics.add(names::COMPRESSED_BYTES_SAVED, saved);
    }
}

/// Decode one possibly-compressed `WriteDelta` block to its raw index
/// and bytes. Uncompressed blocks borrow; framed ones decode (bounded by
/// `max_block`). `None` means an undecodable frame — refuse the delta.
pub fn decode_block<'a>(
    idx: u32,
    payload: &'a [u8],
    max_block: usize,
) -> Option<(u32, std::borrow::Cow<'a, [u8]>)> {
    if idx & COMPRESSED_IDX_BIT == 0 {
        return Some((idx, std::borrow::Cow::Borrowed(payload)));
    }
    let raw = decompress(payload, max_block)?;
    Some((idx & !COMPRESSED_IDX_BIT, std::borrow::Cow::Owned(raw)))
}

// ---------------------------------------------------------------------
// RLE: (count, byte) pairs, count 1..=255
// ---------------------------------------------------------------------

fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let b = data[i];
        let mut run = 1usize;
        while run < 255 && i + run < data.len() && data[i + run] == b {
            run += 1;
        }
        out.push(run as u8);
        out.push(b);
        i += run;
    }
    out
}

fn rle_decode(body: &[u8], max_out: usize) -> Option<Vec<u8>> {
    if body.len() % 2 != 0 {
        return None;
    }
    let mut out = Vec::new();
    for pair in body.chunks_exact(2) {
        let (count, byte) = (pair[0] as usize, pair[1]);
        if count == 0 || out.len() + count > max_out {
            return None;
        }
        out.resize(out.len() + count, byte);
    }
    Some(out)
}

// ---------------------------------------------------------------------
// Rolling-hash LZ: greedy LZ77, 4-byte hash window, 64 KiB distances.
//
// Command stream: a byte `c < 0x80` is a literal run of `c + 1` bytes
// (which follow); `c >= 0x80` is a match of `(c & 0x7f) + MIN_MATCH`
// bytes at the 2-byte little-endian distance that follows (1-based,
// may overlap the output for repeated patterns). Long matches emit
// consecutive match commands.
// ---------------------------------------------------------------------

fn hash4(data: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([data[i], data[i + 1], data[i + 2], data[i + 3]]);
    (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

fn lz_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i < data.len() {
        let mut matched = 0usize;
        let mut dist = 0usize;
        if i + MIN_MATCH <= data.len() {
            let h = hash4(data, i);
            let cand = table[h];
            table[h] = i;
            if cand != usize::MAX && i - cand <= u16::MAX as usize {
                let mut l = 0usize;
                while i + l < data.len() && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l >= MIN_MATCH {
                    matched = l;
                    dist = i - cand;
                }
            }
        }
        if matched == 0 {
            i += 1;
            continue;
        }
        flush_literals(&mut out, &data[lit_start..i]);
        let mut rest = matched;
        while rest >= MIN_MATCH {
            let take = rest.min(0x7f + MIN_MATCH);
            out.push(0x80 | (take - MIN_MATCH) as u8);
            out.extend_from_slice(&(dist as u16).to_le_bytes());
            rest -= take;
        }
        i += matched - rest;
        lit_start = i;
        // the match tail rejoins the literal run if too short to encode
        i += rest;
    }
    flush_literals(&mut out, &data[lit_start..]);
    out
}

fn flush_literals(out: &mut Vec<u8>, mut lits: &[u8]) {
    while !lits.is_empty() {
        let take = lits.len().min(0x80);
        out.push((take - 1) as u8);
        out.extend_from_slice(&lits[..take]);
        lits = &lits[take..];
    }
}

fn lz_decode(body: &[u8], max_out: usize) -> Option<Vec<u8>> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < body.len() {
        let c = body[i];
        i += 1;
        if c < 0x80 {
            let n = c as usize + 1;
            if i + n > body.len() || out.len() + n > max_out {
                return None;
            }
            out.extend_from_slice(&body[i..i + n]);
            i += n;
        } else {
            let n = (c & 0x7f) as usize + MIN_MATCH;
            if i + 2 > body.len() {
                return None;
            }
            let dist = u16::from_le_bytes([body[i], body[i + 1]]) as usize;
            i += 2;
            if dist == 0 || dist > out.len() || out.len() + n > max_out {
                return None;
            }
            // byte-wise copy: overlapping matches replicate the pattern
            let start = out.len() - dist;
            for k in 0..n {
                let b = out[start + k];
                out.push(b);
            }
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn runs_compress_via_rle() {
        let data = vec![0u8; 65536];
        let framed = compress(&data).expect("a zero block must compress");
        assert!(framed.len() < 600, "65536 zeros should RLE to ~515 bytes, got {}", framed.len());
        assert_eq!(decompress(&framed, 65536).unwrap(), data);
    }

    #[test]
    fn patterns_compress_via_lz() {
        let pattern = b"xufs-record:0000000000|";
        let mut data = Vec::new();
        while data.len() < 48_000 {
            data.extend_from_slice(pattern);
        }
        let framed = compress(&data).expect("repeated records must compress");
        assert!(framed.len() * 4 < data.len(), "framed {} vs raw {}", framed.len(), data.len());
        assert_eq!(decompress(&framed, 65536).unwrap(), data);
    }

    #[test]
    fn random_data_ships_raw() {
        let mut rng = Rng::new(0xC0);
        let data: Vec<u8> = (0..65536).map(|_| rng.below(256) as u8).collect();
        assert!(compress(&data).is_none(), "incompressible blocks keep the legacy frame");
    }

    #[test]
    fn roundtrip_mixed_payloads() {
        let mut rng = Rng::new(0xC1);
        for case in 0..64 {
            let len = 1 + rng.below(4096) as usize;
            let data: Vec<u8> = match case % 4 {
                0 => vec![case as u8; len],
                1 => (0..len).map(|i| (i % 7) as u8).collect(),
                2 => (0..len).map(|_| rng.below(4) as u8).collect(),
                _ => (0..len).map(|_| rng.below(256) as u8).collect(),
            };
            if let Some(framed) = compress(&data) {
                assert!(framed.len() < data.len(), "framed form must be strictly smaller");
                assert_eq!(decompress(&framed, data.len()).unwrap(), data, "case {case}");
            }
        }
    }

    #[test]
    fn decoder_is_total_and_bounded() {
        let mut rng = Rng::new(0xC2);
        for _ in 0..512 {
            let len = rng.below(64) as usize;
            let junk: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            // never panics, and any accepted output respects the bound
            if let Some(out) = decompress(&junk, 256) {
                assert!(out.len() <= 256);
            }
        }
        // a frame that decodes past the bound is refused, not truncated
        let framed = compress(&vec![7u8; 1024]).unwrap();
        assert!(decompress(&framed, 1023).is_none());
        assert_eq!(decompress(&framed, 1024).unwrap().len(), 1024);
    }

    #[test]
    fn tampered_frames_never_panic() {
        let mut data = Vec::new();
        for i in 0..2048u32 {
            data.extend_from_slice(&(i / 3).to_le_bytes());
        }
        let framed = compress(&data).unwrap();
        let mut rng = Rng::new(0xC3);
        for _ in 0..256 {
            let mut t = framed.clone();
            let at = rng.below(t.len() as u64) as usize;
            t[at] ^= 1 + rng.below(255) as u8;
            let _ = decompress(&t, data.len()); // must not panic
        }
        for cut in 0..framed.len().min(32) {
            let _ = decompress(&framed[..cut], data.len());
        }
    }

    #[test]
    fn delta_op_compression_is_selective_and_reversible() {
        let m = Metrics::new();
        let mut rng = Rng::new(0xC4);
        let raw_runs = vec![3u8; 65536];
        let raw_rand: Vec<u8> = (0..65536).map(|_| rng.below(256) as u8).collect();
        let mut op = MetaOp::WriteDelta {
            path: "/f".into(),
            total_size: 131072,
            base_version: 5,
            blocks: vec![(0, raw_runs.clone()), (1, raw_rand.clone())],
            digests: vec![1, 2],
        };
        compress_delta_op(&mut op, &m);
        let MetaOp::WriteDelta { blocks, .. } = &op else { panic!() };
        assert_eq!(blocks[0].0, COMPRESSED_IDX_BIT, "runs block framed");
        assert_eq!(blocks[1].0, 1, "random block keeps the legacy frame");
        assert_eq!(blocks[1].1, raw_rand);
        assert!(m.counter(names::COMPRESSED_BYTES_SAVED) > 60_000);
        let (idx, bytes) = decode_block(blocks[0].0, &blocks[0].1, 65536).unwrap();
        assert_eq!(idx, 0);
        assert_eq!(&bytes[..], &raw_runs[..]);
        let (idx, bytes) = decode_block(blocks[1].0, &blocks[1].1, 65536).unwrap();
        assert_eq!((idx, &bytes[..]), (1, &raw_rand[..]));
        // wire accounting shrinks with the payload (that's the WAN win)
        assert!(op.wire_bytes() < 66_000, "wire bytes {}", op.wire_bytes());
    }

    #[test]
    fn decode_block_refuses_undecodable_frames() {
        assert!(decode_block(COMPRESSED_IDX_BIT | 2, &[9, 1, 2, 3], 65536).is_none());
        assert!(decode_block(COMPRESSED_IDX_BIT, &[], 65536).is_none());
        // legacy raw block passes through untouched
        let (idx, bytes) = decode_block(7, &[1, 2, 3], 65536).unwrap();
        assert_eq!((idx, &bytes[..]), (7, &[1u8, 2, 3][..]));
    }
}
