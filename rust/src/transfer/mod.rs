//! Striped-transfer engine helpers (paper §3.3).
//!
//! "All data transfers in XUFS over 64 Kbytes are striped across multiple
//! TCP connections. XUFS uses up to 12 stripes with a minimum 64 kilobytes
//! block size each … [and] spawn[s] multiple (12 by default) parallel
//! threads for pre-fetching files smaller than 64 kilobytes."
//!
//! This module holds the transport-independent pieces: stripe-count
//! policy, integrity verification of fetched images against their
//! per-block digests (via the AOT digest engine), and construction of the
//! writeback op (full vs digest-delta) from a [`TransferPlan`].

use std::sync::Arc;

use crate::config::StripeConfig;
use crate::homefs::FsError;
use crate::metrics::{names, Metrics};
use crate::proto::{FileImage, MetaOp};
use crate::runtime::DigestEngine;

/// How many TCP stripes a transfer of `bytes` uses: 1 below the striping
/// threshold, then one per `min_block`, capped at `max_stripes`.
pub fn stripes_for(bytes: u64, cfg: &StripeConfig) -> usize {
    if bytes <= cfg.stripe_threshold {
        return 1;
    }
    let by_block = bytes.div_ceil(cfg.min_block.max(1)) as usize;
    by_block.clamp(1, cfg.max_stripes.max(1))
}

/// Verify a fetched image end-to-end: recompute per-block digests of the
/// received bytes and compare to the digests the server sent. A mismatch
/// means a corrupted stripe — callers re-fetch.
pub fn verify_image(
    engine: &Arc<DigestEngine>,
    image: &FileImage,
    block_bytes: usize,
    metrics: &Metrics,
) -> Result<(), FsError> {
    if image.digests.is_empty() {
        // server sent no digests (shouldn't happen with our server, but a
        // foreign server could) — nothing to verify against
        return Ok(());
    }
    let got = engine.digests(&image.data, block_bytes);
    if got != image.digests {
        metrics.incr("transfer.integrity_failures");
        return Err(FsError::Protocol(format!(
            "integrity check failed for {} ({} blocks, {} mismatched)",
            image.path,
            got.len(),
            got.iter().zip(&image.digests).filter(|(a, b)| a != b).count()
        )));
    }
    Ok(())
}

/// Extract the dirty blocks named by a plan as `(block_index, bytes)`
/// payloads for a `WriteDelta`.
pub fn delta_blocks(data: &[u8], dirty: &[bool], block_bytes: usize) -> Vec<(u32, Vec<u8>)> {
    dirty
        .iter()
        .enumerate()
        .filter(|(_, &d)| d)
        .map(|(i, _)| {
            let start = i * block_bytes;
            let end = (start + block_bytes).min(data.len());
            (i as u32, data[start.min(data.len())..end].to_vec())
        })
        .collect()
}

/// Decide the writeback op for a closed file: a digest-delta when the
/// cached base digests admit one and it saves enough payload, otherwise
/// the full aggregated content (the paper's baseline behaviour).
#[allow(clippy::too_many_arguments)]
pub fn build_writeback(
    engine: &Arc<DigestEngine>,
    cfg: &StripeConfig,
    path: &str,
    data: &[u8],
    base_version: u64,
    old_digests: &[i32],
    block_bytes: usize,
    metrics: &Metrics,
) -> (MetaOp, Vec<i32>) {
    let plan = engine.plan(data, old_digests, block_bytes, cfg.max_stripes);
    let digests = plan.digests.clone();
    let full_bytes = data.len() as u64;
    let dirty_bytes: u64 = delta_bytes(&plan.dirty, data.len(), block_bytes);
    let use_delta = cfg.delta_writeback
        && !old_digests.is_empty()
        && base_version > 0
        // a delta must actually save payload to be worth the stale-base risk
        && dirty_bytes * 2 < full_bytes.max(1);
    if use_delta {
        metrics.add(names::WRITEBACK_BYTES_SAVED, full_bytes.saturating_sub(dirty_bytes));
        let blocks = delta_blocks(data, &plan.dirty, block_bytes);
        (
            MetaOp::WriteDelta {
                path: path.to_string(),
                total_size: full_bytes,
                base_version,
                blocks,
                digests: digests.clone(),
            },
            digests,
        )
    } else {
        (
            MetaOp::WriteFull { path: path.to_string(), data: data.to_vec(), digests: digests.clone() },
            digests,
        )
    }
}

fn delta_bytes(dirty: &[bool], data_len: usize, block_bytes: usize) -> u64 {
    dirty
        .iter()
        .enumerate()
        .filter(|(_, &d)| d)
        .map(|(i, _)| {
            let start = i * block_bytes;
            let end = (start + block_bytes).min(data_len);
            end.saturating_sub(start) as u64
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StripeConfig;

    fn cfg() -> StripeConfig {
        StripeConfig::default()
    }

    fn engine() -> Arc<DigestEngine> {
        Arc::new(DigestEngine::native(Metrics::new()))
    }

    #[test]
    fn stripe_policy_matches_paper() {
        let c = cfg();
        assert_eq!(stripes_for(0, &c), 1);
        assert_eq!(stripes_for(64 * 1024, &c), 1, "<=64 KiB not striped");
        assert_eq!(stripes_for(64 * 1024 + 1, &c), 2);
        assert_eq!(stripes_for(512 * 1024, &c), 8);
        assert_eq!(stripes_for(1 << 30, &c), 12, "capped at 12");
    }

    #[test]
    fn stripe_policy_respects_overrides() {
        let mut c = cfg();
        c.max_stripes = 4;
        assert_eq!(stripes_for(1 << 30, &c), 4);
        c.stripe_threshold = 0;
        assert_eq!(stripes_for(1, &c), 1);
    }

    #[test]
    fn verify_accepts_good_rejects_corrupt() {
        let e = engine();
        let m = Metrics::new();
        let data = vec![0x42u8; 150_000];
        let digests = e.digests(&data, 65536);
        let mut image = FileImage { path: "/f".into(), version: 1, data, digests };
        verify_image(&e, &image, 65536, &m).unwrap();
        image.data[100_000] ^= 1;
        let err = verify_image(&e, &image, 65536, &m).unwrap_err();
        assert!(matches!(err, FsError::Protocol(_)));
        assert_eq!(m.counter("transfer.integrity_failures"), 1);
    }

    #[test]
    fn verify_skips_digestless_images() {
        let e = engine();
        let image = FileImage { path: "/f".into(), version: 1, data: vec![1, 2, 3], digests: vec![] };
        verify_image(&e, &image, 65536, &Metrics::new()).unwrap();
    }

    #[test]
    fn writeback_small_change_uses_delta() {
        let e = engine();
        let m = Metrics::new();
        let mut data = vec![7u8; 1 << 20]; // 16 blocks
        let old = e.digests(&data, 65536);
        data[0] ^= 0xFF; // one dirty block
        let (op, digests) = build_writeback(&e, &cfg(), "/f", &data, 3, &old, 65536, &m);
        match op {
            MetaOp::WriteDelta { blocks, base_version, total_size, .. } => {
                assert_eq!(blocks.len(), 1);
                assert_eq!(blocks[0].0, 0);
                assert_eq!(base_version, 3);
                assert_eq!(total_size, 1 << 20);
            }
            other => panic!("expected delta, got {other:?}"),
        }
        assert_eq!(digests, e.digests(&data, 65536));
        assert!(m.counter(names::WRITEBACK_BYTES_SAVED) > 900_000);
    }

    #[test]
    fn writeback_new_file_uses_full() {
        let e = engine();
        let data = vec![7u8; 1 << 20];
        let (op, _) = build_writeback(&e, &cfg(), "/f", &data, 0, &[], 65536, &Metrics::new());
        assert!(matches!(op, MetaOp::WriteFull { .. }));
    }

    #[test]
    fn writeback_mostly_changed_uses_full() {
        let e = engine();
        let mut data = vec![7u8; 1 << 20];
        let old = e.digests(&data, 65536);
        for b in data.iter_mut() {
            *b ^= 0xFF; // everything dirty
        }
        let (op, _) = build_writeback(&e, &cfg(), "/f", &data, 3, &old, 65536, &Metrics::new());
        assert!(matches!(op, MetaOp::WriteFull { .. }));
    }

    #[test]
    fn writeback_respects_delta_disable() {
        let e = engine();
        let mut c = cfg();
        c.delta_writeback = false;
        let mut data = vec![7u8; 1 << 20];
        let old = e.digests(&data, 65536);
        data[0] ^= 0xFF;
        let (op, _) = build_writeback(&e, &c, "/f", &data, 3, &old, 65536, &Metrics::new());
        assert!(matches!(op, MetaOp::WriteFull { .. }));
    }

    #[test]
    fn delta_blocks_extract_right_ranges() {
        let data: Vec<u8> = (0..200u8).collect();
        let dirty = vec![false, true, false, true];
        let blocks = delta_blocks(&data, &dirty, 64);
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].0, 1);
        assert_eq!(blocks[0].1, (64..128).map(|x| x as u8).collect::<Vec<_>>());
        assert_eq!(blocks[1].0, 3);
        assert_eq!(blocks[1].1, (192..200).map(|x| x as u8).collect::<Vec<_>>());
    }
}
