//! Striped-transfer engine helpers (paper §3.3).
//!
//! "All data transfers in XUFS over 64 Kbytes are striped across multiple
//! TCP connections. XUFS uses up to 12 stripes with a minimum 64 kilobytes
//! block size each … [and] spawn[s] multiple (12 by default) parallel
//! threads for pre-fetching files smaller than 64 kilobytes."
//!
//! This module holds the transport-independent pieces: stripe-count
//! policy, integrity verification of fetched images against their
//! per-block digests (via the AOT digest engine), and construction of the
//! writeback op (full vs digest-delta) from a [`TransferPlan`].

pub mod compress;
mod tuner;

pub use tuner::AutoTuner;

use std::sync::Arc;

use crate::config::StripeConfig;
use crate::homefs::FsError;
use crate::metrics::{names, Metrics};
use crate::proto::{BlockExtent, FileImage, MetaOp};
use crate::runtime::DigestEngine;

/// How many TCP stripes a transfer of `bytes` uses: 1 below the striping
/// threshold, then one per `min_block`, capped at `max_stripes`. Always
/// at least 1, even for `bytes = 0` with a zero threshold — a transfer
/// plan must never degenerate to zero stripes.
pub fn stripes_for(bytes: u64, cfg: &StripeConfig) -> usize {
    if bytes <= cfg.stripe_threshold {
        return 1;
    }
    let by_block = bytes.div_ceil(cfg.min_block.max(1)).max(1) as usize;
    by_block.clamp(1, cfg.max_stripes.max(1))
}

/// A block-aligned fetch extent with its stripe fan-out: the
/// generalization of the whole-file stripe plan to an arbitrary byte
/// range. A whole file is the degenerate case `plan_range(0, size, size)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExtentPlan {
    /// Block-aligned start offset.
    pub offset: u64,
    /// Bytes to fetch (end clamped to the file size).
    pub len: u64,
    /// Parallel connections the transfer stripes across.
    pub stripes: usize,
}

/// Plan a range fetch exactly like a whole-file transfer: align the
/// requested range outward to block boundaries (clamped to `size`), then
/// stripe the payload by the same policy as [`stripes_for`].
pub fn plan_range(offset: u64, len: u64, size: u64, cfg: &StripeConfig) -> ExtentPlan {
    if offset >= size || len == 0 {
        return ExtentPlan { offset: offset.min(size), len: 0, stripes: 1 };
    }
    let bb = cfg.min_block.max(1);
    let start = (offset / bb) * bb;
    let end = offset.saturating_add(len).min(size);
    let end = end.div_ceil(bb).saturating_mul(bb).min(size);
    let len = end.saturating_sub(start);
    ExtentPlan { offset: start, len, stripes: stripes_for(len, cfg) }
}

/// Verify fetched block extents end-to-end: recompute each block's digest
/// from the received bytes and compare to the digest the server sent. A
/// mismatch means a corrupted stripe — callers re-fetch.
pub fn verify_extents(
    engine: &Arc<DigestEngine>,
    path: &str,
    extents: &[BlockExtent],
    block_bytes: usize,
    metrics: &Metrics,
) -> Result<(), FsError> {
    for x in extents {
        let got = engine.digests(&x.data, block_bytes);
        if x.data.is_empty() || x.data.len() > block_bytes || got != [x.digest] {
            metrics.incr(names::INTEGRITY_FAILURES);
            return Err(FsError::Protocol(format!(
                "integrity check failed for {path} block {} ({} bytes)",
                x.index,
                x.data.len()
            )));
        }
    }
    Ok(())
}

/// Verify a fetched image end-to-end: recompute per-block digests of the
/// received bytes and compare to the digests the server sent. A mismatch
/// means a corrupted stripe — callers re-fetch.
pub fn verify_image(
    engine: &Arc<DigestEngine>,
    image: &FileImage,
    block_bytes: usize,
    metrics: &Metrics,
) -> Result<(), FsError> {
    if image.digests.is_empty() {
        if image.data.is_empty() {
            // an empty file legitimately has no block digests
            return Ok(());
        }
        // Our server always digests non-empty content, so a digestless
        // image for real bytes is integrity laundering: a tampered reply
        // that strips the digest vector must not skip verification
        // (DESIGN.md §2.10 — same refusal class as the server's code 118).
        metrics.incr(names::INTEGRITY_FAILURES);
        return Err(FsError::Corrupted(format!(
            "{} arrived without digests for {} bytes — refusing unverifiable content",
            image.path,
            image.data.len()
        )));
    }
    let got = engine.digests(&image.data, block_bytes);
    if got != image.digests {
        metrics.incr(names::INTEGRITY_FAILURES);
        return Err(FsError::Protocol(format!(
            "integrity check failed for {} ({} blocks, {} mismatched)",
            image.path,
            got.len(),
            got.iter().zip(&image.digests).filter(|(a, b)| a != b).count()
        )));
    }
    Ok(())
}

/// Extract the dirty blocks named by a plan as `(block_index, bytes)`
/// payloads for a `WriteDelta`.
pub fn delta_blocks(data: &[u8], dirty: &[bool], block_bytes: usize) -> Vec<(u32, Vec<u8>)> {
    dirty
        .iter()
        .enumerate()
        .filter(|(_, &d)| d)
        .map(|(i, _)| {
            let start = i * block_bytes;
            let end = (start + block_bytes).min(data.len());
            (i as u32, data[start.min(data.len())..end].to_vec())
        })
        .collect()
}

/// Decide the writeback op for a closed file: a digest-delta when the
/// cached base digests admit one and it saves enough payload, otherwise
/// the full aggregated content (the paper's baseline behaviour).
#[allow(clippy::too_many_arguments)]
pub fn build_writeback(
    engine: &Arc<DigestEngine>,
    cfg: &StripeConfig,
    path: &str,
    data: &[u8],
    base_version: u64,
    old_digests: &[i32],
    block_bytes: usize,
    metrics: &Metrics,
) -> (MetaOp, Vec<i32>) {
    let plan = engine.plan(data, old_digests, block_bytes, cfg.max_stripes);
    let digests = plan.digests.clone();
    let full_bytes = data.len() as u64;
    let dirty_bytes: u64 = delta_bytes(&plan.dirty, data.len(), block_bytes);
    let use_delta = cfg.delta_writeback
        && !old_digests.is_empty()
        && base_version > 0
        // a delta must actually save payload to be worth the stale-base risk
        && dirty_bytes * 2 < full_bytes.max(1);
    if use_delta {
        metrics.add(names::WRITEBACK_BYTES_SAVED, full_bytes.saturating_sub(dirty_bytes));
        let blocks = delta_blocks(data, &plan.dirty, block_bytes);
        (
            MetaOp::WriteDelta {
                path: path.to_string(),
                total_size: full_bytes,
                base_version,
                blocks,
                digests: digests.clone(),
            },
            digests,
        )
    } else {
        (
            MetaOp::WriteFull {
                path: path.to_string(),
                data: data.to_vec(),
                digests: digests.clone(),
                base_version: 0,
            },
            digests,
        )
    }
}

fn delta_bytes(dirty: &[bool], data_len: usize, block_bytes: usize) -> u64 {
    dirty
        .iter()
        .enumerate()
        .filter(|(_, &d)| d)
        .map(|(i, _)| {
            let start = i * block_bytes;
            let end = (start + block_bytes).min(data_len);
            end.saturating_sub(start) as u64
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StripeConfig;

    fn cfg() -> StripeConfig {
        StripeConfig::default()
    }

    fn engine() -> Arc<DigestEngine> {
        Arc::new(DigestEngine::native(Metrics::new()))
    }

    #[test]
    fn stripe_policy_matches_paper() {
        let c = cfg();
        assert_eq!(stripes_for(0, &c), 1);
        assert_eq!(stripes_for(64 * 1024, &c), 1, "<=64 KiB not striped");
        assert_eq!(stripes_for(64 * 1024 + 1, &c), 2);
        assert_eq!(stripes_for(512 * 1024, &c), 8);
        assert_eq!(stripes_for(1 << 30, &c), 12, "capped at 12");
    }

    #[test]
    fn stripe_policy_respects_overrides() {
        let mut c = cfg();
        c.max_stripes = 4;
        assert_eq!(stripes_for(1 << 30, &c), 4);
        c.stripe_threshold = 0;
        assert_eq!(stripes_for(1, &c), 1);
    }

    #[test]
    fn stripe_policy_boundaries_never_zero() {
        // threshold boundaries: exactly at the threshold stays 1 stripe,
        // one past it stripes — and bytes = 0 is always exactly 1 stripe,
        // even with a zero threshold (no zero-block plans)
        let mut c = cfg();
        for threshold in [0u64, 64 * 1024, 1 << 20] {
            c.stripe_threshold = threshold;
            assert_eq!(stripes_for(0, &c), 1, "threshold {threshold}");
            assert_eq!(stripes_for(threshold, &c), 1, "threshold {threshold}");
            assert!(stripes_for(threshold + 1, &c) >= 1, "threshold {threshold}");
        }
        c.stripe_threshold = 0;
        assert_eq!(stripes_for(1, &c), 1, "1 byte is one min_block share");
        assert_eq!(stripes_for(c.min_block + 1, &c), 2);
        // degenerate config: zero min_block must not divide by zero
        c.min_block = 0;
        assert!(stripes_for(1 << 20, &c) >= 1);
    }

    #[test]
    fn plan_range_aligns_and_stripes_like_whole_file() {
        let c = cfg();
        let size = 10 * 64 * 1024 + 100; // 10 full blocks + ragged tail
        // mid-file range aligns outward to block boundaries
        let p = plan_range(70_000, 10_000, size, &c);
        assert_eq!(p.offset, 64 * 1024);
        assert_eq!(p.len, 64 * 1024);
        assert_eq!(p.stripes, 1);
        // range crossing a boundary covers both blocks
        let p = plan_range(64 * 1024 - 1, 2, size, &c);
        assert_eq!(p.offset, 0);
        assert_eq!(p.len, 2 * 64 * 1024);
        // the whole file is the degenerate case, striped identically
        let p = plan_range(0, size, size, &c);
        assert_eq!((p.offset, p.len), (0, size));
        assert_eq!(p.stripes, stripes_for(size, &c));
        // tail range clamps to the ragged end
        let p = plan_range(10 * 64 * 1024, 1 << 20, size, &c);
        assert_eq!(p.offset, 10 * 64 * 1024);
        assert_eq!(p.len, 100);
        // fully out-of-range request degenerates to an empty plan
        let p = plan_range(size + 5, 10, size, &c);
        assert_eq!(p.len, 0);
        assert_eq!(p.stripes, 1);
    }

    #[test]
    fn verify_extents_accepts_good_rejects_corrupt() {
        let e = engine();
        let m = Metrics::new();
        let data = vec![0x42u8; 200_000];
        let digests = e.digests(&data, 65536);
        let mut extents: Vec<BlockExtent> = (0..4)
            .map(|i| {
                let start = i * 65536;
                let end = (start + 65536).min(data.len());
                BlockExtent { index: i as u32, data: data[start..end].to_vec(), digest: digests[i] }
            })
            .collect();
        verify_extents(&e, "/f", &extents, 65536, &m).unwrap();
        // per-block digests match the whole-file digest vector exactly
        extents[2].data[100] ^= 1;
        let err = verify_extents(&e, "/f", &extents, 65536, &m).unwrap_err();
        assert!(matches!(err, FsError::Protocol(_)));
        assert_eq!(m.counter("transfer.integrity_failures"), 1);
        // an oversized block is rejected even with a "matching" digest
        let big = vec![0u8; 65537];
        let bad = BlockExtent { index: 0, digest: e.digests(&big, 65537)[0], data: big };
        assert!(verify_extents(&e, "/f", &[bad], 65536, &m).is_err());
    }

    #[test]
    fn verify_accepts_good_rejects_corrupt() {
        let e = engine();
        let m = Metrics::new();
        let data = vec![0x42u8; 150_000];
        let digests = e.digests(&data, 65536);
        let mut image = FileImage { path: "/f".into(), version: 1, data, digests };
        verify_image(&e, &image, 65536, &m).unwrap();
        image.data[100_000] ^= 1;
        let err = verify_image(&e, &image, 65536, &m).unwrap_err();
        assert!(matches!(err, FsError::Protocol(_)));
        assert_eq!(m.counter("transfer.integrity_failures"), 1);
    }

    #[test]
    fn verify_refuses_digestless_nonempty_images() {
        // stripping the digest vector must not launder tampered bytes
        // past verification: typed Corrupted refusal, counted
        let e = engine();
        let m = Metrics::new();
        let image = FileImage { path: "/f".into(), version: 1, data: vec![1, 2, 3], digests: vec![] };
        let err = verify_image(&e, &image, 65536, &m).unwrap_err();
        assert!(matches!(err, FsError::Corrupted(_)), "{err:?}");
        assert_eq!(m.counter(names::INTEGRITY_FAILURES), 1);
        // an empty file legitimately has no digests
        let empty = FileImage { path: "/e".into(), version: 1, data: vec![], digests: vec![] };
        verify_image(&e, &empty, 65536, &m).unwrap();
        assert_eq!(m.counter(names::INTEGRITY_FAILURES), 1);
    }

    #[test]
    fn writeback_small_change_uses_delta() {
        let e = engine();
        let m = Metrics::new();
        let mut data = vec![7u8; 1 << 20]; // 16 blocks
        let old = e.digests(&data, 65536);
        data[0] ^= 0xFF; // one dirty block
        let (op, digests) = build_writeback(&e, &cfg(), "/f", &data, 3, &old, 65536, &m);
        match op {
            MetaOp::WriteDelta { blocks, base_version, total_size, .. } => {
                assert_eq!(blocks.len(), 1);
                assert_eq!(blocks[0].0, 0);
                assert_eq!(base_version, 3);
                assert_eq!(total_size, 1 << 20);
            }
            other => panic!("expected delta, got {other:?}"),
        }
        assert_eq!(digests, e.digests(&data, 65536));
        assert!(m.counter(names::WRITEBACK_BYTES_SAVED) > 900_000);
    }

    #[test]
    fn writeback_new_file_uses_full() {
        let e = engine();
        let data = vec![7u8; 1 << 20];
        let (op, _) = build_writeback(&e, &cfg(), "/f", &data, 0, &[], 65536, &Metrics::new());
        assert!(matches!(op, MetaOp::WriteFull { .. }));
    }

    #[test]
    fn writeback_mostly_changed_uses_full() {
        let e = engine();
        let mut data = vec![7u8; 1 << 20];
        let old = e.digests(&data, 65536);
        for b in data.iter_mut() {
            *b ^= 0xFF; // everything dirty
        }
        let (op, _) = build_writeback(&e, &cfg(), "/f", &data, 3, &old, 65536, &Metrics::new());
        assert!(matches!(op, MetaOp::WriteFull { .. }));
    }

    #[test]
    fn writeback_respects_delta_disable() {
        let e = engine();
        let mut c = cfg();
        c.delta_writeback = false;
        let mut data = vec![7u8; 1 << 20];
        let old = e.digests(&data, 65536);
        data[0] ^= 0xFF;
        let (op, _) = build_writeback(&e, &c, "/f", &data, 3, &old, 65536, &Metrics::new());
        assert!(matches!(op, MetaOp::WriteFull { .. }));
    }

    #[test]
    fn delta_blocks_extract_right_ranges() {
        let data: Vec<u8> = (0..200u8).collect();
        let dirty = vec![false, true, false, true];
        let blocks = delta_blocks(&data, &dirty, 64);
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].0, 1);
        assert_eq!(blocks[0].1, (64..128).map(|x| x as u8).collect::<Vec<_>>());
        assert_eq!(blocks[1].0, 3);
        assert_eq!(blocks[1].1, (192..200).map(|x| x as u8).collect::<Vec<_>>());
    }
}
