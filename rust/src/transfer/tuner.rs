//! Adaptive stripe-count tuner (transport v2, DESIGN.md §2.12).
//!
//! The paper stripes every large transfer across a static 12 TCP
//! connections (§3.3); the GridFTP line shows the right parallel-stream
//! count is a property of the path, not the config file. [`AutoTuner`]
//! hill-climbs it per mount: each completed extent reports its payload
//! and transfer time, the tuner folds the implied goodput into an EWMA,
//! and the stripe count for the NEXT extent steps by one in whichever
//! direction the last step helped — growing past a static plan on
//! paths where per-stream throughput is the bottleneck (thin/lossy
//! links) and backing off where aggregate capacity binds.

use crate::metrics::{names, Metrics};

/// Per-mount adaptive stripe-count controller. One-step hill climb with
/// a deadband: goodput clearly up → keep stepping the same way; clearly
/// down → reverse; flat → hold (converged).
#[derive(Debug)]
pub struct AutoTuner {
    stripes: usize,
    max_stripes: usize,
    /// Goodput (bytes/sec) observed at the previous extent; 0 until the
    /// first observation lands.
    last_goodput: f64,
    /// Smoothed goodput, reported for diagnostics.
    ewma_goodput: f64,
    dir: i8,
    adjustments: u64,
}

/// Relative goodput change below which the tuner holds its count.
const DEADBAND: f64 = 0.05;
/// EWMA weight of the newest observation.
const ALPHA: f64 = 0.5;

impl AutoTuner {
    pub fn new(initial: usize, max_stripes: usize) -> Self {
        let max_stripes = max_stripes.max(1);
        AutoTuner {
            stripes: initial.clamp(1, max_stripes),
            max_stripes,
            last_goodput: 0.0,
            ewma_goodput: 0.0,
            dir: 1,
            adjustments: 0,
        }
    }

    /// The stripe count the next extent should use.
    pub fn stripes(&self) -> usize {
        self.stripes
    }

    /// Stripe-count changes made so far.
    pub fn adjustments(&self) -> u64 {
        self.adjustments
    }

    /// Smoothed goodput estimate, bytes/sec (0 before any observation).
    pub fn goodput(&self) -> f64 {
        self.ewma_goodput
    }

    /// Feed one completed extent: `bytes` moved in `secs` at the current
    /// stripe count. Decides the count for the next extent.
    pub fn observe(&mut self, bytes: u64, secs: f64, metrics: &Metrics) {
        if bytes == 0 || secs <= 0.0 {
            return;
        }
        let goodput = bytes as f64 / secs;
        self.ewma_goodput = if self.ewma_goodput == 0.0 {
            goodput
        } else {
            ALPHA * goodput + (1.0 - ALPHA) * self.ewma_goodput
        };
        let prev = self.last_goodput;
        self.last_goodput = goodput;
        if prev > 0.0 {
            if goodput > prev * (1.0 + DEADBAND) {
                // clearly better since the last step: keep climbing
            } else if goodput < prev * (1.0 - DEADBAND) {
                self.dir = -self.dir;
            } else {
                return; // flat: converged, hold the count
            }
        }
        // first observation falls through: probe upward once so a flat
        // link still gets explored
        let next = (self.stripes as i64 + self.dir as i64).clamp(1, self.max_stripes as i64);
        if next as usize != self.stripes {
            self.stripes = next as usize;
            self.adjustments += 1;
            metrics.incr(names::STRIPE_ADJUSTMENTS);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WanConfig;
    use crate::simnet::{SimClock, TransferKind, Wan};

    /// Drive the tuner against the analytic WAN model: each iteration
    /// transfers one extent at the tuner's current count and feeds the
    /// modeled duration back.
    fn converge(wan: &Wan, extent: u64, iters: usize) -> AutoTuner {
        let m = Metrics::new();
        let mut t = AutoTuner::new(1, 12);
        for _ in 0..iters {
            let secs = wan.transfer_secs(extent, t.stripes(), TransferKind::WarmConnections);
            t.observe(extent, secs, &m);
        }
        assert_eq!(t.adjustments(), m.counter(names::STRIPE_ADJUSTMENTS));
        t
    }

    #[test]
    fn converges_near_static_optimal_on_steady_symmetric_link() {
        // aggregate = 4 per-stream shares: every count >= 4 moves the
        // extent in the same time, so 4 is the static-optimal plan
        let cfg = WanConfig {
            rtt_s: 0.032,
            per_stream_bps: 2.0 * 1024.0 * 1024.0,
            agg_bps: 8.0 * 1024.0 * 1024.0,
            setup_rtts: 3.0,
            slow_start_rtts: 4.0,
        };
        let wan = Wan::new(cfg, SimClock::new());
        let t = converge(&wan, 4 << 20, 32);
        let optimal = 4i64;
        assert!(
            (t.stripes() as i64 - optimal).abs() <= 1,
            "converged to {} stripes, static-optimal is {optimal}",
            t.stripes()
        );
        assert!(t.goodput() > 0.0);
    }

    #[test]
    fn grows_to_the_cap_when_per_stream_binds() {
        // thin per-stream pipes, huge aggregate: more stripes always help
        let cfg = WanConfig {
            rtt_s: 0.032,
            per_stream_bps: 512.0 * 1024.0,
            agg_bps: 1e9,
            setup_rtts: 3.0,
            slow_start_rtts: 4.0,
        };
        let wan = Wan::new(cfg, SimClock::new());
        let t = converge(&wan, 8 << 20, 32);
        assert!(t.stripes() >= 11, "got {}", t.stripes());
    }

    #[test]
    fn holds_inside_the_deadband_and_clamps() {
        let m = Metrics::new();
        let mut t = AutoTuner::new(6, 8);
        assert_eq!(t.stripes(), 6);
        t.observe(1 << 20, 1.0, &m); // first probe steps up
        assert_eq!(t.stripes(), 7);
        t.observe(1 << 20, 1.0, &m); // flat: hold
        t.observe(1 << 20, 1.0, &m);
        assert_eq!(t.stripes(), 7);
        assert_eq!(t.adjustments(), 1);
        // degenerate inputs are ignored
        t.observe(0, 1.0, &m);
        t.observe(1 << 20, 0.0, &m);
        assert_eq!(t.stripes(), 7);
        // a clear degradation reverses direction
        t.observe(1 << 20, 2.0, &m);
        assert_eq!(t.stripes(), 6);
        // initial count clamps into [1, max]
        assert_eq!(AutoTuner::new(0, 4).stripes(), 1);
        assert_eq!(AutoTuner::new(99, 4).stripes(), 4);
        assert_eq!(AutoTuner::new(3, 0).stripes(), 1, "max clamps to at least 1");
    }
}
