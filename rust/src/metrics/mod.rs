//! Metrics registry: counters, gauges and latency histograms for every
//! subsystem. The registry is cheap to clone (Arc) so server, client and
//! transfer engine can share one sink; benches snapshot it for reports.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::util::stats::Histogram;
use crate::util::Json;

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

/// Shared metrics sink.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    inner: Arc<Mutex<Inner>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter by `n`.
    pub fn add(&self, name: &str, n: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_insert(0) += n;
    }

    /// Increment a counter by 1.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn set_gauge(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// Record a latency sample (seconds) into a named histogram
    /// (exponential buckets 1 µs … ~1100 s).
    pub fn observe(&self, name: &str, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::exponential(1e-6, 2.0, 31))
            .record(secs);
    }

    pub fn histogram_mean(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().histograms.get(name).map(|h| h.mean())
    }

    pub fn histogram_count(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().histograms.get(name).map(|h| h.count()).unwrap_or(0)
    }

    pub fn histogram_quantile(&self, name: &str, q: f64) -> Option<f64> {
        self.inner.lock().unwrap().histograms.get(name).map(|h| h.quantile(q))
    }

    /// Reset everything (between bench runs).
    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.counters.clear();
        g.gauges.clear();
        g.histograms.clear();
    }

    /// Snapshot as JSON (for bench reports / the CLI `--metrics` flag).
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let mut counters = Json::obj();
        for (k, v) in &g.counters {
            counters = counters.set(k, *v);
        }
        let mut gauges = Json::obj();
        for (k, v) in &g.gauges {
            gauges = gauges.set(k, *v);
        }
        let mut hists = Json::obj();
        for (k, h) in &g.histograms {
            hists = hists.set(
                k,
                Json::obj()
                    .set("count", h.count())
                    .set("mean_s", h.mean())
                    .set("p50_s", h.quantile(0.5))
                    .set("p99_s", h.quantile(0.99)),
            );
        }
        Json::obj().set("counters", counters).set("gauges", gauges).set("histograms", hists)
    }
}

/// Well-known metric names (typo safety — use these constants, not ad-hoc
/// strings, from subsystem code).
///
/// Every name also appears in [`names::ALL`] with a one-line meaning;
/// the repo-root `METRICS.md` is generated from that table
/// (`xufs metrics-md`) and a test keeps the two in sync.
pub mod names {
    pub const WAN_BYTES_TX: &str = "wan.bytes_tx";
    pub const WAN_BYTES_RX: &str = "wan.bytes_rx";
    pub const WAN_RPCS: &str = "wan.rpcs";
    pub const WAN_CONNECTS: &str = "wan.connects";
    /// Compound round trips issued (one per `Request::Compound`).
    pub const COMPOUND_RPCS: &str = "wan.compound_rpcs";
    /// Meta-ops carried inside compound round trips.
    pub const COMPOUND_OPS: &str = "wan.compound_ops";
    pub const CACHE_HITS: &str = "cache.hits";
    pub const CACHE_MISSES: &str = "cache.misses";
    pub const CACHE_INVALIDATIONS: &str = "cache.invalidations";
    pub const CACHE_EVICTIONS: &str = "cache.evictions";
    pub const FETCH_FILES: &str = "transfer.fetch_files";
    pub const FETCH_BYTES: &str = "transfer.fetch_bytes";
    /// Paged range fetches issued (demand-paging fault-ins).
    pub const RANGE_FETCHES: &str = "transfer.range_fetches";
    /// Bytes evicted by the budgeted LRU block eviction.
    pub const CACHE_EVICTED_BYTES: &str = "cache.evicted_bytes";
    /// Entries demoted to Invalid by recover on unknown persisted tokens.
    pub const CACHE_RECOVER_DEMOTED: &str = "cache.recover_demoted";
    pub const PREFETCH_FILES: &str = "transfer.prefetch_files";
    pub const WRITEBACK_FILES: &str = "transfer.writeback_files";
    pub const WRITEBACK_BYTES: &str = "transfer.writeback_bytes";
    pub const WRITEBACK_BYTES_SAVED: &str = "transfer.writeback_bytes_saved";
    pub const DIGEST_BLOCKS: &str = "runtime.digest_blocks";
    pub const DIGEST_CALLS: &str = "runtime.digest_calls";
    pub const METAQ_APPENDS: &str = "metaq.appends";
    pub const METAQ_REPLAYS: &str = "metaq.replays";
    /// Replayed ops skipped because their target vanished while the op
    /// sat queued (unlink/rename raced the disconnected write).
    pub const METAQ_REPLAY_SKIPPED: &str = "metaq.replay_skipped";
    /// Faults the fault plane injected (any non-clean delivery).
    pub const FAULTS_INJECTED: &str = "fault.injected";
    /// Interactions refused because the link was partitioned.
    pub const FAULT_PARTITIONED_OPS: &str = "fault.partitioned_ops";
    /// Torn transfers that were transparently resumed mid-range.
    pub const RESUMED_FETCHES: &str = "transfer.resumed_fetches";
    /// Loser copies preserved as `.xufs-conflict-<client>-<seq>` files at the
    /// home space instead of being silently overwritten.
    pub const CONFLICT_FILES: &str = "server.conflict_files";
    pub const LEASE_RENEWALS: &str = "lease.renewals";
    pub const LEASE_EXPIRED: &str = "lease.expired";
    pub const CALLBACKS_SENT: &str = "server.callbacks_sent";
    pub const AUTH_FAILURES: &str = "server.auth_failures";
    /// Shard-lock acquisitions that found the lock held (the request
    /// blocked behind another client on the same namespace shard).
    pub const SHARD_CONTENTION: &str = "server.shard_contention";
    /// Operations that took locks on more than one namespace shard
    /// (cross-shard renames, callback-registry broadcasts).
    pub const CROSS_SHARD_OPS: &str = "server.cross_shard_ops";
    /// TCP connections accepted by the server front-end (reactor or
    /// legacy core).
    pub const SERVER_ACCEPTS: &str = "server.accepts";
    /// Transient `accept()` failures survived — counted and retried,
    /// never a dead listener.
    pub const SERVER_ACCEPT_ERRORS: &str = "server.accept_errors";
    /// Gauge: currently open client connections on the TCP front-end.
    pub const SERVER_ACTIVE_CONNS: &str = "server.active_conns";
    /// Connections/requests refused by admission control with the typed
    /// busy code (117): over `max_connections` or pipelining past
    /// `max_inflight_per_conn`.
    pub const SERVER_BACKPRESSURE_REJECTS: &str = "server.backpressure_rejects";
    /// Per-connection codec buffers rewound and reused without
    /// reallocation (the v2 streaming codec's no-alloc steady state).
    pub const CODEC_BUF_REUSES: &str = "codec.buf_reuses";
    /// Gauge: applied ops the secondary trails the primary's replication
    /// log by (refreshed on every ship attempt).
    pub const REPLICA_LAG: &str = "replica.lag_ops";
    /// Client connects that landed on a different endpoint than the one
    /// previously active (primary -> promoted secondary, or back).
    pub const REPLICA_FAILOVERS: &str = "replica.failovers";
    /// `Replicate` frames the shipper successfully delivered.
    pub const REPLICA_SHIP_BATCHES: &str = "replica.ship_batches";
    /// Applied-op log records dropped by acked-prefix truncation.
    pub const REPLICA_LOG_TRUNCATED: &str = "replica.log_truncated";
    /// Chunks pushed to the secondary to fill ref-shipping gaps.
    pub const REPLICA_CHUNK_PUSHES: &str = "replica.chunk_pushes";
    /// Read requests a serving secondary admitted past its bounded-
    /// staleness gate (read fan-out, DESIGN.md §2.11).
    pub const REPLICA_READ_HITS: &str = "replica.read_hits";
    /// Reads a secondary refused with code 119 `TooStale` (behind the
    /// staleness bound or the client's observed-version floor).
    pub const REPLICA_TOO_STALE: &str = "replica.too_stale";
    /// Replica reads the client transparently re-ran against the
    /// primary after a `TooStale`/unavailable answer.
    pub const REPLICA_READ_REDIRECTS: &str = "replica.redirects";
    /// Chunk writes that found an identical chunk already stored.
    pub const CHUNK_DEDUP_HITS: &str = "chunkstore.dedup_hits";
    /// Bytes dedup avoided storing (logical bytes of deduped chunks).
    pub const CHUNK_DEDUP_BYTES_SAVED: &str = "chunkstore.dedup_bytes_saved";
    /// Dead chunks the deferred GC sweep actually freed.
    pub const CHUNK_GC_COLLECTED: &str = "chunkstore.gc_collected";
    /// CoW snapshots taken of the home namespace.
    pub const CHUNK_SNAPSHOTS: &str = "chunkstore.snapshots";
    /// Chunks whose stored bytes no longer matched their digest (scrub
    /// sweep or verified-read refusal) — quarantined, never served.
    pub const CHUNK_SCRUB_ERRORS: &str = "chunkstore.scrub_errors";
    /// Quarantined chunks healed from a digest-verified replica fill.
    pub const CHUNK_REPAIRED: &str = "chunkstore.repaired";
    /// Background scrub slices run on the server op cadence.
    pub const INTEGRITY_SCRUB_TICKS: &str = "integrity.scrub_ticks";
    /// Op-log records dropped at recovery for a bad HMAC or torn frame.
    pub const METAQ_CORRUPT_RECORDS: &str = "metaq.corrupt_records";
    pub const OP_LATENCY: &str = "vfs.op_latency";
    /// Fault-ins fully or partially covered by a speculative pipelined
    /// readahead already in flight (transport v2, DESIGN.md §2.12).
    pub const PIPELINED_HITS: &str = "transfer.pipelined_hits";
    /// Bytes fetched speculatively by the readahead pipeline that no
    /// demand fault ever consumed (dropped stale/mismatched hints).
    pub const PIPELINE_WASTED_BYTES: &str = "transfer.pipeline_wasted_bytes";
    /// Stripe-count changes made by the adaptive transfer tuner.
    pub const STRIPE_ADJUSTMENTS: &str = "transfer.stripe_adjustments";
    /// Range replies refused by client-side verification (digest
    /// mismatch, or a digestless image for non-empty data).
    pub const INTEGRITY_FAILURES: &str = "transfer.integrity_failures";
    /// Bytes delta compression kept off the WAN (raw minus encoded,
    /// summed over compressed `WriteDelta` blocks).
    pub const COMPRESSED_BYTES_SAVED: &str = "writeback.compressed_bytes_saved";

    /// Every metric the system emits, with a one-line meaning. This is
    /// the source of truth behind `METRICS.md` (see [`metrics_md`]); a
    /// test asserts the two never drift apart.
    pub const ALL: &[(&str, &str)] = &[
        (WAN_BYTES_TX, "Bytes shipped client -> server over the WAN (meta-ops, writebacks)."),
        (WAN_BYTES_RX, "Bytes received server -> client over the WAN (fetches, prefetches)."),
        (WAN_RPCS, "Request/response round trips on the control connection."),
        (WAN_CONNECTS, "WAN connection setups (TCP + USSH handshake cost model)."),
        (COMPOUND_RPCS, "Compound round trips issued (one per `Request::Compound` frame)."),
        (COMPOUND_OPS, "Meta-ops carried inside compound round trips."),
        (CACHE_HITS, "Opens served entirely from the cache space (no WAN)."),
        (CACHE_MISSES, "Opens that had to consult the home space."),
        (CACHE_INVALIDATIONS, "Cache entries invalidated by callback notifications."),
        (CACHE_EVICTIONS, "Whole entries evicted by the capacity policy."),
        (FETCH_FILES, "Whole files fetched from the home space."),
        (FETCH_BYTES, "Bytes of file content fetched whole-file."),
        (RANGE_FETCHES, "Paged range fetches issued (demand-paging fault-ins)."),
        (CACHE_EVICTED_BYTES, "Bytes evicted by the budgeted LRU block eviction."),
        (CACHE_RECOVER_DEMOTED, "Entries demoted to Invalid by recover on unknown persisted tokens."),
        (PREFETCH_FILES, "Small files pulled by the parallel pre-fetch on first chdir."),
        (WRITEBACK_FILES, "Files written back to the home space on close/flush."),
        (WRITEBACK_BYTES, "Bytes actually shipped by writebacks (after delta planning)."),
        (WRITEBACK_BYTES_SAVED, "Bytes delta writeback avoided shipping vs a full write."),
        (DIGEST_BLOCKS, "Stripe blocks digested by the digest engine."),
        (DIGEST_CALLS, "Digest-engine invocations (whole-buffer calls)."),
        (METAQ_APPENDS, "Records appended to the durable op log."),
        (METAQ_REPLAYS, "Ops replayed from the op log after a reconnect or recovery."),
        (METAQ_REPLAY_SKIPPED, "Replayed ops skipped because their target vanished while queued."),
        (FAULTS_INJECTED, "Faults the fault plane injected (any non-clean delivery)."),
        (FAULT_PARTITIONED_OPS, "Interactions refused because the link was partitioned."),
        (RESUMED_FETCHES, "Torn transfers transparently resumed mid-range."),
        (CONFLICT_FILES, "Loser copies preserved as `.xufs-conflict-<client>-<seq>` files at home."),
        (LEASE_RENEWALS, "Lock-lease renewals granted by the server."),
        (LEASE_EXPIRED, "Orphaned lock leases expired by the server."),
        (CALLBACKS_SENT, "Invalidation/removal notifications pushed to registered clients."),
        (AUTH_FAILURES, "USSH authentication attempts the server rejected."),
        (SHARD_CONTENTION, "Shard-lock acquisitions that blocked behind another request."),
        (CROSS_SHARD_OPS, "Operations that locked more than one namespace shard."),
        (SERVER_ACCEPTS, "TCP connections accepted by the server front-end."),
        (SERVER_ACCEPT_ERRORS, "Transient accept() failures survived (listener kept alive)."),
        (SERVER_ACTIVE_CONNS, "Gauge: currently open client connections on the TCP front-end."),
        (SERVER_BACKPRESSURE_REJECTS, "Connections/requests refused with the typed busy code (117) by admission control."),
        (CODEC_BUF_REUSES, "Per-connection codec buffers rewound and reused without reallocation."),
        (REPLICA_LAG, "Gauge: applied ops the secondary trails the primary's replication log by."),
        (REPLICA_FAILOVERS, "Client connects that switched to a different endpoint (failover)."),
        (REPLICA_SHIP_BATCHES, "`Replicate` frames the log shipper successfully delivered."),
        (REPLICA_LOG_TRUNCATED, "Applied-op log records dropped by acked-prefix truncation."),
        (REPLICA_CHUNK_PUSHES, "Chunks pushed to the secondary to fill ref-shipping gaps."),
        (REPLICA_READ_HITS, "Read requests a serving secondary admitted past its staleness gate."),
        (REPLICA_TOO_STALE, "Reads a secondary refused with code 119 `TooStale`."),
        (REPLICA_READ_REDIRECTS, "Replica reads transparently re-run against the primary."),
        (CHUNK_DEDUP_HITS, "Chunk writes that found an identical chunk already stored."),
        (CHUNK_DEDUP_BYTES_SAVED, "Bytes dedup avoided storing (logical bytes of deduped chunks)."),
        (CHUNK_GC_COLLECTED, "Dead chunks the deferred GC sweep actually freed."),
        (CHUNK_SNAPSHOTS, "CoW snapshots taken of the home namespace."),
        (CHUNK_SCRUB_ERRORS, "Chunks detected corrupt (scrub or verified read) and quarantined."),
        (CHUNK_REPAIRED, "Quarantined chunks healed from a digest-verified replica fill."),
        (INTEGRITY_SCRUB_TICKS, "Background scrub slices run on the server op cadence."),
        (METAQ_CORRUPT_RECORDS, "Op-log records dropped at recovery for a bad HMAC or torn frame."),
        (OP_LATENCY, "Histogram of per-VFS-op latency, seconds."),
        (PIPELINED_HITS, "Fault-ins covered by a speculative pipelined readahead already in flight."),
        (PIPELINE_WASTED_BYTES, "Speculatively fetched bytes no demand fault ever consumed."),
        (STRIPE_ADJUSTMENTS, "Stripe-count changes made by the adaptive transfer tuner."),
        (INTEGRITY_FAILURES, "Range replies refused by client-side verification (bad or missing digests)."),
        (COMPRESSED_BYTES_SAVED, "Bytes delta compression kept off the WAN (raw minus encoded payloads)."),
    ];

    /// Render [`ALL`] as the `METRICS.md` table body. `xufs metrics-md`
    /// prints the full document; the sync test checks the shipped file
    /// contains exactly these rows.
    pub fn markdown_rows() -> String {
        let mut out = String::new();
        for (name, meaning) in ALL {
            out.push_str(&format!("| `{name}` | {meaning} |\n"));
        }
        out
    }

    /// The complete `METRICS.md` document (`xufs metrics-md` prints it;
    /// the repo-root file is exactly this output).
    pub fn metrics_md() -> String {
        let mut out = String::new();
        out.push_str("# XUFS metrics\n\n");
        out.push_str(
            "Every counter/gauge/histogram the system emits, by canonical name.\n\
             Names live in `rust/src/metrics/mod.rs` (`metrics::names`); subsystem\n\
             code uses those constants, never ad-hoc strings. This file is\n\
             GENERATED — regenerate with `cargo run -- metrics-md > METRICS.md`\n\
             after extending `names::ALL`; a test (`metrics::tests::\n\
             metrics_md_in_sync_with_names_table`) fails if the two drift.\n\n",
        );
        out.push_str("| metric | meaning |\n|---|---|\n");
        out.push_str(&markdown_rows());
        out.push_str(
            "\nSnapshot any deployment's values with `Metrics::to_json()` (the\n\
             CLI prints it after `xufs selftest`; bench tables embed it in their\n\
             JSON sidecars).\n",
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("a");
        m.add("a", 4);
        assert_eq!(m.counter("a"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn shared_across_clones() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.incr("x");
        assert_eq!(m.counter("x"), 1);
    }

    #[test]
    fn gauges_and_histograms() {
        let m = Metrics::new();
        m.set_gauge("g", 2.5);
        assert_eq!(m.gauge("g"), Some(2.5));
        m.observe("lat", 0.010);
        m.observe("lat", 0.020);
        assert_eq!(m.histogram_count("lat"), 2);
        let mean = m.histogram_mean("lat").unwrap();
        assert!((mean - 0.015).abs() < 1e-9);
    }

    #[test]
    fn reset_clears() {
        let m = Metrics::new();
        m.incr("a");
        m.observe("h", 1.0);
        m.reset();
        assert_eq!(m.counter("a"), 0);
        assert_eq!(m.histogram_count("h"), 0);
    }

    #[test]
    fn names_table_is_complete_and_unique() {
        let mut seen = std::collections::HashSet::new();
        for (name, meaning) in names::ALL {
            assert!(seen.insert(*name), "duplicate metric name {name}");
            assert!(!meaning.is_empty(), "{name} needs a meaning");
            let (subsystem, rest) = name.split_once('.').expect("names are subsystem.metric");
            assert!(!subsystem.is_empty() && !rest.is_empty(), "malformed name {name}");
        }
        // spot-check that the constants subsystem code actually uses are
        // all in the table (additions to `names` must extend `ALL`)
        for c in [
            names::WAN_RPCS,
            names::CACHE_HITS,
            names::RANGE_FETCHES,
            names::CONFLICT_FILES,
            names::SHARD_CONTENTION,
            names::CROSS_SHARD_OPS,
            names::OP_LATENCY,
        ] {
            assert!(seen.contains(c), "{c} missing from names::ALL");
        }
    }

    /// `METRICS.md` at the repo root documents every metric in
    /// [`names::ALL`] — regenerate it with `xufs metrics-md > METRICS.md`
    /// whenever the table changes.
    #[test]
    fn metrics_md_in_sync_with_names_table() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../METRICS.md");
        let doc = std::fs::read_to_string(path)
            .expect("METRICS.md at the repo root (regenerate: xufs metrics-md > METRICS.md)");
        for line in names::markdown_rows().lines() {
            assert!(
                doc.contains(line),
                "METRICS.md is stale — missing row:\n  {line}\nregenerate with `xufs metrics-md > METRICS.md`"
            );
        }
        let doc_rows = doc.lines().filter(|l| l.starts_with("| `")).count();
        assert_eq!(
            doc_rows,
            names::ALL.len(),
            "METRICS.md documents {doc_rows} metrics but names::ALL has {} — regenerate with `xufs metrics-md > METRICS.md`",
            names::ALL.len()
        );
    }

    #[test]
    fn json_snapshot() {
        let m = Metrics::new();
        m.incr(names::CACHE_HITS);
        m.observe(names::OP_LATENCY, 0.001);
        let j = m.to_json();
        assert_eq!(j.get("counters").unwrap().get(names::CACHE_HITS).unwrap().as_i64(), Some(1));
        assert!(j.get("histograms").unwrap().get(names::OP_LATENCY).is_some());
    }
}
