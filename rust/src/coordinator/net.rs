//! Real-TCP deployment: the same [`FileServer`] /
//! [`XufsClient`](crate::client::XufsClient) logic over
//! actual sockets on localhost, with the full USSH challenge-response
//! handshake per connection, genuinely parallel striped range-fetches, and
//! a push-mode callback channel fed by a pump thread. Used by integration
//! tests and the e2e example to prove the protocol works outside the
//! simulator.
//!
//! Since the sharded-server refactor (DESIGN.md §2.6) the server is
//! shared as a bare `Arc<FileServer>`: connections dispatch
//! [`FileServer::handle`] directly, serializing only on the namespace
//! shard a request routes to — concurrent clients on different
//! subtrees are served genuinely in parallel.
//!
//! Serving is readiness-driven (the reactor core, DESIGN.md §2.9): a
//! fixed pool of poll-loop threads owns every connection fd and streams
//! frames through reused per-connection buffers. The legacy
//! thread-per-connection core was removed after its one-release grace
//! period (`[server] reactor` is now a hard config error).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::auth::{self, Authenticator, KeyPair};
use crate::callback::NotifyChannel;
use crate::client::{LinkError, ServerLink};
use crate::config::{ServerConfig, StripesMode, XufsConfig};
use crate::homefs::FsError;
use crate::metrics::{names, Metrics};
use crate::proto::{
    self, BlockExtent, FileImage, MetaOp, NotifyEvent, RangeImage, Request, Response,
};
use crate::server::FileServer;
use crate::transfer;

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

pub(crate) fn write_frame(stream: &mut TcpStream, body: &[u8]) -> std::io::Result<()> {
    stream.write_all(&proto::frame(body))
}

pub(crate) fn read_frame(stream: &mut TcpStream) -> std::io::Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > proto::MAX_FRAME {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "frame too large"));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok(body)
}

fn io_err(e: std::io::Error) -> FsError {
    let _ = e;
    FsError::Disconnected
}

// ---------------------------------------------------------------------
// server
// ---------------------------------------------------------------------

/// Handle to a running TCP front-end for a [`FileServer`].
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind on an ephemeral localhost port and serve until dropped, with
    /// the default server config: the readiness-driven reactor core
    /// (DESIGN.md §2.9).
    pub fn spawn(
        server: Arc<FileServer>,
        authenticator: Arc<Mutex<Authenticator>>,
        metrics: Metrics,
    ) -> std::io::Result<TcpServer> {
        Self::spawn_with(server, authenticator, metrics, &ServerConfig::default())
    }

    /// [`TcpServer::spawn`] with explicit `[server]` knobs (reactor
    /// thread count, admission limits).
    pub fn spawn_with(
        server: Arc<FileServer>,
        authenticator: Arc<Mutex<Authenticator>>,
        metrics: Metrics,
        cfg: &ServerConfig,
    ) -> std::io::Result<TcpServer> {
        let h = super::reactor::spawn(server, authenticator, metrics, cfg)?;
        Ok(TcpServer { addr: h.addr, stop: h.stop, threads: h.threads })
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------
// client link
// ---------------------------------------------------------------------

/// Client side of the USSH handshake on a fresh connection.
fn client_handshake(stream: &mut TcpStream, pair: &KeyPair) -> Result<(), FsError> {
    write_frame(stream, &Request::AuthHello { key_id: pair.key_id.clone() }.encode())
        .map_err(io_err)?;
    let resp = Response::decode(&read_frame(stream).map_err(io_err)?)
        .map_err(|e| FsError::Protocol(e.to_string()))?;
    let Response::Challenge { nonce } = resp else {
        return Err(FsError::Protocol("expected challenge".into()));
    };
    let proof = auth::prove(&pair.phrase, &pair.key_id, &nonce);
    write_frame(stream, &Request::AuthProof { key_id: pair.key_id.clone(), proof }.encode())
        .map_err(io_err)?;
    match Response::decode(&read_frame(stream).map_err(io_err)?)
        .map_err(|e| FsError::Protocol(e.to_string()))?
    {
        Response::AuthOk { .. } => Ok(()),
        Response::AuthFail => Err(FsError::Perm("USSH authentication failed".into())),
        r => Err(FsError::Protocol(format!("unexpected auth response {r:?}"))),
    }
}

pub(crate) fn dial(addr: std::net::SocketAddr, pair: &KeyPair) -> Result<TcpStream, FsError> {
    let mut stream = TcpStream::connect(addr).map_err(io_err)?;
    stream.set_nodelay(true).ok();
    client_handshake(&mut stream, pair)?;
    Ok(stream)
}

/// Real-TCP [`ServerLink`]: an authenticated control connection, parallel
/// stripe connections for range fetches, and a callback reader thread
/// feeding a local [`NotifyChannel`].
///
/// Like the sim deployment's `SimLink`, the link carries the full
/// replica endpoint list: connects and reconnects rotate through it on
/// failed dials and code-112 "wrong endpoint" replies, so failover works
/// over real sockets exactly as it does in the simulator (DESIGN.md
/// §2.7).
pub struct TcpLink {
    addrs: Vec<std::net::SocketAddr>,
    active: usize,
    pair: KeyPair,
    cfg: XufsConfig,
    control: Option<TcpStream>,
    channel: NotifyChannel,
    callback_thread: Option<JoinHandle<()>>,
    callback_stop: Arc<AtomicBool>,
    client_id: u64,
    root: String,
    metrics: Metrics,
    /// Replication-plane link (shipper → secondary): control connection
    /// only, no callback registration — a secondary refuses registration
    /// with code 112, which is exactly right for CLIENTS rotating past
    /// it but would strand the shipper that needs to talk to it.
    replication: bool,
    /// Adaptive stripe tuner (transport v2, DESIGN.md §2.12), created
    /// lazily on the first range fetch when `transfer.stripes = "auto"`.
    tuner: Option<transfer::AutoTuner>,
    /// Speculative pipelined-readahead fetches in flight (§2.12),
    /// oldest first, bounded by `transfer.pipeline_window`.
    hints: Vec<PipelinedHint>,
}

/// One speculative fetch started by a [`ServerLink::pipeline_hint`]
/// (DESIGN.md §2.12): a worker thread pulling the hinted range over its
/// own authenticated connection, concurrently with the application's
/// compute. The matching demand fetch joins it; dropping the handle
/// detaches the worker (its bytes arrive and go unused).
struct PipelinedHint {
    path: String,
    offset: u64,
    len: u64,
    expect_version: u64,
    handle: JoinHandle<Result<Vec<BlockExtent>, LinkError>>,
}

impl TcpLink {
    /// Dial, authenticate, and register the callback channel.
    pub fn connect(
        addr: std::net::SocketAddr,
        pair: KeyPair,
        cfg: XufsConfig,
        client_id: u64,
        root: &str,
        metrics: Metrics,
    ) -> Result<TcpLink, FsError> {
        Self::connect_endpoints(vec![addr], pair, cfg, client_id, root, metrics)
    }

    /// [`TcpLink::connect`] with a replica endpoint list. The first
    /// endpoint that completes dial + USSH + callback registration wins;
    /// standby endpoints answer registration with code 112 and are
    /// rotated past (counted in `replica.failovers` when the active
    /// endpoint actually moves).
    pub fn connect_endpoints(
        addrs: Vec<std::net::SocketAddr>,
        pair: KeyPair,
        cfg: XufsConfig,
        client_id: u64,
        root: &str,
        metrics: Metrics,
    ) -> Result<TcpLink, FsError> {
        assert!(!addrs.is_empty(), "TcpLink needs at least one endpoint");
        let mut link = TcpLink {
            addrs,
            active: 0,
            pair,
            cfg,
            control: None,
            channel: NotifyChannel::new(),
            callback_thread: None,
            callback_stop: Arc::new(AtomicBool::new(false)),
            client_id,
            root: root.to_string(),
            metrics,
            replication: false,
            tuner: None,
            hints: Vec::new(),
        };
        link.establish()?;
        Ok(link)
    }

    /// Dial and authenticate a replication-plane link to a secondary:
    /// the [`crate::replica::Shipper`]'s transport. Skips callback
    /// registration (a secondary refuses it with code 112) — the
    /// replication plane has no cache to invalidate.
    pub fn connect_replication(
        addr: std::net::SocketAddr,
        pair: KeyPair,
        cfg: XufsConfig,
        metrics: Metrics,
    ) -> Result<TcpLink, FsError> {
        let mut link = TcpLink {
            addrs: vec![addr],
            active: 0,
            pair,
            cfg,
            control: None,
            channel: NotifyChannel::new(),
            callback_thread: None,
            callback_stop: Arc::new(AtomicBool::new(false)),
            client_id: 0,
            root: "/".to_string(),
            metrics,
            replication: true,
            tuner: None,
            hints: Vec::new(),
        };
        link.establish()?;
        Ok(link)
    }

    /// The endpoint currently serving this link.
    pub fn active_endpoint(&self) -> std::net::SocketAddr {
        self.addrs[self.active]
    }

    fn addr(&self) -> std::net::SocketAddr {
        self.addrs[self.active]
    }

    /// Establish on the first endpoint that accepts, starting from the
    /// one that last worked (`SimLink::connect`'s rotation, over real
    /// sockets).
    fn establish(&mut self) -> Result<(), FsError> {
        self.teardown_callback();
        self.control = None;
        self.drop_hints();
        let n = self.addrs.len();
        let mut last = FsError::Disconnected;
        for k in 0..n {
            let idx = (self.active + k) % n;
            match self.establish_at(self.addrs[idx]) {
                Ok(()) => {
                    if idx != self.active {
                        self.active = idx;
                        self.metrics.incr(names::REPLICA_FAILOVERS);
                    }
                    return Ok(());
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    fn establish_at(&mut self, addr: std::net::SocketAddr) -> Result<(), FsError> {
        let control = dial(addr, &self.pair)?;
        if self.replication {
            // replication plane: the control connection is the whole link
            self.control = Some(control);
            return Ok(());
        }
        // callback connection: authenticate, register, then read pushes
        let mut cb = dial(addr, &self.pair)?;
        write_frame(
            &mut cb,
            &Request::RegisterCallback { root: self.root.clone(), client_id: self.client_id }.encode(),
        )
        .map_err(io_err)?;
        match Response::decode(&read_frame(&mut cb).map_err(io_err)?)
            .map_err(|e| FsError::Protocol(e.to_string()))?
        {
            Response::CallbackRegistered => {}
            // a standby/fenced endpoint refuses registration with 112:
            // surface a rotatable error so `establish` tries the next one
            Response::Err { code: 112, .. } => return Err(FsError::Disconnected),
            r => return Err(FsError::Protocol(format!("callback registration failed: {r:?}"))),
        }
        let channel = self.channel.clone();
        let stop = Arc::new(AtomicBool::new(false));
        self.callback_stop = stop.clone();
        cb.set_read_timeout(Some(Duration::from_millis(20))).ok();
        self.callback_thread = Some(std::thread::spawn(move || loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            match read_frame(&mut cb) {
                Ok(body) => {
                    if let Ok(ev) = NotifyEvent::decode(&body) {
                        channel.push(ev);
                    }
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut => {}
                Err(_) => {
                    channel.disconnect();
                    return;
                }
            }
        }));
        self.control = Some(control);
        Ok(())
    }

    fn teardown_callback(&mut self) {
        self.callback_stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.callback_thread.take() {
            let _ = t.join();
        }
    }

    /// Abandon every speculative fetch in flight (reconnects, window
    /// eviction): dropping the handles detaches the workers, and their
    /// requested bytes are exactly what the waste metric counts.
    fn drop_hints(&mut self) {
        for h in self.hints.drain(..) {
            self.metrics.add(names::PIPELINE_WASTED_BYTES, h.len);
        }
    }

    fn control_rpc(&mut self, req: &Request) -> Result<Response, FsError> {
        let body = req.encode();
        self.control_rpc_frame(&body)
    }

    /// One request/response exchange from an already-encoded body (lets
    /// the compound flush serialize straight from borrowed meta-ops).
    fn control_rpc_frame(&mut self, body: &[u8]) -> Result<Response, FsError> {
        let stream = self.control.as_mut().ok_or(FsError::Disconnected)?;
        if write_frame(stream, body).is_err() {
            self.control = None;
            return Err(FsError::Disconnected);
        }
        match read_frame(stream) {
            Ok(resp) => {
                let resp =
                    Response::decode(&resp).map_err(|e| FsError::Protocol(e.to_string()))?;
                if let Response::Err { code: 112, .. } = resp {
                    // wrong endpoint (demoted/fenced): sever so the
                    // caller's reconnect rotates to the new primary
                    self.control = None;
                    self.channel.disconnect();
                    return Err(FsError::Disconnected);
                }
                Ok(resp)
            }
            Err(_) => {
                self.control = None;
                Err(FsError::Disconnected)
            }
        }
    }
}

impl Drop for TcpLink {
    fn drop(&mut self) {
        self.teardown_callback();
    }
}

fn response_to_fs_err(r: Response) -> FsError {
    match r {
        Response::Err { code: 2, msg } => FsError::NotFound(msg),
        Response::Err { code: 21, msg } => FsError::IsADir(msg),
        // 111 = server down; 112 = standby/fenced endpoint (DESIGN.md
        // §2.7) — both mean "reconnect, possibly elsewhere"
        Response::Err { code: 111, .. } | Response::Err { code: 112, .. } => FsError::Disconnected,
        Response::Err { code: 116, msg } => FsError::Stale(msg),
        // 118 = integrity refusal (DESIGN.md §2.10): the server detected
        // rot and quarantined the bytes instead of serving them
        Response::Err { code: 118, msg } => FsError::Corrupted(msg),
        // 119 = bounded-staleness refusal (DESIGN.md §2.11): a read
        // replica is lagging behind the client's observed version —
        // retry against a fresher node (the primary always qualifies)
        Response::Err { code: 119, msg } => FsError::Stale(msg),
        r => FsError::Protocol(format!("unexpected response {r:?}")),
    }
}

/// Fetch the blocks covering one range over a dedicated authenticated
/// connection (one stripe's share of a paged fetch).
///
/// A peer reset AFTER the connection was established is a mid-transfer
/// interruption, not a generic failure: it comes back as the typed
/// [`LinkError::Interrupted`] carrying this share's first block — the
/// point the striped fetch resumes from (a share delivers in one frame,
/// so none of ITS blocks landed; everything the other stripes delivered
/// is kept). That retry context is what lets the caller resume instead
/// of failing the whole striped fetch — for the fault plane's torn
/// transfers and real WAN hiccups alike.
fn fetch_blocks_conn(
    addr: std::net::SocketAddr,
    pair: &KeyPair,
    path: &str,
    offset: u64,
    len: u64,
    expect_version: u64,
    block_bytes: u64,
) -> Result<Vec<BlockExtent>, LinkError> {
    let resume_block = offset / block_bytes.max(1);
    // connection setup failing is an ordinary disconnect — nothing was
    // in flight yet
    let mut conn = dial(addr, pair).map_err(LinkError::Fs)?;
    let req = Request::FetchRange { path: path.to_string(), offset, len, expect_version };
    if write_frame(&mut conn, &req.encode()).is_err() {
        return Err(LinkError::Interrupted { resumed_from_block: resume_block });
    }
    let frame = read_frame(&mut conn)
        .map_err(|_| LinkError::Interrupted { resumed_from_block: resume_block })?;
    let resp =
        Response::decode(&frame).map_err(|e| LinkError::Fs(FsError::Protocol(e.to_string())))?;
    match resp {
        Response::FileBlocks { extents, .. } => Ok(extents),
        r => Err(LinkError::Fs(response_to_fs_err(r))),
    }
}

/// Split `[offset, offset+len)` into block-aligned per-stripe shares.
fn stripe_shares(offset: u64, len: u64, stripes: usize, bb: u64) -> Vec<(u64, u64)> {
    let bb = bb.max(1);
    let end = offset + len;
    let blocks = len.div_ceil(bb);
    let per = blocks.div_ceil(stripes.max(1) as u64).max(1) * bb;
    let mut out = Vec::new();
    let mut at = offset;
    while at < end {
        let share = per.min(end - at);
        out.push((at, share));
        at += share;
    }
    out
}

impl ServerLink for TcpLink {
    fn rpc(&mut self, req: Request) -> Result<Response, FsError> {
        // Callback registration rides the DEDICATED callback connection
        // (a RegisterCallback frame converts its connection into the
        // push channel server-side). `establish`/`reconnect` already
        // performed it, so the client's re-register tick is satisfied
        // locally — sending it down the control connection would convert
        // that connection into a push channel and hang every later RPC.
        if matches!(req, Request::RegisterCallback { .. }) {
            return if self.channel.is_connected() {
                Ok(Response::CallbackRegistered)
            } else {
                Err(FsError::Disconnected)
            };
        }
        if let Request::Compound { ops } = &req {
            self.metrics.incr(names::COMPOUND_RPCS);
            self.metrics.add(names::COMPOUND_OPS, ops.len() as u64);
        }
        self.metrics.add(names::WAN_RPCS, 1);
        self.control_rpc(&req)
    }

    fn fetch_range(
        &mut self,
        path: &str,
        offset: u64,
        len: u64,
        expect_version: u64,
    ) -> Result<RangeImage, FsError> {
        // transport v2 (DESIGN.md §2.12): a speculative fetch already in
        // flight for exactly these coordinates is joined instead of
        // re-requested — the worker pulled the same pinned-version range
        // over its own connection while the application computed
        if let Some(i) = self.hints.iter().position(|h| {
            h.path == path
                && h.offset == offset
                && h.len == len
                && h.expect_version == expect_version
        }) {
            let hint = self.hints.remove(i);
            if let Ok(Ok(mut extents)) = hint.handle.join() {
                extents.sort_by_key(|x| x.index);
                let bytes: u64 = extents.iter().map(|x| x.data.len() as u64).sum();
                self.metrics.add(names::WAN_BYTES_RX, bytes);
                self.metrics.incr(names::RANGE_FETCHES);
                self.metrics.incr(names::PIPELINED_HITS);
                return Ok(RangeImage { version: expect_version, extents });
            }
            // a failed speculation falls through to the demand fetch
        }
        // a hint for the same spot that does NOT match (the scan went
        // elsewhere, or the version moved) is dead weight: count it
        if let Some(i) = self.hints.iter().position(|h| h.path == path && h.offset == offset) {
            let dead = self.hints.remove(i);
            self.metrics.add(names::PIPELINE_WASTED_BYTES, dead.len);
        }
        // block-align the range and stripe it exactly like a whole file
        let mut plan =
            transfer::plan_range(offset, len, offset.saturating_add(len), &self.cfg.stripe);
        match self.cfg.transfer.stripes {
            StripesMode::Planned => {}
            StripesMode::Fixed(n) => plan.stripes = n.clamp(1, self.cfg.stripe.max_stripes.max(1)),
            StripesMode::Auto => {
                let max = self.cfg.stripe.max_stripes.max(1);
                plan.stripes =
                    self.tuner.get_or_insert_with(|| transfer::AutoTuner::new(1, max)).stripes();
            }
        }
        let bb = self.cfg.stripe.min_block.max(1);
        self.metrics.incr(names::RANGE_FETCHES);
        if plan.len == 0 {
            return Ok(RangeImage { version: expect_version, extents: Vec::new() });
        }
        let t0 = std::time::Instant::now();
        let shares = if plan.stripes <= 1 {
            vec![(plan.offset, plan.len)]
        } else {
            stripe_shares(plan.offset, plan.len, plan.stripes, bb)
        };
        // genuinely parallel range fetches, one authenticated connection
        // per stripe (paper §3.3)
        let mut results: Vec<Result<Vec<BlockExtent>, LinkError>> =
            Vec::with_capacity(shares.len());
        if shares.len() == 1 {
            let (soff, slen) = shares[0];
            results.push(fetch_blocks_conn(
                self.addr(),
                &self.pair,
                path,
                soff,
                slen,
                expect_version,
                bb,
            ));
        } else {
            let mut handles = Vec::new();
            for &(soff, slen) in &shares {
                let addr = self.addr();
                let pair = self.pair.clone();
                let path = path.to_string();
                handles.push(std::thread::spawn(move || {
                    fetch_blocks_conn(addr, &pair, &path, soff, slen, expect_version, bb)
                }));
            }
            for h in handles {
                results.push(
                    h.join()
                        .map_err(|_| FsError::Protocol("stripe thread panicked".into()))?,
                );
            }
        }
        let mut extents: Vec<BlockExtent> = Vec::new();
        for (i, r) in results.into_iter().enumerate() {
            match r {
                Ok(chunk) => extents.extend(chunk),
                Err(LinkError::Interrupted { resumed_from_block }) => {
                    // a stripe died mid-transfer: the other stripes'
                    // blocks are already in hand, so the fetch resumes at
                    // this share — which delivers in ONE frame, so its
                    // resume point is its own first block. Retry it once
                    // over a fresh authenticated connection.
                    let (soff, slen) = shares[i];
                    debug_assert_eq!(resumed_from_block, soff / bb);
                    self.metrics.incr(names::RESUMED_FETCHES);
                    match fetch_blocks_conn(
                        self.addr(),
                        &self.pair,
                        path,
                        soff,
                        slen,
                        expect_version,
                        bb,
                    ) {
                        Ok(chunk) => extents.extend(chunk),
                        // a second tear on the same share surfaces the
                        // typed interruption to the caller
                        Err(e) => return Err(FsError::from(e)),
                    }
                }
                Err(e) => return Err(FsError::from(e)),
            }
        }
        extents.sort_by_key(|x| x.index);
        let bytes: u64 = extents.iter().map(|x| x.data.len() as u64).sum();
        // the tuner learns from real wall-clock goodput on this link
        if let Some(t) = self.tuner.as_mut() {
            t.observe(bytes, t0.elapsed().as_secs_f64(), &self.metrics);
        }
        self.metrics.add(names::WAN_BYTES_RX, bytes);
        Ok(RangeImage { version: expect_version, extents })
    }

    fn pipeline_hint(&mut self, path: &str, offset: u64, len: u64, expect_version: u64) {
        if !self.cfg.transfer.pipeline || len == 0 || self.control.is_none() {
            return;
        }
        while self.hints.len() >= self.cfg.transfer.pipeline_window.max(1) {
            let evicted = self.hints.remove(0);
            self.metrics.add(names::PIPELINE_WASTED_BYTES, evicted.len);
        }
        // one connection, one stripe: the speculation's value is the
        // overlap with application compute, not stripe parallelism — and
        // a wrong guess then wasted only a single connection's work
        let addr = self.addr();
        let pair = self.pair.clone();
        let p = path.to_string();
        let bb = self.cfg.stripe.min_block.max(1);
        let handle = std::thread::spawn(move || {
            fetch_blocks_conn(addr, &pair, &p, offset, len, expect_version, bb)
        });
        self.hints.push(PipelinedHint {
            path: path.to_string(),
            offset,
            len,
            expect_version,
            handle,
        });
    }

    fn prefetch(&mut self, files: &[(String, u64)]) -> Vec<FileImage> {
        // pre-fetch worker pool: `prefetch_threads` connections pulling
        // whole small files in parallel
        let threads = self.cfg.stripe.prefetch_threads.max(1).min(files.len().max(1));
        let work = Arc::new(Mutex::new(files.to_vec()));
        let results = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for _ in 0..threads {
            let work = work.clone();
            let results = results.clone();
            let addr = self.addr();
            let pair = self.pair.clone();
            let bb = self.cfg.stripe.min_block.max(1);
            handles.push(std::thread::spawn(move || {
                let Ok(mut conn) = dial(addr, &pair) else { return };
                loop {
                    let item = work.lock().unwrap().pop();
                    let Some((path, _size)) = item else { return };
                    let req = Request::FetchMeta { path: path.clone(), min_version: 0 };
                    if write_frame(&mut conn, &req.encode()).is_err() {
                        return;
                    }
                    let Ok(frame) = read_frame(&mut conn) else { return };
                    let Ok(Response::FileMeta { version, size, digests }) = Response::decode(&frame)
                    else {
                        continue;
                    };
                    let req = Request::FetchRange {
                        path: path.clone(),
                        offset: 0,
                        len: size,
                        expect_version: version,
                    };
                    if write_frame(&mut conn, &req.encode()).is_err() {
                        return;
                    }
                    let Ok(frame) = read_frame(&mut conn) else { return };
                    if let Ok(Response::FileBlocks { extents, .. }) = Response::decode(&frame) {
                        let mut data = vec![0u8; size as usize];
                        for x in &extents {
                            let start = (x.index as u64 * bb) as usize;
                            data[start..start + x.data.len()].copy_from_slice(&x.data);
                        }
                        results.lock().unwrap().push(FileImage { path, version, data, digests });
                    }
                }
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        let out = std::mem::take(&mut *results.lock().unwrap());
        self.metrics
            .add(names::WAN_BYTES_RX, out.iter().map(|i| i.data.len() as u64).sum());
        out
    }

    fn ship(&mut self, seq: u64, op: &MetaOp) -> Result<Response, FsError> {
        self.metrics.add(names::WAN_BYTES_TX, op.wire_bytes());
        self.control_rpc(&Request::Apply { seq, op: op.clone() })
    }

    fn ship_compound(&mut self, ops: &[(u64, MetaOp)]) -> Result<Vec<Response>, FsError> {
        // encode straight from the borrowed queue entries — no payload
        // clone on the flush path
        let body = Request::encode_compound_applies(ops);
        let resp = self.control_rpc_frame(&body)?;
        // count only completed exchanges, so a disconnected attempt plus
        // its post-reconnect retry is one frame, not two
        self.metrics
            .add(names::WAN_BYTES_TX, ops.iter().map(|(_, op)| op.wire_bytes()).sum());
        self.metrics.incr(names::COMPOUND_RPCS);
        self.metrics.add(names::COMPOUND_OPS, ops.len() as u64);
        match resp {
            Response::CompoundReply { replies } => Ok(replies),
            r => Err(response_to_fs_err(r)),
        }
    }

    fn drain_notifications(&mut self) -> Vec<NotifyEvent> {
        self.channel.drain()
    }

    fn channel_generation(&self) -> u64 {
        self.channel.generation()
    }

    fn is_connected(&self) -> bool {
        self.control.is_some() && (self.replication || self.channel.is_connected())
    }

    fn reconnect(&mut self) -> Result<u64, FsError> {
        self.channel.reconnect();
        self.establish()?;
        Ok(self.channel.generation())
    }

    fn client_id(&self) -> u64 {
        self.client_id
    }
}
