//! Deployment coordinator.
//!
//! Wires client, server, transport, clock and digest engine into a running
//! deployment. Two transports:
//!
//! * [`sim`] — the WAN-model deployment (virtual clock, deterministic):
//!   all benches run here, reporting simulated seconds calibrated to the
//!   paper's testbed (DESIGN.md §5).
//! * [`net`] — real TCP sockets on localhost with the full USSH
//!   challenge-response handshake, striped fetch connections and a
//!   callback pump thread: integration tests and the e2e example run the
//!   identical client/server logic over actual sockets.

pub mod net;
pub mod sim;

pub use sim::{SimLink, SimWorld};
