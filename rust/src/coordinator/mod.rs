//! Deployment coordinator.
//!
//! Wires client, server, transport, clock and digest engine into a running
//! deployment. Two transports:
//!
//! * [`sim`] — the WAN-model deployment (virtual clock, deterministic):
//!   all benches run here, reporting simulated seconds calibrated to the
//!   paper's testbed (DESIGN.md §5).
//! * [`net`] — real TCP sockets on localhost with the full USSH
//!   challenge-response handshake, striped fetch connections and a
//!   push-mode callback channel: integration tests and the e2e example
//!   run the identical client/server logic over actual sockets. Serving
//!   is readiness-driven (the `reactor` module, DESIGN.md §2.9) — the
//!   only serving core since the legacy thread-per-connection path was
//!   removed at the end of its one-release grace period.

pub mod net;
mod reactor;
pub mod sim;

pub use sim::{SimLink, SimWorld};
