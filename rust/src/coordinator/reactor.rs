//! Readiness-driven reactor server core (DESIGN.md §2.9).
//!
//! A small pool of reactor threads owns every connection fd: each thread
//! runs a `poll(2)` loop over nonblocking sockets, drives per-connection
//! state machines (handshake -> framed request -> dispatch -> framed
//! response), and hands decoded requests straight to the sharded
//! [`FileServer::handle`] — which is lock-free to dispatch into, so no
//! queues or handoff threads sit between the socket and the server core.
//! This replaced the thread-per-connection path — whose 2 ms accept
//! sleep and thousands of blocked threads were the wall in front of the
//! paper's 9000-node claim — and is the sole serving core now that the
//! legacy path's one-release grace period has ended.
//!
//! I/O never blocks a reactor thread: reads go through the v2 streaming
//! decoder ([`FrameDecoder`], one reused buffer per connection), writes
//! through [`FrameWriter`] with partial-write resumption — a slow WAN
//! reader costs buffer space, never a thread. Backpressure is explicit:
//! a connection whose un-flushed output passes the high-water mark stops
//! being read until it drains (so a stalled peer throttles only itself),
//! and admission control refuses work past `[server] max_connections` /
//! `max_inflight_per_conn` with the typed busy code
//! ([`proto::BUSY_CODE`]) instead of queueing unboundedly.
//!
//! The poll timeout doubles as the reactor's timer tick: thread 0 runs
//! the 1 s lease sweep (quiet servers still expire orphaned leases), and
//! every thread pumps callback channels and flushes its codec-reuse
//! counters on the tick.

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::auth::Authenticator;
use crate::callback::NotifyChannel;
use crate::config::ServerConfig;
use crate::metrics::{names, Metrics};
use crate::proto::{self, FrameDecoder, FrameWriter, Request, Response};
use crate::server::FileServer;
use crate::simnet::{Clock, RealClock};

/// Minimal `poll(2)` FFI shim — just the constants and struct layout the
/// reactor needs, straight from POSIX. In-tree on purpose: the offline
/// crate set has no `libc`, and `std` exposes no readiness API.
mod sys {
    use std::os::raw::{c_int, c_short};

    pub const POLLIN: c_short = 0x001;
    pub const POLLOUT: c_short = 0x004;
    pub const POLLERR: c_short = 0x008;
    pub const POLLHUP: c_short = 0x010;
    pub const POLLNVAL: c_short = 0x020;

    #[cfg(target_os = "linux")]
    type NfdsT = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NfdsT = std::os::raw::c_uint;

    #[repr(C)]
    #[derive(Clone, Copy, Debug)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: c_short,
        pub revents: c_short,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: c_int) -> c_int;
    }

    /// Wait for readiness on `fds` for up to `timeout_ms`. EINTR is
    /// reported as zero ready fds — the caller just re-ticks.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
        if rc < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(e);
        }
        Ok(rc as usize)
    }
}

/// Poll timeout: the reactor's timer tick granularity (callback pump
/// latency bound, lease-sweep scheduling, stop-flag responsiveness).
const TICK_MS: i32 = 10;
/// Per-connection read budget per tick — a blasting peer cannot starve
/// its neighbors on the same reactor thread.
const READ_BUDGET: usize = 256 * 1024;
/// Stop reading a connection whose un-flushed output exceeds this.
const WRITE_HIGH_WATER: usize = 4 * 1024 * 1024;
/// Resume reading once the backlog drains below this.
const WRITE_LOW_WATER: usize = 64 * 1024;

/// What `TcpServer` wraps when the reactor core is selected.
pub(crate) struct ReactorHandle {
    pub addr: std::net::SocketAddr,
    pub stop: Arc<AtomicBool>,
    pub threads: Vec<JoinHandle<()>>,
}

/// Everything one reactor thread needs; each thread owns a clone.
struct Shared {
    listener: Arc<TcpListener>,
    server: Arc<FileServer>,
    authenticator: Arc<Mutex<Authenticator>>,
    metrics: Metrics,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    max_connections: usize,
    max_inflight: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum ConnState {
    /// Expecting `AuthHello`.
    AwaitHello,
    /// Challenge sent; expecting `AuthProof`.
    AwaitProof,
    /// Authenticated; framed request -> dispatch -> framed response.
    Serving,
    /// Converted by `RegisterCallback` into the push channel.
    Callback,
}

struct Conn {
    stream: TcpStream,
    dec: FrameDecoder,
    out: FrameWriter,
    state: ConnState,
    session: u64,
    channel: Option<NotifyChannel>,
    /// Backpressured: output past the high-water mark, reads suspended.
    paused: bool,
    /// Terminal frame queued (auth failure); close once it flushes.
    close_after_flush: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            dec: FrameDecoder::new(proto::MAX_FRAME),
            out: FrameWriter::new(),
            state: ConnState::AwaitHello,
            session: 0,
            channel: None,
            paused: false,
            close_after_flush: false,
            dead: false,
        }
    }
}

/// Bind and launch the reactor thread pool.
pub(crate) fn spawn(
    server: Arc<FileServer>,
    authenticator: Arc<Mutex<Authenticator>>,
    metrics: Metrics,
    cfg: &ServerConfig,
) -> std::io::Result<ReactorHandle> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let listener = Arc::new(listener);
    let stop = Arc::new(AtomicBool::new(false));
    let active = Arc::new(AtomicUsize::new(0));
    let threads_n = if cfg.reactor_threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.reactor_threads
    }
    .clamp(1, 64);
    let mut threads = Vec::with_capacity(threads_n);
    for idx in 0..threads_n {
        let sh = Shared {
            listener: listener.clone(),
            server: server.clone(),
            authenticator: authenticator.clone(),
            metrics: metrics.clone(),
            stop: stop.clone(),
            active: active.clone(),
            max_connections: cfg.max_connections.max(1),
            max_inflight: cfg.max_inflight_per_conn.max(1),
        };
        threads.push(std::thread::spawn(move || reactor_loop(sh, idx)));
    }
    Ok(ReactorHandle { addr, stop, threads })
}

fn reactor_loop(sh: Shared, thread_idx: usize) {
    let clock = RealClock::new();
    let mut conns: Vec<Conn> = Vec::new();
    let mut fds: Vec<sys::PollFd> = Vec::new();
    let mut last_tick = Instant::now();
    let mut buf_reuses = 0u64;
    while !sh.stop.load(Ordering::SeqCst) {
        fds.clear();
        fds.push(sys::PollFd { fd: sh.listener.as_raw_fd(), events: sys::POLLIN, revents: 0 });
        for c in &conns {
            let mut ev = 0;
            if !c.paused && !c.close_after_flush {
                // Callback conns register POLLIN too: the peer never
                // sends after registration, so readiness means hangup
                ev |= sys::POLLIN;
            }
            if !c.out.is_empty() {
                ev |= sys::POLLOUT;
            }
            fds.push(sys::PollFd { fd: c.stream.as_raw_fd(), events: ev, revents: 0 });
        }
        if sys::poll_fds(&mut fds, TICK_MS).is_err() {
            // poll itself failing is not a per-connection condition;
            // breathe and re-tick rather than spinning
            std::thread::sleep(Duration::from_millis(10));
            continue;
        }
        // timer duties ride the poll timeout
        if last_tick.elapsed() >= Duration::from_secs(1) {
            last_tick = Instant::now();
            if thread_idx == 0 {
                // the reactor's lease timer: quiet servers still expire
                // orphaned leases (the legacy path swept only between
                // accepts)
                sh.server.expire_leases(clock.now());
            }
            if buf_reuses > 0 {
                sh.metrics.add(names::CODEC_BUF_REUSES, buf_reuses);
                buf_reuses = 0;
            }
        }
        // conn I/O first (their fds entries predate this tick's accepts)
        let polled = fds.len() - 1;
        for (i, c) in conns.iter_mut().take(polled).enumerate() {
            let re = fds[i + 1].revents;
            if re & sys::POLLNVAL != 0 {
                c.dead = true;
                continue;
            }
            if re & (sys::POLLIN | sys::POLLHUP | sys::POLLERR) != 0 && !c.paused {
                read_input(c);
            }
            service_conn(&sh, c, &clock);
            flush_conn(c, &mut buf_reuses);
            if c.paused && c.out.pending() < WRITE_LOW_WATER {
                // drained below low water: resume, and serve any frames
                // that were already buffered before the pause
                c.paused = false;
                service_conn(&sh, c, &clock);
                flush_conn(c, &mut buf_reuses);
            }
            if c.close_after_flush && c.out.is_empty() {
                c.dead = true;
            }
        }
        if fds[0].revents != 0 {
            accept_burst(&sh, &mut conns);
        }
        if conns.iter().any(|c| c.dead) {
            conns.retain(|c| {
                if c.dead {
                    if let Some(ch) = &c.channel {
                        ch.disconnect();
                    }
                    sh.active.fetch_sub(1, Ordering::SeqCst);
                    false
                } else {
                    true
                }
            });
            sh.metrics
                .set_gauge(names::SERVER_ACTIVE_CONNS, sh.active.load(Ordering::SeqCst) as f64);
        }
    }
    // shutdown: sever channels so server-side pushes stop queueing
    for c in &conns {
        if let Some(ch) = &c.channel {
            ch.disconnect();
        }
    }
    sh.active.fetch_sub(conns.len(), Ordering::SeqCst);
}

fn accept_burst(sh: &Shared, conns: &mut Vec<Conn>) {
    loop {
        match sh.listener.accept() {
            Ok((stream, _)) => {
                if sh.active.load(Ordering::SeqCst) >= sh.max_connections {
                    // admission control: a typed busy frame, then drop —
                    // never an unbounded accept queue
                    sh.metrics.incr(names::SERVER_BACKPRESSURE_REJECTS);
                    refuse_busy(stream);
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                stream.set_nodelay(true).ok();
                sh.active.fetch_add(1, Ordering::SeqCst);
                sh.metrics.incr(names::SERVER_ACCEPTS);
                sh.metrics
                    .set_gauge(names::SERVER_ACTIVE_CONNS, sh.active.load(Ordering::SeqCst) as f64);
                conns.push(Conn::new(stream));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(_) => {
                // transient accept failures (ECONNABORTED, fd pressure)
                // are counted and retried next tick — the listener is
                // never silently abandoned
                sh.metrics.incr(names::SERVER_ACCEPT_ERRORS);
                break;
            }
        }
    }
}

/// Tell an over-limit peer it is refused without ever blocking the
/// reactor: one best-effort nonblocking write of a tiny busy frame.
fn refuse_busy(mut stream: TcpStream) {
    stream.set_nonblocking(true).ok();
    let body =
        Response::Err { code: proto::BUSY_CODE, msg: "server at max_connections".into() }.encode();
    let _ = stream.write(&proto::frame(&body));
}

/// Drain the socket into the connection's decode buffer, up to the
/// fairness budget. EOF and hard errors mark the connection dead.
fn read_input(c: &mut Conn) {
    let mut budget = READ_BUDGET;
    loop {
        match c.dec.read_from(&mut c.stream) {
            Ok(0) => {
                c.dead = true;
                return;
            }
            Ok(n) => {
                if n >= budget {
                    return;
                }
                budget -= n;
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
}

/// Serve whatever complete frames the connection has buffered, per its
/// state machine; then (callback conns) pump pending notifications.
fn service_conn(sh: &Shared, c: &mut Conn, clock: &RealClock) {
    if matches!(c.state, ConnState::Callback) {
        pump_callbacks(c);
        return;
    }
    serve_frames(sh, c, clock);
    if matches!(c.state, ConnState::Callback) {
        // converted this round: deliver anything already queued
        pump_callbacks(c);
    }
}

fn serve_frames(sh: &Shared, c: &mut Conn, clock: &RealClock) {
    let mut served = 0usize;
    loop {
        if c.dead || c.close_after_flush || c.paused {
            return;
        }
        // pull one frame; the borrow on the decode buffer ends once the
        // Request is decoded to an owned value
        let frame = match c.dec.next_frame() {
            Ok(None) => return,
            Err(_) => {
                // framing is lost (hostile length prefix) — nothing
                // sensible can follow on this connection
                c.dead = true;
                return;
            }
            Ok(Some(frame)) => Request::decode(frame),
        };
        let req = match frame {
            Ok(r) => r,
            Err(e) => {
                let resp = Response::Err { code: 71, msg: e.to_string() };
                c.out.frame(|enc| resp.encode_into(enc));
                continue;
            }
        };
        match c.state {
            ConnState::AwaitHello => {
                let Request::AuthHello { key_id } = req else {
                    c.dead = true;
                    return;
                };
                let nonce = sh.authenticator.lock().unwrap().challenge(&key_id);
                let resp = Response::Challenge { nonce };
                c.out.frame(|enc| resp.encode_into(enc));
                c.state = ConnState::AwaitProof;
            }
            ConnState::AwaitProof => {
                let Request::AuthProof { key_id, proof } = req else {
                    c.dead = true;
                    return;
                };
                let session =
                    sh.authenticator.lock().unwrap().verify_proof(&key_id, &proof, clock.now());
                match session {
                    Some(s) => {
                        c.session = s;
                        c.state = ConnState::Serving;
                        let resp = Response::AuthOk { session: s };
                        c.out.frame(|enc| resp.encode_into(enc));
                    }
                    None => {
                        sh.metrics.incr(names::AUTH_FAILURES);
                        c.out.frame(|enc| Response::AuthFail.encode_into(enc));
                        c.close_after_flush = true;
                        return;
                    }
                }
            }
            ConnState::Serving => {
                if let Request::RegisterCallback { root, client_id } = &req {
                    // this connection becomes the push channel
                    let channel = NotifyChannel::new();
                    sh.server.attach_channel(*client_id, channel.clone());
                    let resp = sh.server.handle(
                        *client_id,
                        Request::RegisterCallback { root: root.clone(), client_id: *client_id },
                        clock.now(),
                    );
                    c.out.frame(|enc| resp.encode_into(enc));
                    if matches!(resp, Response::CallbackRegistered) {
                        c.channel = Some(channel);
                        c.state = ConnState::Callback;
                    } else {
                        // refused (e.g. standby endpoint): don't leave a
                        // never-drained channel attached
                        channel.disconnect();
                    }
                    continue;
                }
                served += 1;
                if served > sh.max_inflight {
                    // pipelining past the admission cap: typed busy code,
                    // the frame is consumed but not dispatched
                    sh.metrics.incr(names::SERVER_BACKPRESSURE_REJECTS);
                    let resp = Response::Err {
                        code: proto::BUSY_CODE,
                        msg: "too many in-flight requests".into(),
                    };
                    c.out.frame(|enc| resp.encode_into(enc));
                    continue;
                }
                let resp = sh.server.handle(c.session, req, clock.now());
                c.out.frame(|enc| resp.encode_into(enc));
                if c.out.pending() >= WRITE_HIGH_WATER {
                    // backpressure: stop consuming this peer's requests
                    // until its backlog drains below the low-water mark.
                    // Other connections on this thread are unaffected.
                    c.paused = true;
                    return;
                }
            }
            ConnState::Callback => return,
        }
    }
}

/// Push-mode pump: forward queued invalidations; discard anything the
/// peer sends (push-mode peers get no replies, matching the legacy
/// path), and fold a severed channel into connection death.
fn pump_callbacks(c: &mut Conn) {
    loop {
        match c.dec.next_frame() {
            Ok(Some(_)) => {}
            Ok(None) => break,
            Err(_) => {
                c.dead = true;
                return;
            }
        }
    }
    let Some(channel) = c.channel.clone() else { return };
    if !channel.is_connected() {
        c.dead = true;
        return;
    }
    for ev in channel.drain() {
        c.out.frame(|enc| ev.encode_into(enc));
    }
}

/// Nonblocking flush with partial-write resumption; accumulates codec
/// buffer-reuse counts (flushed to metrics once a second).
fn flush_conn(c: &mut Conn, reuses: &mut u64) {
    if !c.out.is_empty() && c.out.flush_to(&mut c.stream).is_err() {
        c.dead = true;
    }
    *reuses += c.out.take_reuses() + c.dec.take_reuses();
}
