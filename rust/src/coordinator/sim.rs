//! Simulated deployment: client <-> server over the analytic WAN model,
//! against a shared virtual clock.
//!
//! Every WAN interaction — RPCs, compound flushes, striped range
//! fetches, prefetch waves, callback delivery, even connection setup —
//! first consults the deployment's optional seeded
//! [`FaultPlan`](crate::simnet::FaultPlan) (DESIGN.md §2.5), so a
//! schedule can drop, duplicate, delay, tear, or partition any of them,
//! and crash/restart the server process, deterministically from a seed.

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use crate::auth::{self, Authenticator, KeyPair};
use crate::callback::NotifyChannel;
use crate::chunkstore::Digest;
use crate::client::{ServerLink, XufsClient};
use crate::config::{StripesMode, XufsConfig};
use crate::homefs::{FileStore, FsError};
use crate::metrics::{names, Metrics};
use crate::proto::{CompoundOp, FileImage, MetaOp, NotifyEvent, RangeImage, Request, Response};
use crate::replica::Shipper;
use crate::runtime::DigestEngine;
use crate::server::{FileServer, Role};
use crate::simnet::{
    Clock, FaultAction, FaultPlan, SimClock, StepOutcome, TransferKind, VirtualTime, Wan,
};
use crate::transfer;
use crate::vdisk::DiskModel;

/// The simulated deployment: one home-space server, any number of mounted
/// clients, one WAN.
///
/// The server is the sharded concurrent core (DESIGN.md §2.6) shared as
/// a bare `Arc` — no global lock. The sim's single-threaded interleaving
/// of multi-client steps exercises the same per-shard routing the
/// threaded TCP deployment runs concurrently.
pub struct SimWorld {
    pub clock: SimClock,
    pub wan: Arc<Wan>,
    pub server: Arc<FileServer>,
    pub auth: Arc<Mutex<Authenticator>>,
    pub engine: Arc<DigestEngine>,
    pub cfg: XufsConfig,
    pub metrics: Metrics,
    pair: KeyPair,
    next_client: u64,
    /// Optional seeded fault plane shared by every link of this world.
    faults: Option<Arc<Mutex<FaultPlan>>>,
    /// Standby home servers (DESIGN.md §2.7/§2.11), stood up by
    /// [`Self::enable_replica`] — `replica.secondaries` of them. The
    /// first is the promotion target; with `replica.read_fanout` they
    /// all serve bounded-staleness reads. Clients mounted afterwards
    /// get every endpoint and fail over on reconnect.
    secondaries: Vec<Arc<FileServer>>,
    /// One log-shipping sidecar per secondary, streaming the primary's
    /// applied-op log (each link rides the WAN + fault plane).
    shippers: Vec<Shipper<SimLink>>,
    /// Set once [`Self::promote_secondary`] succeeded: the first
    /// secondary is the serving primary and the old primary is fenced.
    promoted: bool,
}

impl SimWorld {
    /// Stand up a deployment from config. The home space starts empty;
    /// populate it via `home()` or the workload generators.
    pub fn new(mut cfg: XufsConfig) -> Self {
        // CI pin (like FAULT_SEED): XUFS_CHUNKSTORE=1/0 forces the
        // chunk substrate on or off regardless of the config file, so
        // the fault matrix can run both substrates from one config.
        if let Ok(v) = std::env::var("XUFS_CHUNKSTORE") {
            cfg.chunkstore.enabled = !matches!(v.trim(), "0" | "false" | "off");
        }
        // CI pin (same pattern): XUFS_STRIPES=auto / <n> forces the
        // transport's stripe mode, so the fault matrix can run the
        // adaptive tuner (DESIGN.md §2.12) from an unchanged config.
        if let Ok(v) = std::env::var("XUFS_STRIPES") {
            let v = v.trim();
            if v.eq_ignore_ascii_case("auto") {
                cfg.transfer.stripes = StripesMode::Auto;
            } else if let Ok(n) = v.parse::<usize>() {
                cfg.transfer.stripes = StripesMode::Fixed(n.max(1));
            }
        }
        let clock = SimClock::new();
        let metrics = Metrics::new();
        let wan = Arc::new(Wan::new(cfg.wan.clone(), clock.clone()));
        let engine = Arc::new(
            DigestEngine::from_artifacts(&cfg.artifacts_dir, metrics.clone())
                .unwrap_or_else(|_| DigestEngine::native(metrics.clone())),
        );
        let mut rng = crate::util::Rng::new(cfg.seed ^ 0x5353_4855); // "USSH"
        let pair = KeyPair::generate(&mut rng, clock.now(), 12.0 * 3600.0);
        let home_disk = DiskModel::new(cfg.disk.home_bps, cfg.disk.home_op_s);
        let server = FileServer::new(
            FileStore::default(),
            home_disk,
            engine.clone(),
            cfg.stripe.min_block as usize,
            cfg.lease.duration_s,
            cfg.server.shards,
            metrics.clone(),
            cfg.chunkstore.clone(),
        )
        .with_integrity(cfg.integrity.clone());
        SimWorld {
            clock,
            wan,
            server: Arc::new(server),
            auth: Arc::new(Mutex::new(Authenticator::new(pair.clone(), cfg.seed ^ 0xA0A0))),
            engine,
            cfg,
            metrics,
            pair,
            next_client: 1,
            faults: None,
            secondaries: Vec::new(),
            shippers: Vec::new(),
            promoted: false,
        }
    }

    /// Stand up the standby fleet (DESIGN.md §2.7/§2.11):
    /// `replica.secondaries` [`FileServer`]s, each seeded from a
    /// snapshot of the primary's CURRENT home space (the initial full
    /// sync) and driven by its own log shipper that keeps it within
    /// `replica.max_lag_ops` of the primary's applied-op log. With
    /// `replica.read_fanout` every standby also serves bounded-staleness
    /// reads. Call AFTER pre-populating the home space and BEFORE
    /// mounting clients (mounted links learn every endpoint). Idempotent.
    pub fn enable_replica(&mut self) {
        if !self.secondaries.is_empty() {
            return;
        }
        self.cfg.replica.enabled = true;
        self.server.enable_replication();
        for _ in 0..self.cfg.replica.secondaries.max(1) {
            let snap = self.server.home().clone();
            let home_disk = DiskModel::new(self.cfg.disk.home_bps, self.cfg.disk.home_op_s);
            let sec = FileServer::new(
                snap,
                home_disk,
                self.engine.clone(),
                self.cfg.stripe.min_block as usize,
                self.cfg.lease.duration_s,
                self.cfg.server.shards,
                self.metrics.clone(),
                self.cfg.chunkstore.clone(),
            )
            .with_integrity(self.cfg.integrity.clone());
            sec.set_role(Role::Secondary);
            sec.enable_replication();
            if self.cfg.replica.read_fanout {
                sec.enable_read_serving(self.cfg.replica.staleness_ops);
            }
            let sec = Arc::new(sec);
            self.secondaries.push(sec.clone());
            // each shipper's WAN link targets its own secondary; client
            // id 0 is reserved for the replication daemons
            let link = SimLink {
                servers: vec![sec],
                active: 0,
                crash_target: self.server.clone(),
                auth: self.auth.clone(),
                wan: self.wan.clone(),
                wans: Vec::new(),
                clock: self.clock.clone(),
                channel: NotifyChannel::new(),
                cfg: self.cfg.clone(),
                metrics: self.metrics.clone(),
                pair: self.pair.clone(),
                client_id: 0,
                net_up: true,
                session: None,
                root: "/".to_string(),
                data_conns_warm: false,
                faults: self.faults.clone(),
                replication_link: true,
                read_pref: None,
                tuner: None,
                pipeline: Vec::new(),
            };
            self.shippers.push(Shipper::new(link, self.cfg.replica.ship_batch));
        }
    }

    /// The first standby — the promotion target (kept for the
    /// single-replica tests; fan-out tests use [`Self::secondaries`]).
    pub fn secondary(&self) -> Option<Arc<FileServer>> {
        self.secondaries.first().cloned()
    }

    /// Every standby, in endpoint order (endpoint `i + 1` in the
    /// clients' lists).
    pub fn secondaries(&self) -> &[Arc<FileServer>] {
        &self.secondaries
    }

    /// Has [`Self::promote_secondary`] completed?
    pub fn is_promoted(&self) -> bool {
        self.promoted
    }

    /// The node currently authoritative for the namespace: the promoted
    /// secondary after a failover, the primary otherwise. Invariant
    /// checks compare against THIS node's home space.
    pub fn authority(&self) -> Arc<FileServer> {
        if self.promoted {
            self.secondaries.first().cloned().expect("promoted implies a secondary")
        } else {
            self.server.clone()
        }
    }

    /// One replication housekeeping step: ship the applied-op log to
    /// every standby trailing by at least `replica.max_lag_ops` (`force`
    /// drains unconditionally — quiesce and promotion use that).
    /// Returns the WORST remaining lag across the fleet; shipping rides
    /// the WAN and the fault plane, so a partitioned/refused attempt
    /// just leaves that standby's lag behind for the next tick.
    pub fn replica_tick(&mut self, force: bool) -> u64 {
        if self.promoted || self.shippers.is_empty() {
            return 0;
        }
        let max_lag = self.cfg.replica.max_lag_ops;
        let mut worst = 0u64;
        for shipper in self.shippers.iter_mut() {
            let lag = shipper.lag(&self.server);
            if lag == 0 || (!force && lag < max_lag.max(1)) {
                worst = worst.max(lag);
                continue;
            }
            if !shipper.link().is_connected() {
                if shipper.link_mut().reconnect().is_err() {
                    worst = worst.max(lag);
                    continue;
                }
                if shipper.resync().is_err() {
                    worst = worst.max(lag);
                    continue;
                }
            }
            match shipper.ship(&self.server, &self.metrics) {
                Ok(left) => worst = worst.max(left),
                Err(_) => worst = worst.max(shipper.lag(&self.server)),
            }
        }
        // only the prefix EVERY standby acked is durable fleet-wide:
        // truncate the primary's log at the SLOWEST watermark (DESIGN.md
        // §2.8 retention — chunk pins released, I4 summary folded). A
        // lagging replica still needs everything past it.
        if let Some(min_wm) = self.shippers.iter().map(|s| s.watermark()).min() {
            self.server.repl_truncate_acked(min_wm);
        }
        worst
    }

    /// The explicit failover step (DESIGN.md §2.7): catch the secondary
    /// up to the end of the primary's DURABLE applied-op log (the
    /// shipper sidecar outlives the server process, so this works while
    /// the primary is down), promote it, and fence the old primary so
    /// its crontab restart cannot split-brain the namespace. Fails —
    /// retriable — while the replication link is partitioned.
    pub fn promote_secondary(&mut self) -> Result<(), FsError> {
        if self.promoted {
            return Ok(());
        }
        let Some(shipper) = self.shippers.first_mut() else {
            return Err(FsError::Invalid("promote: no replica configured".into()));
        };
        if !shipper.link().is_connected() {
            shipper.link_mut().reconnect()?;
            shipper.resync()?;
        }
        let lag = shipper.ship(&self.server, &self.metrics)?;
        if lag > 0 {
            return Err(FsError::Disconnected);
        }
        shipper.promote()?;
        self.server.retire();
        self.promoted = true;
        Ok(())
    }

    /// Install a seeded fault plane. Links mounted afterwards consult it
    /// on every WAN interaction; already-mounted links can be attached
    /// via [`SimLink::set_faults`]. The replication shipper's link (if
    /// any) is re-armed too — log shipping is WAN traffic like any other.
    pub fn set_fault_plan(&mut self, plan: Arc<Mutex<FaultPlan>>) {
        self.faults = Some(plan.clone());
        for shipper in self.shippers.iter_mut() {
            shipper.link_mut().set_faults(plan.clone());
        }
    }

    pub fn fault_plan(&self) -> Option<Arc<Mutex<FaultPlan>>> {
        self.faults.clone()
    }

    /// Direct access to the home space (pre-populating workloads, and the
    /// "user edits a file at home" side of consistency tests). The server
    /// takes `&self`; `FileServer::home_mut` hands out store write guards.
    pub fn home<R>(&self, f: impl FnOnce(&FileServer) -> R) -> R {
        f(&self.server)
    }

    /// The endpoint list a freshly mounted client learns from config:
    /// the primary first, then every secondary in fleet order.
    fn endpoints(&self) -> Vec<Arc<FileServer>> {
        let mut servers = vec![self.server.clone()];
        servers.extend(self.secondaries.iter().cloned());
        servers
    }

    /// Per-endpoint WAN paths for one mounted site: entry 0 is the
    /// world's shared primary path (its stats feed the existing
    /// WAN-accounting tests); each secondary gets its own path whose
    /// RTT comes from `replica_rtts` (falling back to the primary's).
    /// Heterogeneous RTTs are the read-fanout win: a site reads from
    /// its NEAREST serving replica.
    fn site_wans(&self, replica_rtts: &[f64]) -> Vec<Arc<Wan>> {
        let mut wans = vec![self.wan.clone()];
        for j in 0..self.secondaries.len() {
            let mut wcfg = self.cfg.wan.clone();
            wcfg.rtt_s = replica_rtts.get(j).copied().unwrap_or(wcfg.rtt_s);
            wans.push(Arc::new(Wan::new(wcfg, self.clock.clone())));
        }
        wans
    }

    /// USSH login + mount: authenticate, open the control + callback
    /// channels, register the callback, return a mounted client.
    pub fn mount(&mut self, root: &str) -> Result<XufsClient<SimLink>, FsError> {
        self.mount_at(root, &[])
    }

    /// [`Self::mount`] from a site with its own replica RTT vector
    /// (`replica_rtts[j]` = seconds to secondary `j`; missing entries
    /// use the primary RTT).
    pub fn mount_at(
        &mut self,
        root: &str,
        replica_rtts: &[f64],
    ) -> Result<XufsClient<SimLink>, FsError> {
        let client_id = self.next_client;
        self.next_client += 1;
        let mut link = SimLink {
            servers: self.endpoints(),
            active: 0,
            crash_target: self.server.clone(),
            auth: self.auth.clone(),
            wan: self.wan.clone(),
            wans: self.site_wans(replica_rtts),
            clock: self.clock.clone(),
            channel: NotifyChannel::new(),
            cfg: self.cfg.clone(),
            metrics: self.metrics.clone(),
            pair: self.pair.clone(),
            client_id,
            net_up: true,
            session: None,
            root: root.to_string(),
            data_conns_warm: false,
            faults: self.faults.clone(),
            replication_link: false,
            read_pref: None,
            tuner: None,
            pipeline: Vec::new(),
        };
        link.connect()?;
        Ok(XufsClient::new(
            link,
            self.cfg.clone(),
            self.engine.clone(),
            Arc::new(self.clock.clone()),
            root,
            self.metrics.clone(),
        ))
    }

    /// Rebuild a crashed client from its surviving cache space (the
    /// `xufs sync` recovery tool): fresh USSH login **under the same
    /// client identity** (sequence numbers are per client, so replaying
    /// ops whose replies were lost must hit the server's idempotence
    /// watermark, not a fresh one), recover the cache index and the
    /// durable op log, replay what the crash left behind. Returns the
    /// client plus the count of corrupt/skipped log records.
    pub fn mount_recovered(
        &mut self,
        root: &str,
        store: &FileStore,
        client_id: u64,
    ) -> Result<(XufsClient<SimLink>, usize), FsError> {
        let mut link = SimLink {
            servers: self.endpoints(),
            active: 0,
            crash_target: self.server.clone(),
            auth: self.auth.clone(),
            wan: self.wan.clone(),
            wans: self.site_wans(&[]),
            clock: self.clock.clone(),
            channel: NotifyChannel::new(),
            cfg: self.cfg.clone(),
            metrics: self.metrics.clone(),
            pair: self.pair.clone(),
            client_id,
            net_up: true,
            session: None,
            root: root.to_string(),
            data_conns_warm: false,
            faults: self.faults.clone(),
            replication_link: false,
            read_pref: None,
            tuner: None,
            pipeline: Vec::new(),
        };
        link.connect()?;
        // the store is cloned only once the login succeeded — retrying
        // callers (a partition blocks the connect) pay nothing per
        // refused attempt
        Ok(XufsClient::recover(
            link,
            self.cfg.clone(),
            self.engine.clone(),
            Arc::new(self.clock.clone()),
            root,
            store.clone(),
            self.metrics.clone(),
        ))
    }

    /// Bit-rot injection for the fault explorer (DESIGN.md §2.10): flip
    /// one byte of one chunk resident on BOTH nodes of the pair (sorted
    /// digest intersection, picked by `sel`), rotting the PRIMARY's
    /// copy. Choosing a shared chunk is what makes the fault
    /// *recoverable*: the secondary's clean copy can heal it. Returns
    /// the rotted digest, or `None` without a replica / shared chunks.
    pub fn corrupt_shared_chunk(&self, sel: u64) -> Option<Digest> {
        let sec = self.secondaries.first()?;
        let shared: Vec<Digest> = {
            let on_sec: HashSet<Digest> = sec.home().chunk_digests().into_iter().collect();
            self.server
                .home()
                .chunk_digests()
                .into_iter()
                .filter(|d| on_sec.contains(d))
                .collect()
        };
        if shared.is_empty() {
            return None;
        }
        let d = shared[(sel % shared.len() as u64) as usize];
        self.server.home_mut().corrupt_chunk_at(&d, sel >> 16).then_some(d)
    }

    /// Rot one chunk on a READ replica (DESIGN.md §2.11): flip a byte of
    /// a chunk resident on secondary `replica`. The replica's scrub
    /// quarantines it, reads of it refuse with code 118 (clients fall
    /// back to the primary), and [`Self::repair_tick`] heals it from the
    /// primary's clean copy. Returns the rotted digest, or `None` when
    /// that replica holds no chunks.
    pub fn corrupt_replica_chunk(&self, replica: usize, sel: u64) -> Option<Digest> {
        let sec = self.secondaries.get(replica)?;
        let digests = sec.home().chunk_digests();
        if digests.is_empty() {
            return None;
        }
        let d = digests[(sel % digests.len() as u64) as usize];
        sec.home_mut().corrupt_chunk_at(&d, sel >> 16).then_some(d)
    }

    /// One repair pass (DESIGN.md §2.10): scrub the primary's whole
    /// chunk table, then heal everything quarantined from the
    /// secondary's clean copies over the repair plane (`ChunkFetch` on
    /// the shipper's link — it rides the WAN and the fault plane, so a
    /// partitioned attempt just leaves the quarantine for the next
    /// tick). Returns how many chunks remain quarantined.
    pub fn repair_tick(&mut self) -> Result<u64, FsError> {
        if self.promoted {
            // post-failover the old primary is fenced; the promoted
            // node's own rot (never injected by the explorer) would
            // need a new standby to heal from
            return Ok(self.authority().quarantined_chunks().len() as u64);
        }
        self.server.scrub_all_chunks();
        let quarantined = self.server.quarantined_chunks();
        if !quarantined.is_empty() {
            if let Some(shipper) = self.shippers.first_mut() {
                if shipper.link().is_connected() || shipper.link_mut().reconnect().is_ok() {
                    let fills = shipper.fetch_chunks(&quarantined)?;
                    self.server.repair_chunks(&fills);
                }
            }
        }
        let mut remaining = self.server.quarantined_chunks().len() as u64;
        // the standbys scrub too, healing the REVERSE direction — from
        // the primary's clean copies (DESIGN.md §2.11): a read replica
        // with a quarantined chunk refuses reads of it (code 118, the
        // client falls back to the primary) until this heal lands
        for sec in &self.secondaries {
            sec.scrub_all_chunks();
            let q = sec.quarantined_chunks();
            if !q.is_empty() {
                let resp =
                    self.server.handle(0, Request::ChunkFetch { digests: q }, self.clock.now());
                if let Response::ChunkFill { chunks } = resp {
                    let bytes: u64 = chunks.iter().map(|c| c.len() as u64).sum();
                    self.wan.rpc(&self.clock, 64, bytes + 64);
                    sec.repair_chunks(&chunks);
                }
            }
            remaining += sec.quarantined_chunks().len() as u64;
        }
        Ok(remaining)
    }

    /// Simulate a server crash (process dies; home disk survives).
    pub fn server_crash(&self) {
        self.server.crash();
    }

    /// Server restarted (paper: by crontab).
    pub fn server_restart(&self) {
        self.server.restart();
    }

    /// Housekeeping tick (lease expiry, as the server's background
    /// thread — on every node of the pair).
    pub fn server_tick(&self) {
        let now = self.clock.now();
        self.server.expire_leases(now);
        for sec in &self.secondaries {
            sec.expire_leases(now);
        }
    }
}

/// Simulated transport: direct calls into the shared server, with WAN time
/// accounted against the virtual clock, plus auth + callback channel.
///
/// Replication-aware (DESIGN.md §2.7): the link holds the config's full
/// endpoint list. Requests go to the ACTIVE endpoint; a failed connect
/// rotates through the others, so when the primary is crashed or fenced
/// and the secondary has been promoted, the client fails over on its
/// next reconnect (counted in `replica.failovers`). A non-promoted
/// standby refuses with code 112, which the link surfaces as
/// `Disconnected` — the client just keeps retrying until an endpoint
/// serves.
pub struct SimLink {
    /// Endpoint list from config: primary first, then the secondary.
    servers: Vec<Arc<FileServer>>,
    /// Index of the endpoint this session is bound to.
    active: usize,
    /// The node the fault plane's server-crash/restart events target:
    /// always the ORIGINAL primary (the paper's crontab-managed home
    /// node; the issue's schedules crash the primary, not the standby).
    crash_target: Arc<FileServer>,
    auth: Arc<Mutex<Authenticator>>,
    wan: Arc<Wan>,
    /// Per-endpoint WAN paths, index-aligned with `servers`. Entry 0 is
    /// the world's shared primary path; read replicas get their own
    /// (possibly closer) paths — the latency half of the fan-out win.
    /// Empty on replication links (they only ever talk to entry 0).
    wans: Vec<Arc<Wan>>,
    clock: SimClock,
    channel: NotifyChannel,
    cfg: XufsConfig,
    metrics: Metrics,
    pair: KeyPair,
    client_id: u64,
    /// Simulated client-side network state (false = cable pulled).
    net_up: bool,
    session: Option<u64>,
    root: String,
    /// Striped data connections stay open between paged range fetches
    /// (the paper's persistent transfer connections): only the first
    /// fetch of a session pays connection setup + slow-start.
    data_conns_warm: bool,
    /// Optional shared fault plane consulted before every interaction.
    faults: Option<Arc<Mutex<FaultPlan>>>,
    /// True only for the log shipper's link (DESIGN.md §2.7): it may
    /// bind to a standby (whose 112 on callback registration is
    /// expected — the replication plane needs no callbacks), while a
    /// CLIENT link treats that refusal as "wrong endpoint, keep
    /// rotating" so it can never wedge on a node that serves nothing.
    replication_link: bool,
    /// Test hook: pin bounded-staleness reads to one endpoint index
    /// (the fault explorer randomizes this per op to cover every
    /// replica). `None` = route to the lowest-RTT serving replica.
    read_pref: Option<usize>,
    /// Adaptive stripe tuner (transport v2, DESIGN.md §2.12), created
    /// lazily on the first transfer when `transfer.stripes = "auto"`.
    tuner: Option<transfer::AutoTuner>,
    /// Speculative pipelined-readahead transfers in flight (§2.12),
    /// oldest first, bounded by `transfer.pipeline_window`.
    pipeline: Vec<PipelinedFetch>,
}

/// One speculative transfer started by a [`ServerLink::pipeline_hint`]
/// (DESIGN.md §2.12): the modeled WAN work starts at hint time without
/// advancing the clock, so the matching demand fetch pays only the
/// not-yet-elapsed tail — the analytic form of compute/transfer overlap.
struct PipelinedFetch {
    path: String,
    offset: u64,
    len: u64,
    version: u64,
    image: RangeImage,
    payload: u64,
    stripes: usize,
    kind: TransferKind,
    /// Modeled transfer duration — the tuner's goodput sample (a hit
    /// never runs `Wan::transfer`, but the speculative transfer still
    /// took this long at this stripe count).
    secs: f64,
    ready_at: VirtualTime,
}

impl SimLink {
    /// Attach (or replace) the fault plane on an already-mounted link.
    pub fn set_faults(&mut self, plan: Arc<Mutex<FaultPlan>>) {
        self.faults = Some(plan);
    }

    /// The endpoint this session is currently bound to.
    fn server(&self) -> &Arc<FileServer> {
        &self.servers[self.active]
    }

    /// Which endpoint the session is bound to (0 = primary) — the
    /// failover tests read this.
    pub fn active_endpoint(&self) -> usize {
        self.active
    }

    /// Pin bounded-staleness reads to endpoint `pref` (1-based into the
    /// endpoint list: 1 = first secondary), or `None` to route to the
    /// lowest-RTT serving replica again. A pinned endpoint that is down
    /// or not serving falls back to the primary like any other refusal.
    pub fn set_read_preference(&mut self, pref: Option<usize>) {
        self.read_pref = pref;
    }

    /// The WAN path to endpoint `idx` (the shared primary path when the
    /// link predates the replica fleet).
    fn link_wan(&self, idx: usize) -> Arc<Wan> {
        self.wans.get(idx).cloned().unwrap_or_else(|| self.wan.clone())
    }

    /// The replica a bounded-staleness read should try first, or `None`
    /// to go straight to the primary. Fan-out applies only to CLIENT
    /// links still bound to the primary (after a failover the promoted
    /// node IS the active endpoint) with read fan-out configured and at
    /// least one serving, reachable replica.
    fn fanout_replica(&self) -> Option<usize> {
        if self.replication_link || !self.cfg.replica.read_fanout || self.active != 0 {
            return None;
        }
        if let Some(p) = self.read_pref {
            let ok = p >= 1
                && p < self.servers.len()
                && self.servers[p].is_up()
                && self.servers[p].read_serving();
            return ok.then_some(p);
        }
        let mut best: Option<(usize, f64)> = None;
        for i in 1..self.servers.len() {
            if !self.servers[i].is_up() || !self.servers[i].read_serving() {
                continue;
            }
            let rtt = self.link_wan(i).config().rtt_s;
            if best.map_or(true, |(_, b)| rtt < b) {
                best = Some((i, rtt));
            }
        }
        best.map(|(i, _)| i)
    }

    /// Advance the fault plane one interaction and apply its control
    /// side-effects (server crash/restart, partition severing the
    /// session). Crash/restart events always target the PRIMARY (see
    /// [`Self::crash_target`]). Returns the outcome for the caller to
    /// act on.
    fn fault_step(&mut self) -> StepOutcome {
        let Some(plan) = &self.faults else { return StepOutcome::default() };
        let out = plan.lock().unwrap().step();
        if out.server_restart {
            self.crash_target.restart();
        }
        if out.server_crash {
            self.crash_target.crash();
        }
        if out.partitioned {
            self.metrics.incr(names::FAULT_PARTITIONED_OPS);
            self.sever();
        } else if out.action.is_some() {
            self.metrics.incr(names::FAULTS_INJECTED);
        }
        if let Some(FaultAction::Delay { ms }) = out.action {
            // queueing delay before the interaction proceeds
            self.clock.advance_secs(ms as f64 / 1e3);
        }
        out
    }

    /// The connection state dies (partition): in-flight callbacks are
    /// lost with it and the session must be re-established.
    fn sever(&mut self) {
        self.channel.disconnect();
        self.session = None;
        self.data_conns_warm = false;
        self.drop_pipeline();
    }

    /// Stripe count for one transfer under `transfer.stripes`
    /// (DESIGN.md §2.12): the static size-based plan, a fixed override,
    /// or the adaptive tuner's current working count.
    fn stripe_plan(&mut self, payload: u64) -> usize {
        match self.cfg.transfer.stripes {
            StripesMode::Planned => transfer::stripes_for(payload, &self.cfg.stripe),
            StripesMode::Fixed(n) => n.clamp(1, self.cfg.stripe.max_stripes.max(1)),
            StripesMode::Auto => {
                let max = self.cfg.stripe.max_stripes.max(1);
                self.tuner.get_or_insert_with(|| transfer::AutoTuner::new(1, max)).stripes()
            }
        }
    }

    /// Abandon every speculative transfer in flight (connection loss,
    /// window eviction at the call sites): the bytes crossed the WAN for
    /// nothing, which is exactly what the waste metric counts.
    fn drop_pipeline(&mut self) {
        for p in self.pipeline.drain(..) {
            self.metrics.add(names::PIPELINE_WASTED_BYTES, p.image.bytes());
        }
    }

    /// A code-112 "wrong endpoint" answer (standby/fenced node,
    /// DESIGN.md §2.7): kill the session so `is_connected` turns false
    /// and the next reconnect rotates endpoints, and surface the same
    /// `Disconnected` a dead server would.
    fn wrong_endpoint(&mut self) -> FsError {
        self.sever();
        FsError::Disconnected
    }

    /// Establish control + callback channels: TCP setup, USSH
    /// challenge-response, callback registration. Connection setup is a
    /// WAN interaction like any other: a partitioned or dropped step
    /// fails the attempt (and advances the schedule, so retrying makes
    /// progress toward the partition's end).
    ///
    /// Failover (DESIGN.md §2.7): the active endpoint is tried first;
    /// a refusal — connect refusal from a crashed primary, the 112
    /// "wrong endpoint" answer from a fenced/standby node — rotates to
    /// the next endpoint in the config list. Binding to a different
    /// endpoint than before counts in `replica.failovers`.
    fn connect(&mut self) -> Result<(), FsError> {
        let out = self.fault_step();
        if out.partitioned || matches!(out.action, Some(FaultAction::DropRequest)) {
            return Err(FsError::Disconnected);
        }
        if !self.net_up {
            return Err(FsError::Disconnected);
        }
        let n = self.servers.len();
        let mut last = FsError::Disconnected;
        for k in 0..n {
            let idx = (self.active + k) % n;
            match self.connect_to(idx) {
                Ok(()) => {
                    if idx != self.active {
                        self.active = idx;
                        self.metrics.incr(names::REPLICA_FAILOVERS);
                    }
                    return Ok(());
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    /// One endpoint's worth of connection setup (see [`Self::connect`]).
    fn connect_to(&mut self, idx: usize) -> Result<(), FsError> {
        let server = self.servers[idx].clone();
        if !server.is_up() {
            return Err(FsError::Disconnected);
        }
        self.data_conns_warm = false;
        // control connection + callback connection setup
        self.wan.connect(&self.clock);
        self.wan.connect(&self.clock);
        // challenge-response (2 RPCs)
        let nonce = {
            let mut a = self.auth.lock().unwrap();
            a.challenge(&self.pair.key_id)
        };
        self.wan.rpc(&self.clock, 64, 96);
        let proof = auth::prove(&self.pair.phrase, &self.pair.key_id, &nonce);
        let session = {
            let mut a = self.auth.lock().unwrap();
            a.verify_proof(&self.pair.key_id, &proof, self.clock.now())
        };
        self.wan.rpc(&self.clock, 96, 32);
        let Some(session) = session else {
            self.metrics.incr(names::AUTH_FAILURES);
            return Err(FsError::Perm("USSH authentication failed".into()));
        };
        // attach + register the callback channel; a standby or fenced
        // endpoint refuses the registration (code 112), which fails a
        // CLIENT's attempt (rotation keeps looking for the serving
        // node) but is expected on the shipper's link — the replication
        // plane needs no callbacks, and binding a client to a node that
        // serves nothing would wedge it there
        server.attach_channel(self.client_id, self.channel.clone());
        let resp = server.handle(
            self.client_id,
            Request::RegisterCallback { root: self.root.clone(), client_id: self.client_id },
            self.clock.now(),
        );
        self.wan.rpc(&self.clock, 64, 16);
        match resp {
            Response::CallbackRegistered => {}
            Response::Err { code: 112, .. } if self.replication_link => {}
            Response::Err { code: 111, .. } | Response::Err { code: 112, .. } => {
                return Err(FsError::Disconnected)
            }
            r => return Err(FsError::Protocol(format!("unexpected register reply {r:?}"))),
        }
        self.session = Some(session);
        Ok(())
    }

    /// Pull the (virtual) network cable.
    pub fn set_network(&mut self, up: bool) {
        self.net_up = up;
        if !up {
            self.channel.disconnect();
            self.session = None;
            self.data_conns_warm = false;
            self.drop_pipeline();
        }
    }

    pub fn channel(&self) -> &NotifyChannel {
        &self.channel
    }

    fn check_up(&self) -> Result<(), FsError> {
        if !self.net_up || self.session.is_none() {
            return Err(FsError::Disconnected);
        }
        if !self.server().is_up() {
            return Err(FsError::Disconnected);
        }
        Ok(())
    }
}

impl ServerLink for SimLink {
    fn rpc(&mut self, req: Request) -> Result<Response, FsError> {
        let out = self.fault_step();
        if out.partitioned {
            return Err(FsError::Disconnected);
        }
        self.check_up()?;
        if let Request::Compound { ops } = &req {
            self.metrics.incr(names::COMPOUND_RPCS);
            self.metrics.add(names::COMPOUND_OPS, ops.len() as u64);
        }
        let req_bytes = req.wire_bytes();
        match out.action {
            Some(FaultAction::DropRequest) => {
                // lost before the server saw it; the client pays the
                // timeout round trip
                self.wan.rpc(&self.clock, req_bytes, 0);
                return Err(FsError::Disconnected);
            }
            Some(FaultAction::DropReply) => {
                // the server APPLIES the request; only the reply is lost.
                // The client must treat this exactly like a drop — which
                // is why replay has to be idempotent.
                self.server().disk.op(&self.clock);
                let _ = self.server().handle(self.client_id, req, self.clock.now());
                self.wan.rpc(&self.clock, req_bytes, 0);
                return Err(FsError::Disconnected);
            }
            Some(FaultAction::Duplicate) => {
                // the request reaches the server twice; the client sees
                // the second reply (both must be identical under
                // idempotent handling). Lock RPCs are exempt: they ride
                // the control connection and are never retransmitted, so
                // network-level duplication cannot reach them — and a
                // doubled LockAcquire would mint a second record whose
                // orphaned token wrongly blocks other clients.
                let duplicable = !matches!(
                    req,
                    Request::LockAcquire { .. }
                        | Request::LockRenew { .. }
                        | Request::LockRelease { .. }
                );
                self.server().disk.op(&self.clock);
                if duplicable {
                    let _ = self.server().handle(self.client_id, req.clone(), self.clock.now());
                }
                let resp = self.server().handle(self.client_id, req, self.clock.now());
                self.wan.rpc(&self.clock, req_bytes, resp.wire_bytes());
                self.metrics.add(names::WAN_RPCS, 1);
                if let Response::Err { code: 112, .. } = &resp {
                    return Err(self.wrong_endpoint());
                }
                return Ok(resp);
            }
            // a torn bulk transfer does not apply to small control RPCs
            Some(FaultAction::Interrupt) | Some(FaultAction::Delay { .. }) | None => {}
        }
        // bounded-staleness read fan-out (DESIGN.md §2.11): whole-file
        // and attribute reads try the closest serving replica first;
        // every refusal — 119 too-stale, 112 fenced, 118 integrity,
        // 111 down — falls back to the primary transparently, without
        // touching the primary session
        if matches!(req, Request::Fetch { .. } | Request::FetchMeta { .. }) {
            if let Some(ridx) = self.fanout_replica() {
                let replica = self.servers[ridx].clone();
                replica.disk.op(&self.clock);
                let resp = replica.handle(self.client_id, req.clone(), self.clock.now());
                self.link_wan(ridx).rpc(&self.clock, req_bytes, resp.wire_bytes());
                self.metrics.add(names::WAN_RPCS, 1);
                match &resp {
                    Response::Err { code: 111 | 112 | 118 | 119, .. } => {
                        self.metrics.incr(names::REPLICA_READ_REDIRECTS);
                    }
                    _ => return Ok(resp),
                }
            }
        }
        // server-side disk op for metadata service
        self.server().disk.op(&self.clock);
        let resp = self.server().handle(self.client_id, req, self.clock.now());
        self.wan.rpc(&self.clock, req_bytes, resp.wire_bytes());
        self.metrics.add(names::WAN_RPCS, 1);
        // "wrong endpoint" (standby/fenced — code 112) surfaces as a
        // disconnection: the client reconnects and fails over
        if let Response::Err { code: 112, .. } = &resp {
            return Err(self.wrong_endpoint());
        }
        Ok(resp)
    }

    fn fetch_range(
        &mut self,
        path: &str,
        offset: u64,
        len: u64,
        expect_version: u64,
    ) -> Result<RangeImage, FsError> {
        let out = self.fault_step();
        if out.partitioned {
            return Err(FsError::Disconnected);
        }
        self.check_up()?;
        if matches!(out.action, Some(FaultAction::DropRequest) | Some(FaultAction::DropReply)) {
            // a torn connection before any block crossed; a fetch has no
            // server-side state so request- and reply-loss look alike
            self.wan.rpc(&self.clock, 128, 0);
            return Err(FsError::Disconnected);
        }
        // transport v2 (DESIGN.md §2.12): a speculative transfer already
        // in flight for exactly these coordinates satisfies the fault
        // directly — the client waits only for the not-yet-elapsed tail
        // of the modeled transfer instead of paying it whole. The bytes
        // are the same ones a demand fetch would have returned (the hint
        // ran the same server handler at the same pinned version).
        if let Some(i) = self.pipeline.iter().position(|p| {
            p.path == path && p.offset == offset && p.len == len && p.version == expect_version
        }) {
            let hit = self.pipeline.remove(i);
            // the serving node's disk read overlaps the transfer tail:
            // charge it first, then join the transfer's completion
            // instant (advance_to keeps whichever is later)
            self.server().disk.io(&self.clock, hit.image.bytes());
            self.clock.advance_to(hit.ready_at);
            self.link_wan(self.active).account_transfer(hit.payload, hit.stripes, hit.kind);
            // the speculative transfer is a goodput sample like any
            // other — without it the tuner would go deaf the moment the
            // pipeline starts covering every fault
            if let Some(t) = self.tuner.as_mut() {
                t.observe(hit.payload, hit.secs, &self.metrics);
            }
            self.metrics.add(names::WAN_BYTES_RX, hit.image.bytes());
            self.metrics.incr(names::RANGE_FETCHES);
            self.metrics.incr(names::PIPELINED_HITS);
            return Ok(hit.image);
        }
        // a hint for the same spot that does NOT match (the scan went
        // elsewhere, or the version moved) is dead weight: count it
        if let Some(i) =
            self.pipeline.iter().position(|p| p.path == path && p.offset == offset)
        {
            let dead = self.pipeline.remove(i);
            self.metrics.add(names::PIPELINE_WASTED_BYTES, dead.image.bytes());
        }
        let req = Request::FetchRange { path: path.to_string(), offset, len, expect_version };
        // bounded-staleness fan-out (DESIGN.md §2.11): paged reads try
        // the closest serving replica; a refusal — 119 lagging, 118
        // quarantined copy, 112 fenced, 111 down — costs one small
        // round on the replica path and falls back to the primary
        let mut widx = self.active;
        let resp = {
            let r = match self.fanout_replica() {
                Some(ridx) => {
                    let r = self.servers[ridx].handle(self.client_id, req.clone(), self.clock.now());
                    match &r {
                        Response::Err { code: 111 | 112 | 118 | 119, .. } => {
                            self.link_wan(ridx).rpc(&self.clock, 128, 64);
                            self.metrics.incr(names::REPLICA_READ_REDIRECTS);
                            self.server().handle(self.client_id, req, self.clock.now())
                        }
                        _ => {
                            widx = ridx;
                            r
                        }
                    }
                }
                None => self.server().handle(self.client_id, req, self.clock.now()),
            };
            if let Response::FileBlocks { extents, .. } = &r {
                // the serving node reads the blocks off its disk
                let bytes: u64 = extents.iter().map(|x| x.data.len() as u64).sum();
                self.servers[widx].disk.io(&self.clock, bytes);
            }
            r
        };
        let wan = self.link_wan(widx);
        match resp {
            Response::FileBlocks { version, extents } => {
                let image = RangeImage { version, extents };
                let payload = image.bytes() + 16 * image.extents.len() as u64 + 64;
                let stripes = self.stripe_plan(payload);
                let kind = if self.data_conns_warm {
                    TransferKind::WarmConnections
                } else {
                    TransferKind::NewConnections
                };
                self.data_conns_warm = true;
                if matches!(out.action, Some(FaultAction::Interrupt)) && !image.extents.is_empty() {
                    // the stripe set dies mid-transfer after roughly half
                    // the blocks landed (an empty reply has nothing to
                    // tear and delivers normally)
                    let torn_at = image.extents.len() / 2;
                    if torn_at == 0 {
                        // nothing landed before the tear: surface the
                        // typed interruption with the resume block
                        let first = image.extents[0].index as u64;
                        wan.rpc(&self.clock, 128, 0);
                        return Err(FsError::Interrupted { resumed_from_block: first });
                    }
                    // the landed prefix crossed the WAN once; the link
                    // resumes the remainder over fresh connections (the
                    // resumable-fetch path real WAN hiccups also take)
                    let torn_bytes: u64 =
                        image.extents[..torn_at].iter().map(|x| x.data.len() as u64).sum();
                    wan.transfer(&self.clock, torn_bytes.max(1), stripes, kind);
                    let rest = payload - torn_bytes.min(payload);
                    wan.transfer(&self.clock, rest.max(1), stripes, TransferKind::NewConnections);
                    self.metrics.incr(names::RESUMED_FETCHES);
                } else {
                    let dt = wan.transfer(&self.clock, payload, stripes, kind);
                    // the tuner learns from clean transfers only — a torn
                    // one's duration says nothing about the stripe count
                    if let Some(t) = self.tuner.as_mut() {
                        t.observe(payload, dt, &self.metrics);
                    }
                }
                self.metrics.add(names::WAN_BYTES_RX, image.bytes());
                self.metrics.incr(names::RANGE_FETCHES);
                Ok(image)
            }
            Response::Err { code: 2, msg } => Err(FsError::NotFound(msg)),
            Response::Err { code: 21, msg } => Err(FsError::IsADir(msg)),
            Response::Err { code: 116, msg } | Response::Err { code: 119, msg } => {
                Err(FsError::Stale(msg))
            }
            Response::Err { code: 111, .. } => Err(FsError::Disconnected),
            Response::Err { code: 112, .. } => Err(self.wrong_endpoint()),
            // integrity refusal (DESIGN.md §2.10): the server detected
            // rot and will not serve the bytes
            Response::Err { code: 118, msg } => Err(FsError::Corrupted(msg)),
            r => Err(FsError::Protocol(format!("unexpected range response {r:?}"))),
        }
    }

    fn pipeline_hint(&mut self, path: &str, offset: u64, len: u64, expect_version: u64) {
        if !self.cfg.transfer.pipeline || len == 0 {
            return;
        }
        // purely advisory — no fault-plane step, no clock advance: an
        // unreachable server just means no speculation happens, and the
        // later demand fault pays full price (and takes the fault step).
        // Keeping the fault schedule untouched is what lets the 220-seed
        // explorer run identically with the pipeline on or off.
        if self.check_up().is_err() {
            return;
        }
        let req = Request::FetchRange { path: path.to_string(), offset, len, expect_version };
        let resp = self.server().handle(self.client_id, req, self.clock.now());
        let Response::FileBlocks { version, extents } = resp else { return };
        let image = RangeImage { version, extents };
        let payload = image.bytes() + 16 * image.extents.len() as u64 + 64;
        let stripes = self.stripe_plan(payload);
        let kind = if self.data_conns_warm {
            TransferKind::WarmConnections
        } else {
            TransferKind::NewConnections
        };
        self.data_conns_warm = true;
        let t = self.link_wan(self.active).transfer_secs(payload, stripes, kind);
        while self.pipeline.len() >= self.cfg.transfer.pipeline_window.max(1) {
            let evicted = self.pipeline.remove(0);
            self.metrics.add(names::PIPELINE_WASTED_BYTES, evicted.image.bytes());
        }
        self.pipeline.push(PipelinedFetch {
            path: path.to_string(),
            offset,
            len,
            version: expect_version,
            image,
            payload,
            stripes,
            kind,
            secs: t,
            ready_at: self.clock.now().add_secs(t),
        });
    }

    fn prefetch(&mut self, files: &[(String, u64)]) -> Vec<FileImage> {
        if !files.is_empty() {
            let out = self.fault_step();
            // prefetch is best-effort: loss-class faults yield nothing
            // (no retry); a Delay (already charged by fault_step) or a
            // Duplicate still delivers
            if out.partitioned
                || matches!(
                    out.action,
                    Some(FaultAction::DropRequest)
                        | Some(FaultAction::DropReply)
                        | Some(FaultAction::Interrupt)
                )
            {
                return Vec::new();
            }
        }
        if self.check_up().is_err() {
            return Vec::new();
        }
        let mut images = Vec::with_capacity(files.len());
        let mut sizes = Vec::with_capacity(files.len());
        for (path, _size) in files {
            if let Response::File { image } = self.server().handle(
                self.client_id,
                Request::Fetch { path: path.clone(), min_version: 0 },
                self.clock.now(),
            ) {
                sizes.push(image.data.len() as u64 + 256);
                images.push(image);
            }
        }
        // server disk: sequential read of all prefetched bytes
        let total: u64 = images.iter().map(|i| i.data.len() as u64).sum();
        self.server().disk.io(&self.clock, total);
        // the 12 prefetch threads fetch in parallel waves
        self.wan.batch_fetch(&self.clock, &sizes, self.cfg.stripe.prefetch_threads);
        self.metrics.add(names::WAN_BYTES_RX, sizes.iter().sum::<u64>());
        images
    }

    fn ship(&mut self, seq: u64, op: &MetaOp) -> Result<Response, FsError> {
        let out = self.fault_step();
        if out.partitioned {
            return Err(FsError::Disconnected);
        }
        self.check_up()?;
        let bytes = op.wire_bytes();
        if matches!(out.action, Some(FaultAction::DropRequest) | Some(FaultAction::Interrupt)) {
            // the payload never arrives whole; nothing applied
            self.wan.rpc(&self.clock, bytes.min(1024), 0);
            return Err(FsError::Disconnected);
        }
        if bytes <= self.cfg.stripe.stripe_threshold {
            // small meta-ops drain over the persistent control connection
            // (1 RTT) — the queue's normal path
            self.wan.rpc(&self.clock, bytes, 64);
        } else {
            // large payloads open striped data connections (§3.3)
            let stripes = transfer::stripes_for(bytes, &self.cfg.stripe);
            self.wan.transfer(&self.clock, bytes, stripes, TransferKind::NewConnections);
        }
        self.metrics.add(names::WAN_BYTES_TX, bytes);
        let resp = {
            // server writes the payload to its disk
            self.server().disk.io(&self.clock, bytes);
            if matches!(out.action, Some(FaultAction::Duplicate)) {
                let _ = self.server().handle(
                    self.client_id,
                    Request::Apply { seq, op: op.clone() },
                    self.clock.now(),
                );
            }
            self.server().handle(
                self.client_id,
                Request::Apply { seq, op: op.clone() },
                self.clock.now(),
            )
        };
        if matches!(out.action, Some(FaultAction::DropReply)) {
            // applied at the server; the ack never comes back
            return Err(FsError::Disconnected);
        }
        if matches!(resp, Response::Err { code: 112, .. }) {
            return Err(self.wrong_endpoint());
        }
        if matches!(resp, Response::Err { code: 111, .. }) {
            return Err(FsError::Disconnected);
        }
        Ok(resp)
    }

    fn ship_compound(&mut self, ops: &[(u64, MetaOp)]) -> Result<Vec<Response>, FsError> {
        let out = self.fault_step();
        if out.partitioned {
            return Err(FsError::Disconnected);
        }
        self.check_up()?;
        let payload: u64 = ops.iter().map(|(_, op)| op.wire_bytes()).sum::<u64>() + 16;
        if matches!(out.action, Some(FaultAction::DropRequest) | Some(FaultAction::Interrupt)) {
            // the frame never arrives whole; NOTHING in the batch applied
            self.wan.rpc(&self.clock, payload.min(1024), 0);
            return Err(FsError::Disconnected);
        }
        if payload <= self.cfg.stripe.stripe_threshold {
            // the whole batch drains over the persistent control
            // connection in ONE round trip — the compound win
            self.wan.rpc(&self.clock, payload, 64 + 16 * ops.len() as u64);
        } else {
            // bulk write-back payloads open striped data connections
            // (§3.3), still a single request/reply exchange
            let stripes = transfer::stripes_for(payload, &self.cfg.stripe);
            self.wan.transfer(&self.clock, payload, stripes, TransferKind::NewConnections);
        }
        self.metrics.add(names::WAN_BYTES_TX, payload);
        self.metrics.incr(names::COMPOUND_RPCS);
        self.metrics.add(names::COMPOUND_OPS, ops.len() as u64);
        let resp = {
            // server writes the aggregated payload to its disk
            self.server().disk.io(&self.clock, payload);
            let req = Request::Compound {
                ops: ops
                    .iter()
                    .map(|(seq, op)| CompoundOp::Apply { seq: *seq, op: op.clone() })
                    .collect(),
            };
            if matches!(out.action, Some(FaultAction::Duplicate)) {
                let _ = self.server().handle(self.client_id, req.clone(), self.clock.now());
            }
            self.server().handle(self.client_id, req, self.clock.now())
        };
        if matches!(out.action, Some(FaultAction::DropReply)) {
            // the WHOLE batch applied; the reply frame is lost. The
            // client restores the batch and replays it — per-op seqs
            // make that safe.
            return Err(FsError::Disconnected);
        }
        match resp {
            Response::CompoundReply { replies } => Ok(replies),
            Response::Err { code: 111, .. } => Err(FsError::Disconnected),
            Response::Err { code: 112, .. } => Err(self.wrong_endpoint()),
            r => Err(FsError::Protocol(format!("unexpected compound reply {r:?}"))),
        }
    }

    fn drain_notifications(&mut self) -> Vec<NotifyEvent> {
        let events = self.channel.drain();
        if events.is_empty() || self.faults.is_none() {
            return events;
        }
        // callback delivery is a WAN interaction too: pushes can be
        // lost, duplicated, or die with a partition
        let out = self.fault_step();
        if out.partitioned {
            // in-flight events are lost with the channel (the reconnect
            // revalidation covers them)
            return Vec::new();
        }
        match out.action {
            Some(FaultAction::DropRequest) | Some(FaultAction::DropReply) => {
                // a push cannot vanish from a healthy TCP channel: losing
                // it means the connection reset. Severing here is what
                // keeps the AFS-2 guarantee sound — the client sees a
                // generation bump on reconnect and revalidates everything
                // the lost callbacks covered.
                self.sever();
                Vec::new()
            }
            Some(FaultAction::Duplicate) => {
                // the push frame is delivered twice; invalidation
                // handling must be idempotent
                let mut twice = events.clone();
                twice.extend(events);
                twice
            }
            _ => events,
        }
    }

    fn channel_generation(&self) -> u64 {
        self.channel.generation()
    }

    fn is_connected(&self) -> bool {
        self.net_up && self.session.is_some() && self.channel.is_connected() && self.server().is_up()
    }

    fn reconnect(&mut self) -> Result<u64, FsError> {
        if !self.net_up {
            return Err(FsError::Disconnected);
        }
        self.channel.reconnect();
        self.connect()?;
        Ok(self.channel.generation())
    }

    fn client_id(&self) -> u64 {
        self.client_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{OpenFlags, Vfs};
    use crate::simnet::VirtualTime;

    fn world_with_home() -> SimWorld {
        let mut cfg = XufsConfig::default();
        cfg.cache.localized_dirs = vec!["/home/u/localout".into()];
        let w = SimWorld::new(cfg);
        w.home(|s| {
            let now = VirtualTime::ZERO;
            s.home_mut().mkdir_p("/home/u/proj", now).unwrap();
            s.home_mut().write("/home/u/proj/main.c", b"int main() { return 0; }\n", now).unwrap();
            s.home_mut().write("/home/u/proj/README", b"docs\n", now).unwrap();
            s.home_mut().write("/home/u/data.bin", &vec![0xAAu8; 300_000], now).unwrap();
        });
        w
    }

    #[test]
    fn mount_read_roundtrip() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        let data = {
            let fd = c.open("/home/u/proj/main.c", OpenFlags::rdonly()).unwrap();
            let mut buf = vec![0u8; 1024];
            let n = c.read(fd, &mut buf).unwrap();
            c.close(fd).unwrap();
            buf.truncate(n);
            buf
        };
        assert_eq!(data, b"int main() { return 0; }\n");
        assert_eq!(c.metrics().counter(names::CACHE_MISSES), 1);
        // second open is a cache hit and much faster
        let t0 = c.now();
        let n = c.scan_file("/home/u/proj/main.c", 1024).unwrap();
        assert_eq!(n, 25);
        assert_eq!(c.metrics().counter(names::CACHE_HITS), 1);
        let dt = c.now().saturating_sub(t0).as_secs();
        assert!(dt < 0.1, "cached read should not touch the WAN ({dt}s)");
    }

    #[test]
    fn write_flushes_to_home_on_close() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        c.write_file("/home/u/proj/new.txt", b"created at site", 4096).unwrap();
        let home = w.home(|s| s.home().read("/home/u/proj/new.txt").unwrap().to_vec());
        assert_eq!(home, b"created at site");
        assert_eq!(c.queue_len(), 0, "sync-on-close drains the queue");
    }

    #[test]
    fn big_fetch_takes_striped_wan_time() {
        let mut w = world_with_home();
        w.home(|s| {
            s.home_mut().write("/home/u/big.dat", &vec![7u8; 100 << 20], VirtualTime::ZERO).unwrap()
        });
        let mut c = w.mount("/home/u").unwrap();
        let t0 = c.now();
        let n = c.scan_file("/home/u/big.dat", 1 << 20).unwrap();
        assert_eq!(n, 100 << 20);
        let dt = c.now().saturating_sub(t0).as_secs();
        // 100 MiB over 12 x 2 MiB/s ~ 4.3s + overheads; local would be ~0.3s
        assert!(dt > 3.5 && dt < 8.0, "dt={dt}");
        // warm scan afterwards is local
        let t1 = c.now();
        c.scan_file("/home/u/big.dat", 1 << 20).unwrap();
        let dt2 = c.now().saturating_sub(t1).as_secs();
        assert!(dt2 < 0.5, "dt2={dt2}");
    }

    #[test]
    fn cross_client_invalidation() {
        let mut w = world_with_home();
        let mut a = w.mount("/home/u").unwrap();
        let mut b = w.mount("/home/u").unwrap();
        // both cache the file
        a.scan_file("/home/u/proj/README", 1024).unwrap();
        b.scan_file("/home/u/proj/README", 1024).unwrap();
        // a updates it; b must see the new content on next open
        a.write_file("/home/u/proj/README", b"updated docs\n", 1024).unwrap();
        let mut buf = Vec::new();
        let mut chunk = [0u8; 64];
        let fd = b.open("/home/u/proj/README", OpenFlags::rdonly()).unwrap();
        loop {
            let n = b.read(fd, &mut chunk).unwrap();
            if n == 0 {
                break;
            }
            buf.extend_from_slice(&chunk[..n]);
        }
        b.close(fd).unwrap();
        assert_eq!(buf, b"updated docs\n");
    }

    #[test]
    fn local_home_edit_invalidates_site_cache() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        c.scan_file("/home/u/proj/README", 1024).unwrap();
        w.home(|s| s.local_write("/home/u/proj/README", b"edited on laptop\n", VirtualTime::from_secs(5.0)).unwrap());
        let fd = c.open("/home/u/proj/README", OpenFlags::rdonly()).unwrap();
        let mut buf = [0u8; 64];
        let n = c.read(fd, &mut buf).unwrap();
        c.close(fd).unwrap();
        assert_eq!(&buf[..n], b"edited on laptop\n");
    }

    #[test]
    fn disconnected_reads_cached_write_queues() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        c.scan_file("/home/u/proj/main.c", 1024).unwrap();
        c.link_mut().set_network(false);
        // cached file still readable during the outage
        let n = c.scan_file("/home/u/proj/main.c", 1024).unwrap();
        assert_eq!(n, 25);
        // uncached file is unreachable
        assert!(matches!(
            c.open("/home/u/data.bin", OpenFlags::rdonly()),
            Err(FsError::Disconnected)
        ));
        // writes succeed locally and queue
        c.write_file("/home/u/proj/offline.txt", b"queued", 1024).unwrap();
        assert!(c.queue_len() > 0);
        let missing = w.home(|s| s.home().exists("/home/u/proj/offline.txt"));
        assert!(!missing, "not at home yet");
        // reconnect: queue drains, file lands at home
        c.link_mut().set_network(true);
        c.link_mut().reconnect().unwrap();
        c.fsync().unwrap();
        assert_eq!(c.queue_len(), 0);
        assert!(w.home(|s| s.home().exists("/home/u/proj/offline.txt")));
    }

    #[test]
    fn localized_dir_files_never_reach_home() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        c.chdir("/home/u/localout").unwrap();
        c.write_file("/home/u/localout/raw_output.dat", &[1u8; 100_000], 4096).unwrap();
        let n = c.scan_file("/home/u/localout/raw_output.dat", 4096).unwrap();
        assert_eq!(n, 100_000);
        assert!(!w.home(|s| s.home().exists("/home/u/localout/raw_output.dat")));
        assert_eq!(c.queue_len(), 0);
    }

    #[test]
    fn stat_served_from_attr_cache_without_wan() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        c.readdir("/home/u/proj").unwrap();
        let rpcs_before = w.wan.stats().rpcs;
        let a = c.stat("/home/u/proj/main.c").unwrap();
        assert_eq!(a.size, 25);
        assert_eq!(w.wan.stats().rpcs, rpcs_before, "stat must be WAN-free");
        // negative lookups from a complete listing are also local
        assert!(matches!(c.stat("/home/u/proj/nope"), Err(FsError::NotFound(_))));
        assert_eq!(w.wan.stats().rpcs, rpcs_before);
    }

    #[test]
    fn op_latency_histogram_sees_sub_second_wan_ops() {
        // regression for the zeroed-histogram bug: over a ~50 ms-RTT
        // link an open costs a fractional second, and an integer-second
        // latency reading records every such op as 0.0 — the histogram
        // must land them in nonzero sub-second buckets instead
        let mut cfg = XufsConfig::default();
        cfg.wan.rtt_s = 0.05;
        let mut w = SimWorld::new(cfg);
        w.home(|s| {
            s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
            s.home_mut().write("/home/u/f.dat", &vec![3u8; 200_000], VirtualTime::ZERO).unwrap();
        });
        let mut c = w.mount("/home/u").unwrap();
        c.scan_file("/home/u/f.dat", 4096).unwrap();
        let m = c.metrics().clone();
        assert!(m.histogram_count(names::OP_LATENCY) >= 2, "open + close both observe");
        let mean = m.histogram_mean(names::OP_LATENCY).unwrap();
        let p50 = m.histogram_quantile(names::OP_LATENCY, 0.5).unwrap();
        let p99 = m.histogram_quantile(names::OP_LATENCY, 0.99).unwrap();
        assert!(mean > 0.0 && mean < 1.0, "mean={mean}");
        assert!(p50 > 0.0 && p50 < 1.0, "p50={p50}");
        assert!(p99 > 0.0 && p99 < 1.0, "p99={p99}");
    }

    #[test]
    fn pipelined_readahead_is_byte_identical_and_hits() {
        // the speculative window is a pure latency optimization: a
        // paged scan must return the same bytes with it on or off, and
        // on a steady sequential scan most faults should be hits
        let payload: Vec<u8> = (0..2_000_000u32).map(|i| (i * 31 % 251) as u8).collect();
        let scan = |pipeline: bool| {
            let mut cfg = XufsConfig::default();
            // no readahead: every 64 KiB pread is its own demand fault,
            // so the sequential scan exercises the hint/hit machinery
            cfg.cache.readahead_blocks = 0;
            cfg.transfer.pipeline = pipeline;
            cfg.transfer.pipeline_window = 2;
            let mut w = SimWorld::new(cfg);
            w.home(|s| {
                s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
                s.home_mut().write("/home/u/seq.dat", &payload, VirtualTime::ZERO).unwrap();
            });
            let mut c = w.mount("/home/u").unwrap();
            let fd = c.open("/home/u/seq.dat", OpenFlags::rdonly()).unwrap();
            let mut got = Vec::new();
            let mut buf = vec![0u8; 64 << 10];
            let mut off = 0u64;
            loop {
                let n = c.pread(fd, &mut buf, off).unwrap();
                if n == 0 {
                    break;
                }
                got.extend_from_slice(&buf[..n]);
                off += n as u64;
            }
            c.close(fd).unwrap();
            let hits = c.metrics().counter(names::PIPELINED_HITS);
            (got, hits)
        };
        let (plain, plain_hits) = scan(false);
        let (piped, piped_hits) = scan(true);
        assert_eq!(plain, payload);
        assert_eq!(piped, payload, "pipelined scan must be byte-identical");
        assert_eq!(plain_hits, 0);
        assert!(piped_hits > 0, "sequential scan should consume its hints");
    }

    #[test]
    fn auto_stripes_adapts_and_stays_correct() {
        // stripes = auto only changes modeled transfer time, never the
        // bytes: a large scan stays correct while the tuner makes at
        // least one adjustment away from its 1-stripe starting point
        let mut cfg = XufsConfig::default();
        cfg.transfer.stripes = StripesMode::Auto;
        let mut w = SimWorld::new(cfg);
        w.home(|s| {
            s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
            s.home_mut().write("/home/u/big.dat", &vec![9u8; 20 << 20], VirtualTime::ZERO).unwrap();
        });
        let mut c = w.mount("/home/u").unwrap();
        let n = c.scan_file("/home/u/big.dat", 1 << 20).unwrap();
        assert_eq!(n, 20 << 20);
        assert!(
            c.metrics().counter(names::STRIPE_ADJUSTMENTS) > 0,
            "the tuner should move off its initial stripe count"
        );
    }

    #[test]
    fn prefetch_pulls_small_files_on_chdir() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        c.chdir("/home/u/proj").unwrap();
        assert_eq!(c.metrics().counter(names::PREFETCH_FILES), 2);
        // opening them is now WAN-free
        let rpcs = w.wan.stats().rpcs;
        c.scan_file("/home/u/proj/main.c", 1024).unwrap();
        c.scan_file("/home/u/proj/README", 1024).unwrap();
        assert_eq!(w.wan.stats().rpcs, rpcs);
        assert_eq!(c.metrics().counter(names::CACHE_MISSES), 0);
    }

    #[test]
    fn server_crash_and_restart_recovers_consistency() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        c.scan_file("/home/u/proj/main.c", 1024).unwrap();
        w.server_crash();
        // cached read still works (disconnected operation)
        assert_eq!(c.scan_file("/home/u/proj/main.c", 1024).unwrap(), 25);
        w.server_restart();
        c.link_mut().reconnect().unwrap();
        // after reconnect the client revalidates and keeps working
        assert_eq!(c.scan_file("/home/u/proj/main.c", 1024).unwrap(), 25);
        c.write_file("/home/u/proj/after.txt", b"ok", 64).unwrap();
        assert!(w.home(|s| s.home().exists("/home/u/proj/after.txt")));
    }

    #[test]
    fn interleaved_multi_client_steps_on_the_sharded_core() {
        let mut w = world_with_home();
        assert!(w.server.shard_count() > 1, "default config is sharded");
        let mut clients: Vec<_> = (0..4).map(|_| w.mount("/home/u").unwrap()).collect();
        // round-robin interleaving: each client grows its own files while
        // re-reading a shared one — every step dispatches into the
        // sharded core with no global server lock
        for round in 0..6 {
            for (i, c) in clients.iter_mut().enumerate() {
                let path = format!("/home/u/proj/c{i}_{round}.txt");
                c.write_file(&path, format!("r{round} by c{i}").as_bytes(), 1024).unwrap();
                c.scan_file("/home/u/proj/README", 1024).unwrap();
            }
        }
        for c in clients.iter_mut() {
            c.fsync().unwrap();
        }
        // every client's writes landed at home, and every other client
        // converges on them (callback fanout crossed shard boundaries)
        for i in 0..4 {
            for round in 0..6 {
                let path = format!("/home/u/proj/c{i}_{round}.txt");
                let want = format!("r{round} by c{i}").into_bytes();
                let home = w.home(|s| s.home().read(&path).map(|d| d.to_vec()));
                assert_eq!(home.as_deref(), Ok(&want[..]), "{path} at home");
                for (j, c) in clients.iter_mut().enumerate() {
                    let n = c.scan_file(&path, 1024).unwrap();
                    assert_eq!(n as usize, want.len(), "client {j} reads {path}");
                }
            }
        }
    }

    #[test]
    fn replica_ships_and_failover_serves_clients() {
        let mut w = world_with_home();
        w.enable_replica();
        let mut c = w.mount("/home/u").unwrap();
        assert_eq!(c.link().active_endpoint(), 0);
        // writes land at the primary and ship to the standby
        c.write_file("/home/u/proj/repl.txt", b"replicated content", 1024).unwrap();
        assert_eq!(w.replica_tick(true), 0, "forced tick drains the log");
        let sec = w.secondary().unwrap();
        assert_eq!(sec.home().read("/home/u/proj/repl.txt").unwrap(), b"replicated content");
        // the standby refuses clients while the primary serves
        assert!(!w.is_promoted());
        // primary crashes; the operator promotes (drain + Promote + fence)
        w.server_crash();
        w.promote_secondary().unwrap();
        assert!(w.is_promoted());
        // the client's next reconnect rotates to the promoted secondary
        assert!(!c.link().is_connected(), "crashed primary leaves the session dead");
        c.link_mut().reconnect().unwrap();
        assert_eq!(c.link().active_endpoint(), 1);
        assert!(w.metrics.counter(names::REPLICA_FAILOVERS) >= 1);
        assert_eq!(c.scan_file("/home/u/proj/repl.txt", 1024).unwrap(), 18);
        // and writes keep working against the new primary
        c.write_file("/home/u/proj/after-failover.txt", b"post", 64).unwrap();
        assert_eq!(
            w.authority().home().read("/home/u/proj/after-failover.txt").unwrap(),
            b"post"
        );
        // the fenced old primary refuses even after its crontab restart
        w.server_restart();
        let r = w.server.handle(
            c.link().client_id(),
            Request::Stat { path: "/home/u/proj/repl.txt".into() },
            w.clock.now(),
        );
        assert!(matches!(r, Response::Err { code: 112, .. }), "{r:?}");
    }

    #[test]
    fn replica_tick_respects_lag_threshold() {
        let mut w = world_with_home();
        w.cfg.replica.max_lag_ops = 100; // far above anything this test queues
        w.enable_replica();
        let mut c = w.mount("/home/u").unwrap();
        c.write_file("/home/u/proj/lagged.txt", b"lagging", 1024).unwrap();
        let lag = w.replica_tick(false);
        assert!(lag >= 1, "below the threshold nothing ships (lag {lag})");
        let sec = w.secondary().unwrap();
        assert!(!sec.home().exists("/home/u/proj/lagged.txt"));
        // I4 shape: the un-shipped write is invisible at the standby —
        // it never serves state ahead of its watermark
        assert_eq!(sec.repl_ship_seq(), 0);
        assert_eq!(w.replica_tick(true), 0);
        assert!(sec.home().exists("/home/u/proj/lagged.txt"));
    }

    #[test]
    fn client_crash_recovery_replays_queue() {
        let mut w = world_with_home();
        let mut c = w.mount("/home/u").unwrap();
        c.writeback = crate::client::WritebackMode::Async;
        c.write_file("/home/u/proj/wip.txt", b"work in progress", 1024).unwrap();
        assert!(c.queue_len() > 0, "async mode leaves ops queued");
        assert!(!w.home(|s| s.home().exists("/home/u/proj/wip.txt")));
        // crash the client; cache space (parallel FS) survives
        let surviving_store = c.cache_store_snapshot();
        let client_id = c.link().client_id();
        drop(c);

        let mut w2 = w; // same world/server
        let (c2, corrupt) = w2.mount_recovered("/home/u", &surviving_store, client_id).unwrap();
        assert_eq!(corrupt, 0);
        assert_eq!(c2.queue_len(), 0, "recovery replays the persisted queue");
        let home = w2.home(|s| s.home().read("/home/u/proj/wip.txt").unwrap().to_vec());
        assert_eq!(home, b"work in progress");
    }
}
