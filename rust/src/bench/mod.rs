//! Experiment drivers: one function per paper table/figure, shared by the
//! `rust/benches/*` binaries and the CLI's `xufs bench` subcommand. Every
//! driver returns [`report::Table`]s whose rows mirror what the paper
//! plots, with the paper's own numbers attached as notes for side-by-side
//! comparison (EXPERIMENTS.md records both).

pub mod dedup;
pub mod failover;
pub mod figures;
pub mod read_fanout;
pub mod report;
pub mod scale;
pub mod transport;

pub use dedup::run_dedup;
pub use failover::run_failover;
pub use read_fanout::run_read_fanout;
pub use transport::run_transport;
pub use figures::{
    run_ablation_compound, run_ablation_consistency, run_ablation_delta, run_ablation_paging,
    run_ablation_prefetch, run_ablation_stripes, run_ablation_writeback, run_fig2_fig3, run_fig4,
    run_fig5_table2, run_table1,
};
pub use report::Table;
pub use scale::{
    run_conn_point, run_conn_scale, run_scale, run_scale_point, ConnPoint, ScalePoint,
};
