//! Multi-client scale harness (DESIGN.md §2.6): N real OS threads of
//! mixed workload (buildtree-style metadata + small writes, iozone-style
//! rewrites, largefile-style range fetches) hammer one shared
//! [`FileServer`] in wall-clock time, for the sharded core and the
//! `shards = 1` single-lock ablation.
//!
//! What makes the comparison honest on any machine: the server's modeled
//! home-disk service times are slept for REAL
//! ([`FileServer::set_modeled_disk_waits`]) — metadata service and write
//! payloads under the request's shard lock (exactly the serialization
//! the old global-Mutex server imposed on every client, and a real disk
//! imposes per subtree), fetch payloads outside any shard lock. The
//! sharded core overlaps the per-shard waits of different clients; the
//! ablation cannot. Aggregate ops/s and p99 request latency per
//! (clients, shards) point land in `BENCH_scale.json` (regenerate:
//! `cargo bench --bench scale`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::callback::NotifyChannel;
use crate::config::XufsConfig;
use crate::homefs::FileStore;
use crate::metrics::{names, Metrics};
use crate::proto::{MetaOp, Request, Response};
use crate::runtime::DigestEngine;
use crate::server::FileServer;
use crate::simnet::VirtualTime;
use crate::util::Rng;
use crate::vdisk::DiskModel;

use super::report::Table;

/// Subtrees pre-populated per client (every point sees the same tree).
const MAX_CLIENTS: usize = 16;
/// Small files per client subtree.
const SMALL_FILES: u64 = 16;
/// Small-file payload (buildtree-class).
const SMALL_BYTES: usize = 2 * 1024;
/// Per-client large file (largefile-class range fetches).
const BIG_BYTES: u64 = 2 << 20;
/// Range-fetch window (two 64 KiB blocks, iozone record scale).
const RANGE_BYTES: u64 = 128 * 1024;
/// Modeled home-disk per-op service time for the harness, seconds. Small
/// enough that a full sweep stays in seconds, large enough to dominate
/// lock overhead on any machine.
const OP_SERVICE_S: f64 = 1e-3;

/// One measured point: `clients` threads against a `shards`-way server.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub clients: usize,
    pub shards: usize,
    pub ops: u64,
    pub ops_per_s: f64,
    pub p99_ms: f64,
}

fn build_server(cfg: &XufsConfig, shards: usize) -> Arc<FileServer> {
    let now = VirtualTime::ZERO;
    let mut fs = FileStore::default();
    let mut rng = Rng::new(cfg.seed ^ 0x5CA1_E000);
    let mut small = vec![0u8; SMALL_BYTES];
    rng.fill_bytes(&mut small);
    let mut big = vec![0u8; BIG_BYTES as usize];
    rng.fill_bytes(&mut big);
    for c in 0..MAX_CLIENTS {
        fs.mkdir_p(&format!("/bench/c{c}/src"), now).unwrap();
        fs.mkdir_p(&format!("/bench/c{c}/data"), now).unwrap();
        for j in 0..SMALL_FILES {
            fs.write(&format!("/bench/c{c}/src/f{j}"), &small, now).unwrap();
        }
        fs.write(&format!("/bench/c{c}/data/big.bin"), &big, now).unwrap();
    }
    let metrics = Metrics::new();
    let server = FileServer::new(
        fs,
        DiskModel::new(cfg.disk.home_bps, OP_SERVICE_S),
        Arc::new(DigestEngine::native(metrics.clone())),
        cfg.stripe.min_block as usize,
        cfg.lease.duration_s,
        shards,
        metrics,
        cfg.chunkstore.clone(),
    );
    server.set_modeled_disk_waits(true);
    Arc::new(server)
}

/// One client thread's loop: mixed ops against its own subtree until the
/// deadline, recording per-request wall latency.
fn client_loop(
    server: Arc<FileServer>,
    c: usize,
    seed: u64,
    deadline: Instant,
) -> (u64, Vec<f64>) {
    let client_id = c as u64 + 1;
    let channel = NotifyChannel::new();
    server.attach_channel(client_id, channel.clone());
    server.handle(
        client_id,
        Request::RegisterCallback { root: "/bench".into(), client_id },
        VirtualTime::ZERO,
    );
    let big = format!("/bench/c{c}/data/big.bin");
    let big_version = match server.handle(
        client_id,
        Request::FetchMeta { path: big.clone() },
        VirtualTime::ZERO,
    ) {
        Response::FileMeta { version, .. } => version,
        r => panic!("bench setup: {r:?}"),
    };
    let mut rng = Rng::new(seed ^ (client_id << 32));
    let mut payload = vec![0u8; SMALL_BYTES];
    rng.fill_bytes(&mut payload);
    let mut seq = 0u64;
    let mut lat = Vec::with_capacity(4096);
    let mut ops = 0u64;
    while Instant::now() < deadline {
        let pick = rng.below(100);
        let req = if pick < 35 {
            Request::Stat { path: format!("/bench/c{c}/src/f{}", rng.below(SMALL_FILES)) }
        } else if pick < 45 {
            Request::ReadDir { path: format!("/bench/c{c}/src") }
        } else if pick < 70 {
            let max_off = (BIG_BYTES - RANGE_BYTES) / RANGE_BYTES;
            Request::FetchRange {
                path: big.clone(),
                offset: rng.below(max_off + 1) * RANGE_BYTES,
                len: RANGE_BYTES,
                expect_version: big_version,
            }
        } else if pick < 95 {
            seq += 1;
            // fresh content each time (first byte varies) — an
            // iozone-style rewrite of a buildtree-sized file
            payload[0] = seq as u8;
            Request::Apply {
                seq,
                op: MetaOp::WriteFull {
                    path: format!("/bench/c{c}/src/f{}", rng.below(SMALL_FILES)),
                    data: payload.clone(),
                    digests: vec![],
                    base_version: 0,
                },
            }
        } else {
            seq += 1;
            Request::Apply {
                seq,
                op: MetaOp::SetMode {
                    path: format!("/bench/c{c}/src/f{}", rng.below(SMALL_FILES)),
                    mode: 0o640,
                },
            }
        };
        let t0 = Instant::now();
        let resp = server.handle(client_id, req, VirtualTime::ZERO);
        lat.push(t0.elapsed().as_secs_f64());
        ops += 1;
        // a hard assert (benches build with release): an erroring op must
        // fail the harness, not count toward the acceptance throughput
        assert!(!matches!(&resp, Response::Err { .. }), "bench op failed: {resp:?}");
        // keep the callback queue drained (writes fan out to the other
        // registered clients, as in a real deployment)
        channel.drain();
    }
    (ops, lat)
}

/// Run one (clients, shards) point for `window` seconds of wall time.
pub fn run_scale_point(cfg: &XufsConfig, clients: usize, shards: usize, window: f64) -> ScalePoint {
    let server = build_server(cfg, shards);
    let deadline = Instant::now() + Duration::from_secs_f64(window);
    let started = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients.min(MAX_CLIENTS) {
        let server = server.clone();
        let seed = cfg.seed ^ 0xBE4C;
        handles.push(std::thread::spawn(move || client_loop(server, c, seed, deadline)));
    }
    let mut ops = 0u64;
    let mut lat: Vec<f64> = Vec::new();
    for h in handles {
        let (n, l) = h.join().expect("client thread panicked");
        ops += n;
        lat.extend(l);
    }
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = if lat.is_empty() {
        0.0
    } else {
        lat[((lat.len() - 1) as f64 * 0.99) as usize] * 1e3
    };
    ScalePoint { clients, shards, ops, ops_per_s: ops as f64 / elapsed, p99_ms: p99 }
}

/// The 8-client sharded-vs-ablation speedup a healthy core must clear
/// (the PR's acceptance criterion; `benches/scale.rs` enforces it).
pub const ACCEPT_SPEEDUP_AT_8: f64 = 3.0;

/// The 8-client speedup recorded in a [`run_scale`] table (the last
/// cell of the sharded row at 8 clients). `None` if the table has no
/// 8-client rows.
pub fn speedup_at_8(t: &Table) -> Option<f64> {
    t.rows
        .iter()
        .find(|r| r[0] == "8" && r[1] != "1")
        .and_then(|r| r.last())
        .and_then(|s| s.parse().ok())
}

/// The full sweep: 1/2/4/8/16 clients against the sharded server and the
/// `shards = 1` ablation. The `speedup` column is the sharded row's
/// aggregate ops/s over the same-client-count ablation row.
pub fn run_scale(cfg: &XufsConfig, window: f64) -> Table {
    let sharded = cfg.server.shards.max(2);
    let mut t = Table::new(
        &format!("Scale — {sharded}-shard server vs shards=1 ablation (mixed workload)"),
        &["clients", "shards", "agg ops/s", "p99 ms", "ops", "speedup"],
    );
    let mut at8: (f64, f64) = (0.0, 0.0); // (ablation, sharded) ops/s at 8 clients
    for &clients in &[1usize, 2, 4, 8, 16] {
        let base = run_scale_point(cfg, clients, 1, window);
        let shrd = run_scale_point(cfg, clients, sharded, window);
        if clients == 8 {
            at8 = (base.ops_per_s, shrd.ops_per_s);
        }
        for (p, speedup) in [(&base, 1.0), (&shrd, shrd.ops_per_s / base.ops_per_s.max(1e-9))] {
            t.row(vec![
                p.clients.to_string(),
                p.shards.to_string(),
                format!("{:.0}", p.ops_per_s),
                format!("{:.2}", p.p99_ms),
                p.ops.to_string(),
                format!("{speedup:.2}"),
            ]);
        }
    }
    t.note(format!(
        "8 clients: {:.0} ops/s sharded vs {:.0} ops/s single-lock — {:.1}x (acceptance: >= 3x)",
        at8.1,
        at8.0,
        at8.1 / at8.0.max(1e-9)
    ));
    t.note(format!(
        "modeled home-disk service slept for real: {OP_SERVICE_S}s/op + write payloads under \
         the shard lock, fetch payloads outside locks (DESIGN.md §2.6); blocking counted in `{}`",
        names::SHARD_CONTENTION
    ));
    t
}
