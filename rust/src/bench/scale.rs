//! Multi-client scale harness (DESIGN.md §2.6): N real OS threads of
//! mixed workload (buildtree-style metadata + small writes, iozone-style
//! rewrites, largefile-style range fetches) hammer one shared
//! [`FileServer`] in wall-clock time, for the sharded core and the
//! `shards = 1` single-lock ablation.
//!
//! What makes the comparison honest on any machine: the server's modeled
//! home-disk service times are slept for REAL
//! ([`FileServer::set_modeled_disk_waits`]) — metadata service and write
//! payloads under the request's shard lock (exactly the serialization
//! the old global-Mutex server imposed on every client, and a real disk
//! imposes per subtree), fetch payloads outside any shard lock. The
//! sharded core overlaps the per-shard waits of different clients; the
//! ablation cannot. Aggregate ops/s and p99 request latency per
//! (clients, shards) point land in `BENCH_scale.json` (regenerate:
//! `cargo bench --bench scale`).

use std::collections::VecDeque;
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::auth::{Authenticator, KeyPair};
use crate::callback::NotifyChannel;
use crate::config::XufsConfig;
use crate::coordinator::net::{dial, TcpServer};
use crate::homefs::FileStore;
use crate::metrics::{names, Metrics};
use crate::proto::{self, FrameDecoder, FrameWriter, MetaOp, Request, Response};
use crate::runtime::DigestEngine;
use crate::server::FileServer;
use crate::simnet::VirtualTime;
use crate::util::Rng;
use crate::vdisk::DiskModel;

use super::report::Table;

/// Subtrees pre-populated per client (every point sees the same tree).
const MAX_CLIENTS: usize = 16;
/// Small files per client subtree.
const SMALL_FILES: u64 = 16;
/// Small-file payload (buildtree-class).
const SMALL_BYTES: usize = 2 * 1024;
/// Per-client large file (largefile-class range fetches).
const BIG_BYTES: u64 = 2 << 20;
/// Range-fetch window (two 64 KiB blocks, iozone record scale).
const RANGE_BYTES: u64 = 128 * 1024;
/// Modeled home-disk per-op service time for the harness, seconds. Small
/// enough that a full sweep stays in seconds, large enough to dominate
/// lock overhead on any machine.
const OP_SERVICE_S: f64 = 1e-3;

/// One measured point: `clients` threads against a `shards`-way server.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub clients: usize,
    pub shards: usize,
    pub ops: u64,
    pub ops_per_s: f64,
    pub p99_ms: f64,
}

fn build_server(cfg: &XufsConfig, shards: usize) -> Arc<FileServer> {
    let now = VirtualTime::ZERO;
    let mut fs = FileStore::default();
    let mut rng = Rng::new(cfg.seed ^ 0x5CA1_E000);
    let mut small = vec![0u8; SMALL_BYTES];
    rng.fill_bytes(&mut small);
    let mut big = vec![0u8; BIG_BYTES as usize];
    rng.fill_bytes(&mut big);
    for c in 0..MAX_CLIENTS {
        fs.mkdir_p(&format!("/bench/c{c}/src"), now).unwrap();
        fs.mkdir_p(&format!("/bench/c{c}/data"), now).unwrap();
        for j in 0..SMALL_FILES {
            fs.write(&format!("/bench/c{c}/src/f{j}"), &small, now).unwrap();
        }
        fs.write(&format!("/bench/c{c}/data/big.bin"), &big, now).unwrap();
    }
    let metrics = Metrics::new();
    let server = FileServer::new(
        fs,
        DiskModel::new(cfg.disk.home_bps, OP_SERVICE_S),
        Arc::new(DigestEngine::native(metrics.clone())),
        cfg.stripe.min_block as usize,
        cfg.lease.duration_s,
        shards,
        metrics,
        cfg.chunkstore.clone(),
    );
    server.set_modeled_disk_waits(true);
    Arc::new(server)
}

/// One client thread's loop: mixed ops against its own subtree until the
/// deadline, recording per-request wall latency.
fn client_loop(
    server: Arc<FileServer>,
    c: usize,
    seed: u64,
    deadline: Instant,
) -> (u64, Vec<f64>) {
    let client_id = c as u64 + 1;
    let channel = NotifyChannel::new();
    server.attach_channel(client_id, channel.clone());
    server.handle(
        client_id,
        Request::RegisterCallback { root: "/bench".into(), client_id },
        VirtualTime::ZERO,
    );
    let big = format!("/bench/c{c}/data/big.bin");
    let big_version = match server.handle(
        client_id,
        Request::FetchMeta { path: big.clone(), min_version: 0 },
        VirtualTime::ZERO,
    ) {
        Response::FileMeta { version, .. } => version,
        r => panic!("bench setup: {r:?}"),
    };
    let mut rng = Rng::new(seed ^ (client_id << 32));
    let mut payload = vec![0u8; SMALL_BYTES];
    rng.fill_bytes(&mut payload);
    let mut seq = 0u64;
    let mut lat = Vec::with_capacity(4096);
    let mut ops = 0u64;
    while Instant::now() < deadline {
        let pick = rng.below(100);
        let req = if pick < 35 {
            Request::Stat { path: format!("/bench/c{c}/src/f{}", rng.below(SMALL_FILES)) }
        } else if pick < 45 {
            Request::ReadDir { path: format!("/bench/c{c}/src") }
        } else if pick < 70 {
            let max_off = (BIG_BYTES - RANGE_BYTES) / RANGE_BYTES;
            Request::FetchRange {
                path: big.clone(),
                offset: rng.below(max_off + 1) * RANGE_BYTES,
                len: RANGE_BYTES,
                expect_version: big_version,
            }
        } else if pick < 95 {
            seq += 1;
            // fresh content each time (first byte varies) — an
            // iozone-style rewrite of a buildtree-sized file
            payload[0] = seq as u8;
            Request::Apply {
                seq,
                op: MetaOp::WriteFull {
                    path: format!("/bench/c{c}/src/f{}", rng.below(SMALL_FILES)),
                    data: payload.clone(),
                    digests: vec![],
                    base_version: 0,
                },
            }
        } else {
            seq += 1;
            Request::Apply {
                seq,
                op: MetaOp::SetMode {
                    path: format!("/bench/c{c}/src/f{}", rng.below(SMALL_FILES)),
                    mode: 0o640,
                },
            }
        };
        let t0 = Instant::now();
        let resp = server.handle(client_id, req, VirtualTime::ZERO);
        lat.push(t0.elapsed().as_secs_f64());
        ops += 1;
        // a hard assert (benches build with release): an erroring op must
        // fail the harness, not count toward the acceptance throughput
        assert!(!matches!(&resp, Response::Err { .. }), "bench op failed: {resp:?}");
        // keep the callback queue drained (writes fan out to the other
        // registered clients, as in a real deployment)
        channel.drain();
    }
    (ops, lat)
}

/// Run one (clients, shards) point for `window` seconds of wall time.
pub fn run_scale_point(cfg: &XufsConfig, clients: usize, shards: usize, window: f64) -> ScalePoint {
    let server = build_server(cfg, shards);
    let deadline = Instant::now() + Duration::from_secs_f64(window);
    let started = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients.min(MAX_CLIENTS) {
        let server = server.clone();
        let seed = cfg.seed ^ 0xBE4C;
        handles.push(std::thread::spawn(move || client_loop(server, c, seed, deadline)));
    }
    let mut ops = 0u64;
    let mut lat: Vec<f64> = Vec::new();
    for h in handles {
        let (n, l) = h.join().expect("client thread panicked");
        ops += n;
        lat.extend(l);
    }
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99 = if lat.is_empty() {
        0.0
    } else {
        lat[((lat.len() - 1) as f64 * 0.99) as usize] * 1e3
    };
    ScalePoint { clients, shards, ops, ops_per_s: ops as f64 / elapsed, p99_ms: p99 }
}

/// The 8-client sharded-vs-ablation speedup a healthy core must clear
/// (the PR's acceptance criterion; `benches/scale.rs` enforces it).
pub const ACCEPT_SPEEDUP_AT_8: f64 = 3.0;

/// The 8-client speedup recorded in a [`run_scale`] table (the last
/// cell of the sharded row at 8 clients). `None` if the table has no
/// 8-client rows.
pub fn speedup_at_8(t: &Table) -> Option<f64> {
    t.rows
        .iter()
        .find(|r| r[0] == "8" && r[1] != "1")
        .and_then(|r| r.last())
        .and_then(|s| s.parse().ok())
}

/// The full sweep: 1/2/4/8/16 clients against the sharded server and the
/// `shards = 1` ablation. The `speedup` column is the sharded row's
/// aggregate ops/s over the same-client-count ablation row.
pub fn run_scale(cfg: &XufsConfig, window: f64) -> Table {
    let sharded = cfg.server.shards.max(2);
    let mut t = Table::new(
        &format!("Scale — {sharded}-shard server vs shards=1 ablation (mixed workload)"),
        &["clients", "shards", "agg ops/s", "p99 ms", "ops", "speedup"],
    );
    let mut at8: (f64, f64) = (0.0, 0.0); // (ablation, sharded) ops/s at 8 clients
    for &clients in &[1usize, 2, 4, 8, 16] {
        let base = run_scale_point(cfg, clients, 1, window);
        let shrd = run_scale_point(cfg, clients, sharded, window);
        if clients == 8 {
            at8 = (base.ops_per_s, shrd.ops_per_s);
        }
        for (p, speedup) in [(&base, 1.0), (&shrd, shrd.ops_per_s / base.ops_per_s.max(1e-9))] {
            t.row(vec![
                p.clients.to_string(),
                p.shards.to_string(),
                format!("{:.0}", p.ops_per_s),
                format!("{:.2}", p.p99_ms),
                p.ops.to_string(),
                format!("{speedup:.2}"),
            ]);
        }
    }
    t.note(format!(
        "8 clients: {:.0} ops/s sharded vs {:.0} ops/s single-lock — {:.1}x (acceptance: >= 3x)",
        at8.1,
        at8.0,
        at8.1 / at8.0.max(1e-9)
    ));
    t.note(format!(
        "modeled home-disk service slept for real: {OP_SERVICE_S}s/op + write payloads under \
         the shard lock, fetch payloads outside locks (DESIGN.md §2.6); blocking counted in `{}`",
        names::SHARD_CONTENTION
    ));
    t
}

// ---------------------------------------------------------------------------
// Connection-scale harness (DESIGN.md §2.9): N real TCP connections, each a
// nonblocking pipelined client, against the reactor core (the sole serving
// core since the thread-per-connection path was removed). Unlike the dispatch
// harness above, modeled disk waits are OFF — the point is the serving core
// (accept path, poll loop, per-connection buffers, wakeup latency), not the
// disk model.
// ---------------------------------------------------------------------------

/// Requests each simulated connection keeps in flight.
const CONN_PIPELINE: usize = 8;
/// Shared read-mostly files the connections hammer.
const CONN_FILES: u64 = 64;
/// Range-fetch block for the connection workload (metadata-class frames
/// dominate; this keeps payload frames small enough that the harness
/// measures per-frame costs, not memcpy bandwidth).
const CONN_BLOCK: usize = 4096;
/// Blocks per shared file.
const CONN_FILE_BLOCKS: u64 = 16;
/// Driver threads multiplexing the simulated connections. The drivers are
/// nonblocking event loops themselves, so a handful of OS threads can
/// honestly represent 1024 independent sockets on the client side.
const DRIVER_THREADS: usize = 4;

/// One measured point: `clients` live TCP connections against one core.
#[derive(Debug, Clone)]
pub struct ConnPoint {
    pub clients: usize,
    pub ops: u64,
    pub ops_per_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

fn build_conn_server(cfg: &XufsConfig) -> (Arc<FileServer>, Metrics) {
    let now = VirtualTime::ZERO;
    let mut fs = FileStore::default();
    let mut rng = Rng::new(cfg.seed ^ 0xC0_11EC7);
    let mut block = vec![0u8; CONN_FILE_BLOCKS as usize * CONN_BLOCK];
    rng.fill_bytes(&mut block);
    fs.mkdir_p("/conn", now).unwrap();
    for j in 0..CONN_FILES {
        fs.write(&format!("/conn/f{j}"), &block, now).unwrap();
    }
    let metrics = Metrics::new();
    let server = FileServer::new(
        fs,
        DiskModel::new(cfg.disk.home_bps, 0.0),
        Arc::new(DigestEngine::native(metrics.clone())),
        CONN_BLOCK,
        cfg.lease.duration_s,
        cfg.server.shards.max(2),
        metrics.clone(),
        cfg.chunkstore.clone(),
    );
    // no modeled sleeps: saturate the serving core, not the disk model
    server.set_modeled_disk_waits(false);
    (Arc::new(server), metrics)
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() - 1) as f64 * q) as usize] * 1e3
}

/// One nonblocking simulated connection owned by a driver thread.
struct SimConn {
    stream: std::net::TcpStream,
    dec: FrameDecoder,
    out: FrameWriter,
    inflight: VecDeque<Instant>,
}

/// A driver's event loop over its slice of connections: keep every pipeline
/// topped up, flush what the sockets will take, decode what arrives.
#[allow(clippy::too_many_arguments)]
fn conn_driver(
    addr: std::net::SocketAddr,
    pair: KeyPair,
    versions: Arc<Vec<u64>>,
    conns: usize,
    seed: u64,
    setup: Arc<Barrier>,
    start: Arc<Barrier>,
    window: f64,
) -> (u64, Vec<f64>) {
    // handshakes are blocking (USSH needs request/response lockstep), then
    // the socket goes nonblocking for the measured window
    let mut clients: Vec<SimConn> = (0..conns)
        .map(|_| {
            let stream = dial(addr, &pair).expect("conn bench dial");
            stream.set_nonblocking(true).expect("set_nonblocking");
            SimConn {
                stream,
                dec: FrameDecoder::new(proto::MAX_FRAME),
                out: FrameWriter::new(),
                inflight: VecDeque::new(),
            }
        })
        .collect();
    let mut rng = Rng::new(seed);
    setup.wait(); // every connection is authenticated before anyone measures
    start.wait();
    let deadline = Instant::now() + Duration::from_secs_f64(window);
    let mut ops = 0u64;
    let mut lat: Vec<f64> = Vec::with_capacity(8192);
    loop {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let mut progress = false;
        for c in clients.iter_mut() {
            while c.inflight.len() < CONN_PIPELINE {
                let j = rng.below(CONN_FILES);
                let req = if rng.below(100) < 70 {
                    Request::Stat { path: format!("/conn/f{j}") }
                } else {
                    Request::FetchRange {
                        path: format!("/conn/f{j}"),
                        offset: rng.below(CONN_FILE_BLOCKS) * CONN_BLOCK as u64,
                        len: CONN_BLOCK as u64,
                        expect_version: versions[j as usize],
                    }
                };
                c.out.frame(|e| req.encode_into(e));
                c.inflight.push_back(Instant::now());
                progress = true;
            }
            c.out.flush_to(&mut c.stream).expect("conn bench write");
            loop {
                match c.dec.read_from(&mut c.stream) {
                    Ok(0) => panic!("server closed a bench connection"),
                    Ok(_) => progress = true,
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => panic!("conn bench read: {e}"),
                }
            }
            while let Some(frame) = c.dec.next_frame().expect("conn bench decode") {
                let resp = Response::decode(frame).expect("conn bench response");
                assert!(!matches!(&resp, Response::Err { .. }), "bench op failed: {resp:?}");
                let t0 = c.inflight.pop_front().expect("response without a request");
                lat.push(t0.elapsed().as_secs_f64());
                ops += 1;
                progress = true;
            }
        }
        if !progress {
            std::thread::yield_now();
        }
    }
    (ops, lat)
}

/// Run one connection-count point: `clients` authenticated TCP connections
/// pipelining a Stat-heavy workload for `window` seconds against the
/// reactor core.
pub fn run_conn_point(cfg: &XufsConfig, clients: usize, window: f64) -> ConnPoint {
    let (server, metrics) = build_conn_server(cfg);
    let mut rng = Rng::new(cfg.seed ^ 0xD1A1);
    let pair = KeyPair::generate(&mut rng, VirtualTime::ZERO, 3600.0);
    let auth = Arc::new(Mutex::new(Authenticator::new(pair.clone(), cfg.seed)));
    let mut scfg = cfg.server.clone();
    // admission must never bite in the bench: the point is throughput at
    // N live connections, not the busy path
    scfg.max_connections = clients + 16;
    let tcp = TcpServer::spawn_with(server.clone(), auth, metrics, &scfg)
        .expect("conn bench server spawn");
    let versions: Arc<Vec<u64>> = Arc::new(
        (0..CONN_FILES)
            .map(|j| match server.handle(
                u64::MAX,
                Request::FetchMeta { path: format!("/conn/f{j}"), min_version: 0 },
                VirtualTime::ZERO,
            ) {
                Response::FileMeta { version, .. } => version,
                r => panic!("conn bench setup: {r:?}"),
            })
            .collect(),
    );
    let setup = Arc::new(Barrier::new(DRIVER_THREADS));
    let start = Arc::new(Barrier::new(DRIVER_THREADS));
    let mut handles = Vec::with_capacity(DRIVER_THREADS);
    for d in 0..DRIVER_THREADS {
        let conns = clients / DRIVER_THREADS + usize::from(d < clients % DRIVER_THREADS);
        let addr = tcp.addr;
        let pair = pair.clone();
        let versions = versions.clone();
        let seed = cfg.seed ^ 0xC0_4BE4C ^ ((d as u64) << 40);
        let setup = setup.clone();
        let start = start.clone();
        handles.push(std::thread::spawn(move || {
            conn_driver(addr, pair, versions, conns, seed, setup, start, window)
        }));
    }
    let mut ops = 0u64;
    let mut lat: Vec<f64> = Vec::new();
    for h in handles {
        let (n, l) = h.join().expect("conn driver panicked");
        ops += n;
        lat.extend(l);
    }
    drop(tcp); // joins the serving threads before the next point binds
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ConnPoint {
        clients,
        ops,
        // the drivers start their windows together (barrier) and stop on
        // the same deadline, so the window IS the measurement interval —
        // handshake setup time stays out of the denominator
        ops_per_s: ops as f64 / window.max(1e-9),
        p50_ms: pct(&lat, 0.50),
        p99_ms: pct(&lat, 0.99),
    }
}

/// Flat-scaling floor the reactor must clear at 256 connections: aggregate
/// ops/s at 256 live connections must stay at or above this fraction of the
/// 16-connection point (the PR's acceptance criterion; `benches/scale.rs`
/// enforces it when the sweep includes both points). With the
/// thread-per-connection ablation removed, the bar is absolute scaling —
/// throughput must not collapse as connections multiply.
pub const ACCEPT_CONN_FLAT_AT_256: f64 = 0.5;

/// The aggregate ops/s recorded in a [`run_conn_scale`] table at `clients`
/// connections. `None` if the sweep skipped that point.
pub fn conn_ops_at(t: &Table, clients: usize) -> Option<f64> {
    let want = clients.to_string();
    t.rows.iter().find(|r| r[0] == want).and_then(|r| r.get(1)).and_then(|s| s.parse().ok())
}

/// The p99 latency (ms) recorded in a [`run_conn_scale`] table at `clients`
/// connections.
pub fn conn_p99_at(t: &Table, clients: usize) -> Option<f64> {
    let want = clients.to_string();
    t.rows.iter().find(|r| r[0] == want).and_then(|r| r.get(3)).and_then(|s| s.parse().ok())
}

/// Which connection counts to sweep: `CONN_CLIENTS=16,256` overrides (CI
/// runners cap fds near 1024, so the nightly smoke pins a short list); the
/// default saturation sweep runs to 1024 live connections.
fn conn_counts() -> Vec<usize> {
    match std::env::var("CONN_CLIENTS") {
        Ok(s) => s
            .split(',')
            .filter_map(|p| p.trim().parse().ok())
            .filter(|&c| c > 0)
            .collect(),
        Err(_) => vec![16, 64, 256, 512, 1024],
    }
}

/// The connection-scale sweep: each count against the reactor core.
pub fn run_conn_scale(cfg: &XufsConfig, window: f64) -> Table {
    let mut t = Table::new(
        "Connection scale — reactor core",
        &["clients", "agg ops/s", "p50 ms", "p99 ms", "ops"],
    );
    for clients in conn_counts() {
        let p = run_conn_point(cfg, clients, window);
        t.row(vec![
            p.clients.to_string(),
            format!("{:.0}", p.ops_per_s),
            format!("{:.2}", p.p50_ms),
            format!("{:.2}", p.p99_ms),
            p.ops.to_string(),
        ]);
    }
    t.note(format!(
        "{CONN_PIPELINE} pipelined requests/conn (70% Stat, 30% {CONN_BLOCK}-byte FetchRange), \
         {DRIVER_THREADS} nonblocking driver threads multiplexing the client side; \
         modeled disk waits OFF — this measures the serving core (DESIGN.md §2.9)"
    ));
    t.note(
        "full sweep needs ~2 fds per connection: raise `ulimit -n` past 4096 before the \
         1024-client point; CI smoke pins CONN_CLIENTS=16,256"
            .to_string(),
    );
    t
}
