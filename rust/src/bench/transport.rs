//! WAN transport v2 bench (DESIGN.md §2.12): a sequential paged scan of
//! an 8 MiB file with per-chunk application compute, swept across four
//! heterogeneous WAN profiles (fat / thin / lossy / asymmetric) under
//! {static, adaptive} striping x {fault-on-miss, pipelined} readahead.
//! Every transfer is charged to the virtual clock, so the table
//! reproduces bit-identically on any machine. `BENCH_transport.json` at
//! the repo root records it (regenerate: `cargo bench --bench transport`).
//!
//! The table also reports the `vfs.op_latency` p50/p99 each run
//! observed — the histogram whose integer-second readings once recorded
//! every sub-second op as 0.0 and hid the transport's latency profile
//! entirely (the bug this bench is the regression surface for).

use crate::client::{OpenFlags, Vfs};
use crate::config::{StripesMode, XufsConfig};
use crate::coordinator::SimWorld;
use crate::metrics::names;
use crate::simnet::{wan_profile, VirtualTime, WAN_PROFILES};

use super::report::{rate, secs, Table};

/// Bytes scanned per run.
const FILE_BYTES: u64 = 8 << 20;
/// Application read size — with readahead disabled, also the steady
/// fault-extent size, so every chunk is one demand fault.
const CHUNK: u64 = 256 << 10;
/// Per-chunk application compute. Comparable to a chunk's transfer time
/// on the hard profiles — the regime pipelined readahead exists for.
const THINK_S: f64 = 0.05;

/// One run's results.
pub struct TransportPoint {
    pub profile: String,
    pub adaptive: bool,
    pub pipeline: bool,
    pub elapsed_s: f64,
    pub goodput_mib_s: f64,
    pub pipelined_hits: u64,
    pub stripe_adjustments: u64,
    pub op_p50_s: f64,
    pub op_p99_s: f64,
}

/// Scan the file once under one transport configuration.
fn run_point(base: &XufsConfig, profile: &str, adaptive: bool, pipeline: bool) -> TransportPoint {
    let mut cfg = base.clone();
    cfg.wan = wan_profile(profile).expect("known WAN profile");
    // one fault per chunk: the bench measures the transport, not the
    // readahead window heuristics
    cfg.cache.readahead_blocks = 0;
    cfg.transfer.stripes = if adaptive { StripesMode::Auto } else { StripesMode::Planned };
    cfg.transfer.pipeline = pipeline;
    cfg.transfer.pipeline_window = 2;
    let mut world = SimWorld::new(cfg);
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
        let body: Vec<u8> = (0..FILE_BYTES).map(|i| (i * 131 % 251) as u8).collect();
        s.home_mut().write("/home/u/scan.dat", &body, VirtualTime::ZERO).unwrap();
    });
    let mut c = world.mount("/home/u").unwrap();
    let t0 = c.now();
    let fd = c.open("/home/u/scan.dat", OpenFlags::rdonly()).unwrap();
    let mut buf = vec![0u8; CHUNK as usize];
    let mut off = 0u64;
    while off < FILE_BYTES {
        let n = c.pread(fd, &mut buf, off).expect("bench read");
        assert!(n > 0, "scan must make progress");
        off += n as u64;
        // the application computes on the chunk it just read — the
        // window the pipelined transfer overlaps with
        c.think(THINK_S);
    }
    c.close(fd).unwrap();
    let elapsed = c.now().saturating_sub(t0).as_secs().max(1e-9);
    let m = c.metrics().clone();
    TransportPoint {
        profile: profile.to_string(),
        adaptive,
        pipeline,
        elapsed_s: elapsed,
        goodput_mib_s: FILE_BYTES as f64 / (1024.0 * 1024.0) / elapsed,
        pipelined_hits: m.counter(names::PIPELINED_HITS),
        stripe_adjustments: m.counter(names::STRIPE_ADJUSTMENTS),
        op_p50_s: m.histogram_quantile(names::OP_LATENCY, 0.5).unwrap_or(0.0),
        op_p99_s: m.histogram_quantile(names::OP_LATENCY, 0.99).unwrap_or(0.0),
    }
}

/// The adaptive+pipelined speedup over the static fault-on-miss
/// baseline for `profile`, parsed back out of the table.
pub fn speedup(t: &Table, profile: &str) -> Option<f64> {
    let row = t
        .rows
        .iter()
        .find(|r| r[0] == profile && r[1] == "auto" && r[2] == "on")?;
    row.get(5)?.strip_suffix('x')?.parse::<f64>().ok()
}

/// Largest op-latency p99 across the table's rows (the regression
/// surface for the zeroed-histogram bug: it must be nonzero and
/// sub-second for these WAN-bound workloads).
pub fn worst_op_p99(t: &Table) -> Option<f64> {
    t.rows.iter().filter_map(|r| r.get(8)?.parse::<f64>().ok()).fold(None, |acc, v| {
        Some(acc.map_or(v, |a: f64| a.max(v)))
    })
}

/// The transport matrix (`cargo bench --bench transport`).
pub fn run_transport(cfg: &XufsConfig) -> Table {
    let mut t = Table::new(
        "WAN transport v2 — adaptive striping + pipelined readahead vs the static \
         fault-on-miss baseline, four WAN profiles (DESIGN.md §2.12)",
        &[
            "profile",
            "stripes",
            "pipeline",
            "elapsed s",
            "goodput MiB/s",
            "speedup",
            "hits",
            "op p50 s",
            "op p99 s",
        ],
    );
    for profile in WAN_PROFILES {
        let mut baseline = 0.0f64;
        for (adaptive, pipeline) in [(false, false), (false, true), (true, false), (true, true)] {
            let p = run_point(cfg, profile, adaptive, pipeline);
            if !adaptive && !pipeline {
                baseline = p.elapsed_s;
            }
            t.row(vec![
                p.profile.clone(),
                if p.adaptive { "auto".into() } else { "static".into() },
                if p.pipeline { "on".into() } else { "off".into() },
                secs(p.elapsed_s),
                rate(p.goodput_mib_s),
                format!("{:.2}x", baseline / p.elapsed_s.max(1e-9)),
                p.pipelined_hits.to_string(),
                format!("{:.6}", p.op_p50_s),
                format!("{:.6}", p.op_p99_s),
            ]);
        }
    }
    t.note(format!(
        "{} MiB sequential paged scan, {} KiB chunks, {} ms compute per chunk; speedup is \
         vs the same profile's static fault-on-miss row",
        FILE_BYTES >> 20,
        CHUNK >> 10,
        (THINK_S * 1e3) as u64,
    ));
    t.note(
        "acceptance: adaptive+pipelined >= 1.3x static fault-on-miss on the lossy AND \
         asymmetric profiles, with nonzero sub-second op-latency p50/p99 \
         (benches/transport.rs enforces)"
            .to_string(),
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The nightly smoke in miniature: the two hard profiles must clear
    /// the 1.3x acceptance bar, and the op-latency histogram — the one
    /// the integer-second truncation bug silently zeroed — must show
    /// nonzero sub-second quantiles.
    #[test]
    fn adaptive_pipelined_clears_the_acceptance_bar() {
        let t = run_transport(&XufsConfig::default());
        for profile in ["lossy", "asymmetric"] {
            let s = speedup(&t, profile).expect("adaptive+pipelined row");
            assert!(
                s >= 1.3,
                "{profile}: adaptive+pipelined must reach 1.3x static fault-on-miss, got {s:.2}x"
            );
        }
        let p99 = worst_op_p99(&t).expect("op-latency column");
        assert!(p99 > 0.0 && p99 < 1.0, "op latency must be nonzero sub-second, p99={p99}");
    }
}
