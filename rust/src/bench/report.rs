//! Bench report formatting: fixed-width tables on stdout plus a JSON
//! sidecar line per table (machine-readable, picked up by EXPERIMENTS.md
//! tooling).

use crate::util::Json;

/// A printable results table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes printed under the table (paper reference values,
    /// shape checks).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render to stdout.
    pub fn print(&self) {
        let w = self.widths();
        let line = |sep: &str| {
            w.iter().map(|n| "-".repeat(n + 2)).collect::<Vec<_>>().join(sep)
        };
        println!("\n== {} ==", self.title);
        println!("+{}+", line("+"));
        let fmt_row = |cells: &[String]| {
            let body = cells
                .iter()
                .zip(&w)
                .map(|(c, n)| format!(" {c:>width$} ", width = n))
                .collect::<Vec<_>>()
                .join("|");
            format!("|{body}|")
        };
        println!("{}", fmt_row(&self.headers));
        println!("+{}+", line("+"));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!("+{}+", line("+"));
        for n in &self.notes {
            println!("  note: {n}");
        }
        println!("  json: {}", self.to_json());
    }

    /// Machine-readable form.
    pub fn to_json(&self) -> Json {
        let headers: Vec<Json> = self.headers.iter().map(|h| Json::Str(h.clone())).collect();
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
            .collect();
        Json::obj()
            .set("title", self.title.clone())
            .set("headers", Json::Arr(headers))
            .set("rows", Json::Arr(rows))
    }
}

/// Format seconds with bench-appropriate precision.
pub fn secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.1}")
    } else {
        format!("{s:.3}")
    }
}

/// Format a MiB/s rate.
pub fn rate(r: f64) -> String {
    if r >= 100.0 {
        format!("{r:.0}")
    } else {
        format!("{r:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.note("hello");
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("Demo"));
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 1);
        t.print(); // shouldn't panic
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatting() {
        assert_eq!(secs(123.4), "123");
        assert_eq!(secs(12.34), "12.3");
        assert_eq!(secs(0.1234), "0.123");
        assert_eq!(rate(123.4), "123");
        assert_eq!(rate(12.34), "12.3");
    }
}
