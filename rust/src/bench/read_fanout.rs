//! Read-fanout bench (DESIGN.md §2.11): aggregate cold-read throughput
//! for 3 WAN sites against 0/1/2/3 SERVING secondaries versus the
//! primary alone, over heterogeneous RTTs (8 ms to a site's local
//! replica, 48 ms cross-site, 96 ms to the far primary). Every read is
//! charged to the virtual clock over the site's own WAN path, so the
//! table reproduces bit-identically on any machine.
//! `BENCH_fanout.json` at the repo root records it (regenerate:
//! `cargo bench --bench read_fanout`).

use crate::client::Vfs;
use crate::config::XufsConfig;
use crate::coordinator::SimWorld;
use crate::simnet::VirtualTime;

use super::report::{rate, Table};

/// WAN sites issuing reads (one client per site).
const SITES: usize = 3;
/// Cold files read per site per run.
const FILES_PER_SITE: usize = 40;
/// Bytes per file — small enough that round trips dominate, the regime
/// read fan-out exists for.
const FILE_BYTES: usize = 16 * 1024;
/// RTT from a site to its OWN replica (same metro).
const RTT_LOCAL_S: f64 = 0.008;
/// RTT from a site to another site's replica.
const RTT_CROSS_S: f64 = 0.048;
/// RTT from every site to the far primary.
const RTT_PRIMARY_S: f64 = 0.096;

/// One throughput row.
pub struct FanoutPoint {
    pub label: String,
    /// Secondaries admitted to serve reads (0 = primary-only baseline).
    pub serving: usize,
    pub ops_per_s: f64,
    pub speedup: f64,
}

/// Aggregate cold-read ops/s with `serving` read replicas (0 disables
/// fan-out entirely: the paper's primary-bound reads).
fn run_point(base: &XufsConfig, serving: usize) -> f64 {
    let mut cfg = base.clone();
    cfg.wan.rtt_s = RTT_PRIMARY_S;
    cfg.replica.secondaries = serving.max(1);
    cfg.replica.read_fanout = serving > 0;
    cfg.replica.staleness_ops = 64;
    let mut world = SimWorld::new(cfg);
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u/data", VirtualTime::ZERO).unwrap();
        for site in 0..SITES {
            for k in 0..FILES_PER_SITE {
                let body = vec![(site * 31 + k) as u8; FILE_BYTES];
                s.home_mut()
                    .write(&format!("/home/u/data/s{site}_{k}"), &body, VirtualTime::ZERO)
                    .unwrap();
            }
        }
    });
    // secondaries come up from the snapshot: fully caught up, serving
    world.enable_replica();
    let mut clients = Vec::new();
    for site in 0..SITES {
        let rtts: Vec<f64> = (0..serving.max(1))
            .map(|j| if j == site { RTT_LOCAL_S } else { RTT_CROSS_S })
            .collect();
        clients.push(world.mount_at("/home/u", &rtts).unwrap());
    }
    let t0 = clients[0].now();
    for k in 0..FILES_PER_SITE {
        for site in 0..SITES {
            clients[site]
                .scan_file(&format!("/home/u/data/s{site}_{k}"), FILE_BYTES)
                .expect("bench read");
        }
    }
    let elapsed = clients[0].now().saturating_sub(t0).as_secs();
    (SITES * FILES_PER_SITE) as f64 / elapsed.max(1e-9)
}

/// The per-row speedups over the primary-only baseline, in row order
/// (baseline first, so its entry is 1.0).
pub fn speedups(t: &Table) -> Option<Vec<f64>> {
    t.rows.iter().map(|r| r.last()?.strip_suffix('x')?.parse::<f64>().ok()).collect()
}

/// The read-scaling table (`cargo bench --bench read_fanout`).
pub fn run_read_fanout(cfg: &XufsConfig) -> Table {
    let mut t = Table::new(
        "Read fan-out — aggregate cold-read throughput, 3 WAN sites vs serving secondaries \
         (bounded-staleness reads, DESIGN.md §2.11)",
        &["serving replicas", "read ops/s", "speedup"],
    );
    let base = run_point(cfg, 0);
    let mut points = Vec::new();
    for serving in 0..=SITES {
        let ops = run_point(cfg, serving);
        points.push(FanoutPoint {
            label: if serving == 0 { "primary-only".into() } else { format!("{serving}") },
            serving,
            ops_per_s: ops,
            speedup: ops / base.max(1e-9),
        });
    }
    for p in &points {
        t.row(vec![p.label.clone(), rate(p.ops_per_s), format!("{:.2}x", p.speedup)]);
    }
    t.note(format!(
        "{SITES} sites x {FILES_PER_SITE} cold {}-KiB reads; RTTs: {:.0} ms site-local replica, \
         {:.0} ms cross-site, {:.0} ms primary — each site's link picks its lowest-RTT serving \
         replica, lagging replicas answer code 119 and fall back",
        FILE_BYTES / 1024,
        RTT_LOCAL_S * 1e3,
        RTT_CROSS_S * 1e3,
        RTT_PRIMARY_S * 1e3,
    ));
    t.note(
        "acceptance: >= 1.8x aggregate read throughput at 3 serving replicas \
         (benches/read_fanout.rs enforces)"
            .to_string(),
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The nightly smoke in miniature: one deterministic run, read
    /// throughput must scale with serving replicas and clear the 1.8x
    /// acceptance bar at 3.
    #[test]
    fn fanout_scales_reads_past_acceptance_bar() {
        let t = run_read_fanout(&XufsConfig::default());
        let s = speedups(&t).expect("parse speedups");
        assert_eq!(s.len(), SITES + 1);
        assert!((s[0] - 1.0).abs() < 0.05, "baseline row is 1.0x, got {}", s[0]);
        for w in s.windows(2) {
            assert!(w[1] >= w[0] * 0.95, "throughput must not regress as replicas join: {s:?}");
        }
        assert!(s[1] > 1.2, "one serving replica already beats primary-only: {s:?}");
        assert!(s[SITES] >= 1.8, "3 serving replicas must clear 1.8x, got {}", s[SITES]);
    }
}
