//! Cross-user dedup bench (DESIGN.md §2.8): N users each carry a private
//! copy of the same software stack in their home dir — the paper's
//! wide-area pattern of every site replicating the common toolchain —
//! plus genuinely unique job output. The content-addressed chunk store
//! must collapse the shared copies to ONE physical instance, so logical
//! bytes divided by stored bytes lands well above 1. Deterministic
//! virtual-clock model: a single iteration IS the run, and
//! `cargo bench --bench dedup` regenerates `BENCH_dedup.json` and
//! enforces the acceptance ratio (> 1.5x).

use std::sync::Arc;

use crate::config::XufsConfig;
use crate::homefs::FileStore;
use crate::metrics::Metrics;
use crate::runtime::DigestEngine;
use crate::server::FileServer;
use crate::simnet::VirtualTime;
use crate::util::Rng;
use crate::vdisk::DiskModel;

use super::report::Table;

/// Users, each with a full private copy of the shared stack.
const USERS: usize = 3;
/// Shared software-stack files every user's home dir holds.
const SHARED_FILES: usize = 8;
/// Bytes per shared stack file (4 chunks at the default 64 KiB).
const SHARED_BYTES: usize = 256 * 1024;
/// Unique job-output files per user (no dedup possible).
const UNIQUE_FILES: usize = 4;
/// Bytes per unique file (2 chunks at the default 64 KiB).
const UNIQUE_BYTES: usize = 128 * 1024;

/// Run the dedup experiment and report logical vs physical bytes.
pub fn run_dedup(cfg: &XufsConfig) -> Table {
    let now = VirtualTime::ZERO;
    let mut fs = FileStore::default();
    for u in 0..USERS {
        fs.mkdir_p(&format!("/home/u{u}/stack"), now).unwrap();
        fs.mkdir_p(&format!("/home/u{u}/data"), now).unwrap();
    }
    let metrics = Metrics::new();
    let server = FileServer::new(
        fs,
        DiskModel::new(cfg.disk.home_bps, cfg.disk.home_op_s),
        Arc::new(DigestEngine::native(metrics.clone())),
        cfg.stripe.min_block as usize,
        cfg.lease.duration_s,
        cfg.server.shards,
        metrics,
        cfg.chunkstore.clone(),
    );
    let mut rng = Rng::new(cfg.seed ^ 0xDED0_C0DE);
    // the stack is generated once; every user writes the same bytes
    let shared: Vec<Vec<u8>> = (0..SHARED_FILES)
        .map(|_| {
            let mut d = vec![0u8; SHARED_BYTES];
            rng.fill_bytes(&mut d);
            d
        })
        .collect();
    let mut logical = 0u64;
    for u in 0..USERS {
        for (i, blob) in shared.iter().enumerate() {
            server.local_write(&format!("/home/u{u}/stack/lib{i}.so"), blob, now).unwrap();
            logical += blob.len() as u64;
        }
        for i in 0..UNIQUE_FILES {
            let mut d = vec![0u8; UNIQUE_BYTES];
            rng.fill_bytes(&mut d);
            server.local_write(&format!("/home/u{u}/data/run{i}.out"), &d, now).unwrap();
            logical += d.len() as u64;
        }
    }
    let g = server.home();
    let cs = g.chunkstore().expect("the dedup bench needs [chunkstore] enabled");
    let stored = cs.stored_bytes();
    let hits = cs.dedup_hits();
    let saved = cs.dedup_bytes_saved();
    let ratio = logical as f64 / stored.max(1) as f64;
    let mib = |b: u64| format!("{:.2}", b as f64 / (1024.0 * 1024.0));
    let mut t = Table::new(
        "Cross-user dedup — shared software stacks under the content-addressed chunk store",
        &["users", "logical MiB", "stored MiB", "dedup ratio", "dedup hits", "MiB saved"],
    );
    t.row(vec![
        USERS.to_string(),
        mib(logical),
        mib(stored),
        format!("{ratio:.2}"),
        hits.to_string(),
        mib(saved),
    ]);
    t.note(format!(
        "per user: {SHARED_FILES} shared stack files x {} KiB + {UNIQUE_FILES} unique x {} KiB; \
         chunk size {} KiB",
        SHARED_BYTES / 1024,
        UNIQUE_BYTES / 1024,
        cfg.chunkstore.chunk_kib
    ));
    t.note("acceptance: dedup ratio > 1.5x (enforced by `cargo bench --bench dedup`)");
    t
}

/// The dedup ratio from a finished table (bench acceptance gate).
pub fn ratio(t: &Table) -> Option<f64> {
    let col = t.headers.iter().position(|h| h == "dedup ratio")?;
    t.rows.first()?.get(col)?.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_collapses_shared_stacks() {
        let t = run_dedup(&XufsConfig::default());
        let r = ratio(&t).expect("ratio column");
        // 7.5 MiB logical over 3.5 MiB physical
        assert!(r > 2.0 && r < 2.3, "expected ~2.14x, got {r}");
    }

    #[test]
    fn dedup_disabled_store_stays_dense() {
        let mut cfg = XufsConfig::default();
        cfg.chunkstore.enabled = false;
        // the run should refuse loudly rather than silently report 1.0x
        let res = std::panic::catch_unwind(|| run_dedup(&cfg));
        assert!(res.is_err(), "dense store must not produce a dedup table");
    }
}
