//! One driver per paper table/figure (DESIGN.md §4 experiment index).

use std::sync::Arc;

use crate::baselines::{GpfsWan, GpfsWanParams, LocalFs, NfsClient, Scp, Tgcp};
use crate::bench::report::{rate, secs, Table};
use crate::client::{Vfs, WritebackMode, XufsClient};
use crate::config::XufsConfig;
use crate::coordinator::{SimLink, SimWorld};
use crate::homefs::FileStore;
use crate::metrics::names;
use crate::simnet::{SimClock, VirtualTime, Wan};
use crate::vdisk::DiskModel;
use crate::workload::{buildtree, iozone, largefile, sizedist};

const MIB: u64 = 1 << 20;

fn cache_disk(cfg: &XufsConfig) -> DiskModel {
    DiskModel::new(cfg.disk.cache_bps, cfg.disk.cache_op_s)
}

/// Fresh XUFS deployment with `files` pre-populated at the home space
/// under /home/u.
fn xufs_world(cfg: &XufsConfig, files: &[(String, Vec<u8>)]) -> (SimWorld, XufsClient<SimLink>) {
    let mut w = SimWorld::new(cfg.clone());
    w.home(|s| {
        s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
        for (p, data) in files {
            s.home_mut().mkdir_p(&crate::util::path::parent(p), VirtualTime::ZERO).unwrap();
            s.home_mut().write(p, data, VirtualTime::ZERO).unwrap();
        }
    });
    let c = w.mount("/home/u").expect("mount");
    (w, c)
}

fn gpfs_world(cfg: &XufsConfig, files: &[(String, Vec<u8>)]) -> GpfsWan {
    let clock = Arc::new(SimClock::new());
    let mut fs = FileStore::default();
    fs.mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
    for (p, data) in files {
        fs.mkdir_p(&crate::util::path::parent(p), VirtualTime::ZERO).unwrap();
        fs.write(p, data, VirtualTime::ZERO).unwrap();
    }
    let _ = cfg;
    GpfsWan::new(fs, GpfsWanParams::default(), clock)
}

fn local_world(cfg: &XufsConfig, files: &[(String, Vec<u8>)]) -> LocalFs {
    let clock = Arc::new(SimClock::new());
    let mut fs = FileStore::default();
    fs.mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
    for (p, data) in files {
        fs.mkdir_p(&crate::util::path::parent(p), VirtualTime::ZERO).unwrap();
        fs.write(p, data, VirtualTime::ZERO).unwrap();
    }
    LocalFs::new(fs, cache_disk(cfg), clock)
}

/// Generate the paper's §4.2 source tree and return its files as
/// `(path, contents)` pairs for pre-populating a world's home space
/// (shared by Fig. 4 and every build-workload ablation).
fn build_tree_files(seed: u64, spec: &buildtree::BuildSpec) -> Vec<(String, Vec<u8>)> {
    let mut home = FileStore::default();
    buildtree::generate_tree(&mut home, "/home/u/src", spec, seed).unwrap();
    home.walk("/home/u/src")
        .unwrap()
        .into_iter()
        .filter(|(_, a)| a.kind == crate::homefs::NodeKind::File)
        .map(|(p, _)| {
            let data = home.read(&p).unwrap().to_vec();
            (p, data)
        })
        .collect()
}

// ---------------------------------------------------------------------
// Table 1
// ---------------------------------------------------------------------

/// Regenerate Table 1 from the calibrated population model.
pub fn run_table1(seed: u64) -> Table {
    let sizes = sizedist::generate_sizes(&sizedist::SizeDistParams::default(), seed);
    let c = sizedist::census(&sizes);
    let mut t = Table::new(
        "Table 1 — cumulative file-size distribution (TACC scratch census)",
        &["Size", "Files", "Files% ", "GB", "Bytes%", "paper files", "paper GB"],
    );
    for (row, (_, _, pf, pgb)) in c.rows.iter().zip(sizedist::PAPER_TABLE1.iter()) {
        t.row(vec![
            row.label.clone(),
            row.files.to_string(),
            format!("{:.2}%", row.file_pct),
            format!("{:.1}", row.gigabytes),
            format!("{:.2}%", row.byte_pct),
            pf.to_string(),
            format!("{pgb:.1}"),
        ]);
    }
    t.row(vec![
        "Total".into(),
        c.total_files.to_string(),
        "100%".into(),
        format!("{:.1}", c.total_gb),
        "100%".into(),
        sizedist::PAPER_TOTAL_FILES.to_string(),
        format!("{:.1}", sizedist::PAPER_TOTAL_GB),
    ]);
    let m1 = &c.rows[5];
    t.note(format!(
        "headline skew: >1M files = {:.2}% of files, {:.2}% of bytes (paper: 9%, 98.49%)",
        m1.file_pct, m1.byte_pct
    ));
    t
}

// ---------------------------------------------------------------------
// Figures 2 & 3 — IOzone write/read throughput
// ---------------------------------------------------------------------

/// Sizes from 1 MiB to 1 GiB (the paper's range).
pub fn iozone_sizes(quick: bool) -> Vec<u64> {
    if quick {
        vec![MIB, 4 * MIB, 16 * MIB, 64 * MIB, 256 * MIB]
    } else {
        vec![MIB, 2 * MIB, 4 * MIB, 8 * MIB, 16 * MIB, 32 * MIB, 64 * MIB, 128 * MIB, 256 * MIB, 512 * MIB, 1024 * MIB]
    }
}

/// Figures 2 (write) and 3 (read): throughput incl. close for XUFS,
/// GPFS-WAN and the local parallel FS.
pub fn run_fig2_fig3(cfg: &XufsConfig, quick: bool) -> (Table, Table) {
    let mut wt = Table::new(
        "Figure 2 — IOzone write throughput, close included (MiB/s)",
        &["size", "XUFS", "GPFS-WAN", "local GPFS"],
    );
    let mut rt = Table::new(
        "Figure 3 — IOzone read throughput (MiB/s)",
        &["size", "XUFS", "GPFS-WAN", "local GPFS"],
    );
    for &size in &iozone_sizes(quick) {
        // XUFS: write then read in the mounted name space
        let (_w, mut xc) = xufs_world(cfg, &[]);
        let xw = iozone::write_test(&mut xc, "/home/u/io.dat", size, cfg.seed).unwrap();
        let xr = iozone::read_test(&mut xc, "/home/u/io.dat").unwrap();

        let mut g = gpfs_world(cfg, &[]);
        let gw = iozone::write_test(&mut g, "/home/u/io.dat", size, cfg.seed).unwrap();
        let gr = iozone::read_test(&mut g, "/home/u/io.dat").unwrap();

        let mut l = local_world(cfg, &[]);
        let lw = iozone::write_test(&mut l, "/home/u/io.dat", size, cfg.seed).unwrap();
        let lr = iozone::read_test(&mut l, "/home/u/io.dat").unwrap();

        let label = format!("{} MiB", size / MIB);
        wt.row(vec![label.clone(), rate(xw.mib_per_sec), rate(gw.mib_per_sec), rate(lw.mib_per_sec)]);
        rt.row(vec![label, rate(xr.mib_per_sec), rate(gr.mib_per_sec), rate(lr.mib_per_sec)]);
    }
    wt.note("paper shape: GPFS-WAN ≫ XUFS at 1 MiB (page-pool absorb); comparable above");
    rt.note("paper shape: XUFS wins for >1 MiB — reads come from the local cache FS");
    (wt, rt)
}

// ---------------------------------------------------------------------
// Figure 4 — source build times
// ---------------------------------------------------------------------

/// Figure 4: clean-make times for 5 consecutive runs on each system.
pub fn run_fig4(cfg: &XufsConfig, runs: usize) -> Table {
    let spec = buildtree::BuildSpec::default();
    let tree = build_tree_files(cfg.seed, &spec);

    let mut t = Table::new(
        "Figure 4 — build times over consecutive runs (seconds)",
        &["run", "XUFS", "GPFS-WAN", "local GPFS"],
    );

    let (_w, mut xc) = xufs_world(cfg, &tree);
    let mut g = gpfs_world(cfg, &tree);
    let mut l = local_world(cfg, &tree);
    let mut series = Vec::new();
    for run in 1..=runs {
        let xs = buildtree::build(&mut xc, "/home/u/src", &spec).unwrap();
        buildtree::clean(&mut xc, "/home/u/src").unwrap();
        let gs = buildtree::build(&mut g, "/home/u/src", &spec).unwrap();
        buildtree::clean(&mut g, "/home/u/src").unwrap();
        let ls = buildtree::build(&mut l, "/home/u/src", &spec).unwrap();
        buildtree::clean(&mut l, "/home/u/src").unwrap();
        series.push((xs.secs, gs.secs, ls.secs));
        t.row(vec![run.to_string(), secs(xs.secs), secs(gs.secs), secs(ls.secs)]);
    }
    let wins = series.iter().filter(|(x, g, _)| x < g).count();
    t.note(format!(
        "paper shape: XUFS mostly outperforms GPFS-WAN (aggressive parallel pre-fetch); here XUFS wins {wins}/{runs} runs"
    ));
    t.note("local GPFS is the floor in every run");
    t
}

// ---------------------------------------------------------------------
// Figure 5 + Table 2 — 1 GiB `wc -l`
// ---------------------------------------------------------------------

/// Figure 5: `wc -l` on a 1 GiB file, 5 consecutive runs per system.
/// Table 2: the XUFS access time vs TGCP and SCP copy times.
pub fn run_fig5_table2(cfg: &XufsConfig, runs: usize, gib: u64) -> (Table, Table) {
    let content = largefile::text_content(gib as usize, 80, cfg.seed);
    let files = [("/home/u/big.txt".to_string(), content)];

    let mut fig5 = Table::new(
        "Figure 5 — `wc -l` on a 1 GiB file, consecutive runs (seconds)",
        &["run", "XUFS", "GPFS-WAN", "local GPFS"],
    );

    let (_w, mut xc) = xufs_world(cfg, &files);
    let mut g = gpfs_world(cfg, &files);
    let mut l = local_world(cfg, &files);
    let mut xufs_first = 0.0;
    let mut gpfs_times = Vec::new();
    for run in 1..=runs {
        let (_, xs) = largefile::wc_l(&mut xc, "/home/u/big.txt", MIB as usize).unwrap();
        let (_, gs) = largefile::wc_l(&mut g, "/home/u/big.txt", MIB as usize).unwrap();
        let (_, ls) = largefile::wc_l(&mut l, "/home/u/big.txt", MIB as usize).unwrap();
        if run == 1 {
            xufs_first = xs;
        }
        gpfs_times.push(gs);
        fig5.row(vec![run.to_string(), secs(xs), secs(gs), secs(ls)]);
    }
    fig5.note("paper shape: XUFS ≈60 s first run (cold fetch into cache), then seconds; GPFS-WAN flat ≈33 s");

    // Table 2: copy tools on a fresh WAN
    let clock = Arc::new(SimClock::new());
    let wan = Arc::new(Wan::new(cfg.wan.clone(), (*clock).clone()));
    let tgcp = Tgcp::new(wan.clone(), clock.clone(), cache_disk(cfg), cfg.stripe.clone());
    let tgcp_secs = tgcp.copy(gib);
    let scp = Scp::new(wan, clock, cache_disk(cfg), XufsConfig::scp_cipher_bps());
    let scp_secs = scp.copy(gib);

    let mut t2 = Table::new(
        "Table 2 — mean time to access a 1 GiB file across the WAN (seconds)",
        &["XUFS (wc -l, cold)", "TGCP (copy)", "SCP (copy)", "paper XUFS", "paper TGCP", "paper SCP"],
    );
    t2.row(vec![
        secs(xufs_first),
        secs(tgcp_secs),
        secs(scp_secs),
        "57".into(),
        "49".into(),
        "2100".into(),
    ]);
    t2.note(format!(
        "shape: TGCP slightly ahead of XUFS (ratio {:.2}, paper 0.86); SCP ~{:.0}x slower than XUFS (paper ~37x)",
        tgcp_secs / xufs_first.max(1e-9),
        scp_secs / xufs_first.max(1e-9)
    ));
    let _ = gpfs_times;
    (fig5, t2)
}

// ---------------------------------------------------------------------
// Ablations (design choices from DESIGN.md §3)
// ---------------------------------------------------------------------

/// Stripe-count sweep: cold 1 GiB fetch time vs number of stripes.
pub fn run_ablation_stripes(cfg: &XufsConfig, gib: u64) -> Table {
    let mut t = Table::new(
        "Ablation — stripe count vs cold 1 GiB fetch (seconds)",
        &["stripes", "fetch secs", "speedup vs 1"],
    );
    let content = vec![0x55u8; gib as usize];
    let mut base = 0.0;
    for stripes in [1usize, 2, 4, 8, 12, 16] {
        let mut c2 = cfg.clone();
        c2.stripe.max_stripes = stripes;
        let (_w, mut xc) = xufs_world(&c2, &[("/home/u/big.dat".to_string(), content.clone())]);
        let t0 = xc.now();
        xc.scan_file("/home/u/big.dat", MIB as usize).unwrap();
        let dt = xc.now().saturating_sub(t0).as_secs();
        if stripes == 1 {
            base = dt;
        }
        t.row(vec![stripes.to_string(), secs(dt), format!("{:.1}x", base / dt)]);
    }
    t.note("speedup saturates once per-stream caps stop binding (paper picked 12)");
    t
}

/// Pre-fetch on/off: first-build time + WAN round trips.
pub fn run_ablation_prefetch(cfg: &XufsConfig) -> Table {
    let spec = buildtree::BuildSpec::default();
    let mut t = Table::new(
        "Ablation — parallel small-file pre-fetch (first clean make)",
        &["prefetch", "build secs", "WAN rpcs", "files prefetched"],
    );
    let tree = build_tree_files(cfg.seed, &spec);
    for enabled in [true, false] {
        let mut c2 = cfg.clone();
        c2.stripe.prefetch_enabled = enabled;
        let (w, mut xc) = xufs_world(&c2, &tree);
        let stats = buildtree::build(&mut xc, "/home/u/src", &spec).unwrap();
        t.row(vec![
            enabled.to_string(),
            secs(stats.secs),
            w.wan.stats().rpcs.to_string(),
            xc.metrics().counter(names::PREFETCH_FILES).to_string(),
        ]);
    }
    t.note("the paper credits its Fig. 4 win to this pre-fetch (§4.2)");
    t
}

/// Delta writeback on/off: edit one block of a large cached file, close.
pub fn run_ablation_delta(cfg: &XufsConfig, file_mib: u64) -> Table {
    let mut t = Table::new(
        "Ablation — digest delta writeback (1-block edit of a cached file)",
        &["delta", "close+flush secs", "bytes shipped", "bytes saved"],
    );
    let size = file_mib * MIB;
    for enabled in [true, false] {
        let mut c2 = cfg.clone();
        c2.stripe.delta_writeback = enabled;
        let content = vec![0xA7u8; size as usize];
        let (_w, mut xc) = xufs_world(&c2, &[("/home/u/data.bin".to_string(), content)]);
        // cache it (cold fetch)
        xc.scan_file("/home/u/data.bin", MIB as usize).unwrap();
        // edit a single 64 KiB block in place
        let t0 = xc.now();
        let fd = xc.open("/home/u/data.bin", crate::client::OpenFlags::rdwr()).unwrap();
        xc.seek(fd, 128 * 1024).unwrap();
        xc.write(fd, &vec![0x11u8; 64 * 1024]).unwrap();
        xc.close(fd).unwrap();
        let dt = xc.now().saturating_sub(t0).as_secs();
        t.row(vec![
            enabled.to_string(),
            secs(dt),
            xc.metrics().counter(names::WRITEBACK_BYTES).to_string(),
            xc.metrics().counter(names::WRITEBACK_BYTES_SAVED).to_string(),
        ]);
    }
    t.note("delta plan computed by the AOT digest engine (PJRT artifact when present)");
    t
}

/// Callback consistency vs NFS-style check-on-open: repeated builds.
pub fn run_ablation_consistency(cfg: &XufsConfig, runs: usize) -> Table {
    let spec = buildtree::BuildSpec::default();
    let tree = build_tree_files(cfg.seed, &spec);

    // XUFS (callbacks)
    let (w, mut xc) = xufs_world(cfg, &tree);
    let mut x_total = 0.0;
    for _ in 0..runs {
        let s = buildtree::build(&mut xc, "/home/u/src", &spec).unwrap();
        buildtree::clean(&mut xc, "/home/u/src").unwrap();
        x_total += s.secs;
    }
    let x_rpcs = w.wan.stats().rpcs;

    // NFS-style (check on open) — same tree, regenerated as its remote
    // authoritative store (generation is seed-deterministic)
    let mut home = FileStore::default();
    buildtree::generate_tree(&mut home, "/home/u/src", &spec, cfg.seed).unwrap();
    let clock = Arc::new(SimClock::new());
    let wan = Arc::new(Wan::new(cfg.wan.clone(), (*clock).clone()));
    let mut nfs = NfsClient::new(home, clock, wan.clone(), cache_disk(cfg), cfg.stripe.max_stripes);
    let mut n_total = 0.0;
    for _ in 0..runs {
        let s = buildtree::build(&mut nfs, "/home/u/src", &spec).unwrap();
        buildtree::clean(&mut nfs, "/home/u/src").unwrap();
        n_total += s.secs;
    }

    let mut t = Table::new(
        "Ablation — callback consistency vs NFS check-on-open",
        &["protocol", "total secs", "WAN rpcs", "revalidation rpcs"],
    );
    t.row(vec!["XUFS callbacks".into(), secs(x_total), x_rpcs.to_string(), "0".into()]);
    t.row(vec![
        "check-on-open".into(),
        secs(n_total),
        wan.stats().rpcs.to_string(),
        nfs.revalidation_rpcs.to_string(),
    ]);
    t.note("cached copies are assumed current unless notified (AFS-2 style, paper §5)");
    t
}

/// Compound RPC vs per-op meta-queue flush: identical async build (§4.2)
/// plus the final fsync on each, counting WAN round trips. The per-op
/// mode is the pre-v2 wire behaviour (one `Request::Apply` round trip per
/// queued op); compound ships the whole queue as one `Request::Compound`.
pub fn run_ablation_compound(cfg: &XufsConfig) -> Table {
    let spec = buildtree::BuildSpec::default();
    let mut t = Table::new(
        "Ablation — compound RPC queue flush (async clean make + final fsync)",
        &["flush mode", "build+sync secs", "WAN rpcs", "compound rpcs", "ops batched"],
    );
    let tree = build_tree_files(cfg.seed, &spec);
    for compound in [true, false] {
        let (w, mut xc) = xufs_world(cfg, &tree);
        xc.compound = compound;
        xc.writeback = WritebackMode::Async;
        xc.async_flush_threshold = usize::MAX;
        let t0 = xc.now();
        buildtree::build(&mut xc, "/home/u/src", &spec).unwrap();
        xc.fsync().unwrap();
        let dt = xc.now().saturating_sub(t0).as_secs();
        t.row(vec![
            if compound { "compound".into() } else { "per-op".into() },
            secs(dt),
            w.wan.stats().rpcs.to_string(),
            xc.metrics().counter(names::COMPOUND_RPCS).to_string(),
            xc.metrics().counter(names::COMPOUND_OPS).to_string(),
        ]);
    }
    t.note("compound mode ships the whole meta-op queue in one Request::Compound round trip");
    t
}

/// Demand paging vs whole-file fetch (DESIGN.md §2.4): time-to-first-byte
/// and bytes-over-WAN on the 1 GiB `wc -l` workload, plus an early-exit
/// variant reading only the first 1/16th of the file (`head`-style).
pub fn run_ablation_paging(cfg: &XufsConfig, file_bytes: u64) -> Table {
    let mut t = Table::new(
        "Ablation — demand paging vs whole-file fetch (cold `wc -l`)",
        &[
            "mode",
            "ttfb secs",
            "full scan secs",
            "WAN bytes (full)",
            "early-exit secs",
            "WAN bytes (early)",
        ],
    );
    let content = largefile::text_content(file_bytes as usize, 80, cfg.seed);
    let files = [("/home/u/big.txt".to_string(), content)];
    let early_bytes = file_bytes / 16;
    for paging in [true, false] {
        // cold full scan, timing the first 1 MiB separately (TTFB)
        let (w, mut xc) = xufs_world(cfg, &files);
        xc.paging = paging;
        let base_wan = w.wan.stats().bytes;
        let t0 = xc.now();
        let fd = xc.open("/home/u/big.txt", crate::client::OpenFlags::rdonly()).unwrap();
        let mut buf = vec![0u8; MIB as usize];
        let mut total = xc.read(fd, &mut buf).unwrap() as u64;
        let ttfb = xc.now().saturating_sub(t0).as_secs();
        loop {
            let n = xc.read(fd, &mut buf).unwrap();
            if n == 0 {
                break;
            }
            total += n as u64;
        }
        xc.close(fd).unwrap();
        assert_eq!(total, file_bytes, "scan must read the whole file");
        let full_secs = xc.now().saturating_sub(t0).as_secs();
        let full_wan = w.wan.stats().bytes - base_wan;

        // cold early-exit scan on a fresh world: read 1/16th, stop
        let (w2, mut x2) = xufs_world(cfg, &files);
        x2.paging = paging;
        let base_wan = w2.wan.stats().bytes;
        let t0 = x2.now();
        let fd = x2.open("/home/u/big.txt", crate::client::OpenFlags::rdonly()).unwrap();
        let mut got = 0u64;
        while got < early_bytes {
            let n = x2.read(fd, &mut buf).unwrap();
            if n == 0 {
                break;
            }
            got += n as u64;
        }
        x2.close(fd).unwrap();
        let early_secs = x2.now().saturating_sub(t0).as_secs();
        let early_wan = w2.wan.stats().bytes - base_wan;

        t.row(vec![
            if paging { "paging".into() } else { "whole-file".into() },
            secs(ttfb),
            secs(full_secs),
            full_wan.to_string(),
            secs(early_secs),
            early_wan.to_string(),
        ]);
    }
    t.note("paging faults only the blocks a read touches (+ readahead window); whole-file is §3.1");
    t.note("first byte no longer waits for the whole transfer; early exits stop paying for the tail");
    t
}

/// Sync-on-close vs async queue flushing.
pub fn run_ablation_writeback(cfg: &XufsConfig) -> Table {
    let spec = buildtree::BuildSpec::default();
    let mut t = Table::new(
        "Ablation — writeback mode (clean make incl. final sync)",
        &["mode", "build secs", "final fsync secs"],
    );
    let tree = build_tree_files(cfg.seed, &spec);
    for mode in [WritebackMode::SyncOnClose, WritebackMode::Async] {
        let (_w, mut xc) = xufs_world(cfg, &tree);
        xc.writeback = mode;
        xc.async_flush_threshold = usize::MAX;
        let stats = buildtree::build(&mut xc, "/home/u/src", &spec).unwrap();
        let t0 = xc.now();
        xc.fsync().unwrap();
        let fsync_s = xc.now().saturating_sub(t0).as_secs();
        let label = match mode {
            WritebackMode::SyncOnClose => "sync-on-close",
            WritebackMode::Async => "async queue",
        };
        t.row(vec![label.into(), secs(stats.secs), secs(fsync_s)]);
    }
    t.note("paper §3.1: no file op blocks on the network — async mode shows the latency-hiding headroom");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> XufsConfig {
        XufsConfig::default()
    }

    #[test]
    fn table1_regenerates() {
        let t = run_table1(1);
        assert_eq!(t.rows.len(), 9); // 8 cut points + total
    }

    #[test]
    fn fig2_fig3_shapes_hold_quick() {
        let (wt, rt) = run_fig2_fig3(&cfg(), true);
        // row 0 is 1 MiB: GPFS write beats XUFS write
        let x1w: f64 = wt.rows[0][1].parse().unwrap();
        let g1w: f64 = wt.rows[0][2].parse().unwrap();
        assert!(g1w > 2.0 * x1w, "1 MiB write: GPFS {g1w} vs XUFS {x1w}");
        // reads above 1 MiB: XUFS wins (cache-local)
        for row in &rt.rows[1..] {
            let x: f64 = row[1].parse().unwrap();
            let g: f64 = row[2].parse().unwrap();
            assert!(x > g, "read row {row:?}");
        }
        // large writes comparable: within 3x either way at 256 MiB
        let last = &wt.rows[wt.rows.len() - 1];
        let xw: f64 = last[1].parse().unwrap();
        let gw: f64 = last[2].parse().unwrap();
        assert!(xw * 3.0 > gw && gw * 3.0 > xw, "large write {last:?}");
    }

    #[test]
    fn fig5_shape_holds_small() {
        // 128 MiB stand-in (must exceed the GPFS page pool so its curve
        // stays flat); the bench binary runs the paper's full 1 GiB
        let (fig5, _t2) = run_fig5_table2(&cfg(), 3, 128 * MIB);
        let first_x: f64 = fig5.rows[0][1].parse().unwrap();
        let warm_x: f64 = fig5.rows[1][1].parse().unwrap();
        let g1: f64 = fig5.rows[0][2].parse().unwrap();
        let g2: f64 = fig5.rows[1][2].parse().unwrap();
        assert!(first_x > 5.0 * warm_x, "XUFS warm drop: {first_x} -> {warm_x}");
        assert!((g1 - g2).abs() / g1 < 0.25, "GPFS flat: {g1} vs {g2}");
    }

    #[test]
    fn ablation_stripes_monotone() {
        let t = run_ablation_stripes(&cfg(), 32 * MIB);
        let s1: f64 = t.rows[0][1].parse().unwrap();
        let s12: f64 = t.rows[4][1].parse().unwrap();
        assert!(s1 / s12 > 6.0, "striping speedup {s1}/{s12}");
    }

    #[test]
    fn ablation_delta_saves_bytes() {
        let t = run_ablation_delta(&cfg(), 16);
        let shipped_on: u64 = t.rows[0][2].parse().unwrap();
        let shipped_off: u64 = t.rows[1][2].parse().unwrap();
        assert!(shipped_on * 10 < shipped_off, "delta {shipped_on} vs full {shipped_off}");
    }

    #[test]
    fn ablation_paging_cuts_ttfb_and_early_exit_bytes() {
        // 64 MiB stand-in; the bench binary runs the paper's full 1 GiB
        let t = run_ablation_paging(&cfg(), 64 * MIB);
        // rows: [paging, whole-file]
        let ttfb_paging: f64 = t.rows[0][1].parse().unwrap();
        let ttfb_whole: f64 = t.rows[1][1].parse().unwrap();
        assert!(
            ttfb_paging * 5.0 < ttfb_whole,
            "paging TTFB must be >=5x better ({ttfb_paging} vs {ttfb_whole})"
        );
        // the early-exit read moves ~1/16th of the bytes, not the file
        let early_paging: u64 = t.rows[0][5].parse().unwrap();
        let early_whole: u64 = t.rows[1][5].parse().unwrap();
        assert!(
            early_paging * 4 < early_whole,
            "early exit must move proportionally fewer bytes ({early_paging} vs {early_whole})"
        );
        // the full sequential scan moves the same content either way
        // (within protocol overheads)
        let full_paging: u64 = t.rows[0][3].parse().unwrap();
        let full_whole: u64 = t.rows[1][3].parse().unwrap();
        assert!(
            full_paging < full_whole + full_whole / 4,
            "paging must not inflate full-scan WAN bytes ({full_paging} vs {full_whole})"
        );
    }

    #[test]
    fn budget_below_working_set_still_builds_correctly() {
        // cache.budget_bytes far below the working set: the build-style
        // workload (write then re-read) must still complete with correct
        // bytes, dirty blocks must never be evicted, and the eviction
        // metrics must show the budget actually bound
        let mut c2 = cfg();
        c2.cache.budget_bytes = 512 * 1024; // 8 blocks
        c2.stripe.prefetch_enabled = false;
        let files: Vec<(String, Vec<u8>)> = (0..4)
            .map(|i| (format!("/home/u/src/in{i}.dat"), vec![i as u8 + 1; 3 * 64 * 1024]))
            .collect();
        let (w, mut xc) = xufs_world(&c2, &files);
        xc.writeback = WritebackMode::Async;
        xc.async_flush_threshold = usize::MAX;
        // read every input (faults blocks under budget pressure), write
        // an output per input (dirty blocks pile up unflushed)
        for i in 0..4 {
            let n = xc.scan_file(&format!("/home/u/src/in{i}.dat"), 64 * 1024).unwrap();
            assert_eq!(n, 3 * 64 * 1024);
            let out = vec![0xB0 + i as u8; 2 * 64 * 1024];
            xc.write_file(&format!("/home/u/src/out{i}.dat"), &out, 64 * 1024).unwrap();
        }
        // re-read an input end-to-end: evicted blocks re-fault correctly
        let n = xc.scan_file("/home/u/src/in0.dat", 64 * 1024).unwrap();
        assert_eq!(n, 3 * 64 * 1024);
        let evicted = xc.metrics().counter(names::CACHE_EVICTED_BYTES);
        assert!(evicted > 0, "the budget must have forced evictions");
        // dirty blocks were never evicted: the queued outputs flush whole
        // and land at home bit-exact
        xc.fsync().unwrap();
        for i in 0..4 {
            let p = format!("/home/u/src/out{i}.dat");
            let home = w.home(|s| s.home().read(&p).unwrap().to_vec());
            assert_eq!(home, vec![0xB0 + i as u8; 2 * 64 * 1024], "out{i} corrupted");
        }
        // and the inputs are still intact at home (reads never wrote back)
        for i in 0..4 {
            let p = format!("/home/u/src/in{i}.dat");
            let home = w.home(|s| s.home().read(&p).unwrap().to_vec());
            assert_eq!(home, vec![i as u8 + 1; 3 * 64 * 1024]);
        }
    }

    #[test]
    fn ablation_compound_cuts_round_trips() {
        let t = run_ablation_compound(&cfg());
        // rows: [compound, per-op]
        let compound_rpcs: u64 = t.rows[0][2].parse().unwrap();
        let perop_rpcs: u64 = t.rows[1][2].parse().unwrap();
        assert!(
            compound_rpcs < perop_rpcs,
            "compound flush must use fewer WAN round trips ({compound_rpcs} vs {perop_rpcs})"
        );
        let batched: u64 = t.rows[0][4].parse().unwrap();
        assert!(batched > 20, "the whole build queue should batch (got {batched})");
        let compound_frames: u64 = t.rows[0][3].parse().unwrap();
        assert!(compound_frames <= 2, "one flush ≈ one compound frame (got {compound_frames})");
        // the per-op run must not have issued any compound frames
        assert_eq!(t.rows[1][3], "0");
    }
}
