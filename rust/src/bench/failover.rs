//! Failover bench (DESIGN.md §2.7): virtual-clock **time-to-first-op**
//! after a primary crash, for the replicated pair (detect → drain the
//! durable log tail → promote → reconnect) versus the paper's deployment
//! (wait for the crontab restart). Fully deterministic — everything is
//! charged to the virtual clock, so the table reproduces bit-identically
//! on any machine. `BENCH_failover.json` at the repo root records it
//! (regenerate: `cargo bench --bench failover`).

use crate::client::Vfs;
use crate::config::XufsConfig;
use crate::coordinator::SimWorld;
use crate::simnet::VirtualTime;

use super::report::{secs, Table};

/// Modeled failure detector: seconds one refused reconnect attempt
/// burns (TCP connect timeout / lease-renew RPC timeout class).
pub const DETECT_TIMEOUT_S: f64 = 1.0;
/// Refused attempts before the client/operator declares the primary
/// dead (two timeouts ~ the classic "is it really down" double-check).
pub const DETECT_ATTEMPTS: u32 = 2;
/// The paper restarts the crashed server from crontab; one period.
pub const CRONTAB_PERIOD_S: f64 = 60.0;
/// Warm-up files written (and replicated) before the crash.
const WARM_FILES: usize = 16;
/// Files written after the last shipping tick: the un-shipped tail the
/// promotion has to drain from the durable log (bounded-lag catch-up).
const LAG_FILES: usize = 4;

/// One measured recovery path.
pub struct FailoverPoint {
    pub mode: &'static str,
    /// Crash -> the client concludes the primary is gone.
    pub detect_s: f64,
    /// Takeover work: drain + promote (failover) or the crontab wait
    /// (cold restart).
    pub takeover_s: f64,
    /// Reconnect + the first completed write against the new head.
    pub first_op_s: f64,
    pub total_s: f64,
}

fn run_point(cfg: &XufsConfig, replicated: bool) -> FailoverPoint {
    let mut world = SimWorld::new(cfg.clone());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
    });
    if replicated {
        world.enable_replica();
    }
    let mut c = world.mount("/home/u").unwrap();
    for i in 0..WARM_FILES {
        c.write_file(&format!("/home/u/f{i}"), format!("warm {i}").as_bytes(), 1024).unwrap();
    }
    if replicated {
        // steady-state shipping drains the backlog...
        world.replica_tick(true);
    }
    for i in 0..LAG_FILES {
        // ...then a burst lands just before the crash: this tail is the
        // bounded lag the promotion must catch up from the durable log
        c.write_file(&format!("/home/u/tail{i}"), b"late burst", 1024).unwrap();
    }

    let t0 = c.now();
    world.server_crash();
    for _ in 0..DETECT_ATTEMPTS {
        // refused: the primary is down and the standby (if any) is not
        // yet promoted — each attempt costs one detector timeout
        let _ = c.link_mut().reconnect();
        c.think(DETECT_TIMEOUT_S);
    }
    let detect_s = c.now().saturating_sub(t0).as_secs();

    let t1 = c.now();
    let mode = if replicated {
        // the operator's explicit failover: drain the durable log tail
        // to the secondary over the WAN, promote it, fence the primary
        world.promote_secondary().expect("promote_secondary");
        "failover"
    } else {
        // the paper's recovery: wait out the crontab period
        c.think(CRONTAB_PERIOD_S);
        world.server_restart();
        "cold-restart"
    };
    let takeover_s = c.now().saturating_sub(t1).as_secs();

    let t2 = c.now();
    c.link_mut().reconnect().expect("reconnect after takeover");
    c.write_file("/home/u/first-after", b"first op", 64).expect("first op after takeover");
    let first_op_s = c.now().saturating_sub(t2).as_secs();

    // sanity: the new head really holds everything acknowledged before
    // the crash (the drain covered the lag tail)
    let authority = world.authority();
    for i in 0..LAG_FILES {
        assert!(
            authority.home().exists(&format!("/home/u/tail{i}")),
            "{mode}: lag-tail file tail{i} missing at the serving node"
        );
    }

    FailoverPoint {
        mode,
        detect_s,
        takeover_s,
        first_op_s,
        total_s: c.now().saturating_sub(t0).as_secs(),
    }
}

/// `(failover_total_s, cold_total_s)` out of a [`run_failover`] table.
pub fn totals(t: &Table) -> Option<(f64, f64)> {
    let total = |mode: &str| -> Option<f64> {
        t.rows.iter().find(|r| r[0] == mode)?.last()?.parse::<f64>().ok()
    };
    Some((total("failover")?, total("cold-restart")?))
}

/// The two recovery paths, one table (`cargo bench --bench failover`).
pub fn run_failover(cfg: &XufsConfig) -> Table {
    let mut t = Table::new(
        "Failover — replicated takeover vs cold crontab restart (time-to-first-op after \
         primary crash)",
        &["mode", "detect s", "takeover s", "first op s", "total s"],
    );
    let fo = run_point(cfg, true);
    let cold = run_point(cfg, false);
    for p in [&fo, &cold] {
        t.row(vec![
            p.mode.to_string(),
            secs(p.detect_s),
            secs(p.takeover_s),
            secs(p.first_op_s),
            secs(p.total_s),
        ]);
    }
    t.note(format!(
        "time-to-first-op: {}s failover vs {}s cold restart — {:.1}x faster (model: \
         {DETECT_ATTEMPTS} x {DETECT_TIMEOUT_S}s detection timeouts, crontab period \
         {CRONTAB_PERIOD_S}s, {LAG_FILES}-file lag tail drained at promote)",
        secs(fo.total_s),
        secs(cold.total_s),
        cold.total_s / fo.total_s.max(1e-9),
    ));
    t.note(
        "acceptance: failover total < cold-restart total (benches/failover.rs enforces)"
            .to_string(),
    );
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The nightly smoke in miniature: one deterministic run, failover
    /// must beat the crontab wait.
    #[test]
    fn failover_beats_cold_restart() {
        let t = run_failover(&XufsConfig::default());
        let (fo, cold) = totals(&t).expect("both rows present");
        assert!(fo > 0.0 && cold > 0.0);
        assert!(fo < cold, "failover {fo}s must beat cold restart {cold}s");
        // the cold path is dominated by the crontab period by construction
        assert!(cold >= CRONTAB_PERIOD_S);
    }
}
