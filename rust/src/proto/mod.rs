//! XUFS wire protocol.
//!
//! Every client<->server interaction — auth handshake, namespace reads,
//! striped fetches, meta-operation replay, callback registration, lock
//! leases — is a typed [`Request`]/[`Response`] pair with a hand-rolled
//! binary codec (the offline crate set has no serde). The same messages
//! flow over both transports: the simulated WAN (function call + modeled
//! delay) and real TCP (length-prefixed frames, `coordinator::net`).

mod codec;
mod messages;

pub use codec::{Decoder, Encoder, FrameDecoder, FrameWriter, ProtoError};
pub use messages::{
    BlockExtent, CompoundOp, DirEntry, FileImage, LockKind, MetaOp, NotifyEvent, RangeImage,
    ReplPayload, ReplRecord, Request, Response, WireAttr,
};

/// Frame a message body with a u32-LE length prefix (TCP transport).
pub fn frame(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 4);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(body);
    out
}

/// Maximum accepted frame (64 MiB + slack): bounds a malicious peer.
pub const MAX_FRAME: usize = 64 * 1024 * 1024 + 4096;

/// [`Response::Err`] code for "over admission limits, retry later"
/// (DESIGN.md §2.9): the reactor's typed busy signal for refused
/// connections and excess pipelined requests. Distinct from 111 (server
/// down) and 112 (wrong endpoint): the endpoint is right and healthy,
/// the client should simply back off.
pub const BUSY_CODE: u32 = 117;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let f = frame(b"abc");
        assert_eq!(&f[..4], &3u32.to_le_bytes());
        assert_eq!(&f[4..], b"abc");
    }
}
