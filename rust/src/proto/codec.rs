//! Binary codec primitives: tagged, varint-lengthed, little-endian.

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError(pub String);

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "protocol decode error: {}", self.0)
    }
}
impl std::error::Error for ProtoError {}

fn err(msg: &str) -> ProtoError {
    ProtoError(msg.to_string())
}

/// Append-only encoder.
#[derive(Debug, Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.u8(v as u8)
    }

    /// LEB128 varint (used for all lengths and most integers).
    pub fn varint(&mut self, mut v: u64) -> &mut Self {
        loop {
            let byte = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return self;
            }
            self.buf.push(byte | 0x80);
        }
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn i32(&mut self, v: i32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.varint(v.len() as u64);
        self.buf.extend_from_slice(v);
        self
    }

    pub fn str(&mut self, v: &str) -> &mut Self {
        self.bytes(v.as_bytes())
    }

    pub fn i32_slice(&mut self, v: &[i32]) -> &mut Self {
        self.varint(v.len() as u64);
        for &x in v {
            self.i32(x);
        }
        self
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }
}

/// Cursor-based decoder.
#[derive(Debug)]
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Decoder { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn finished(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.remaining() < n {
            return Err(err("short buffer"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, ProtoError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(err(&format!("bad bool {v}"))),
        }
    }

    pub fn varint(&mut self) -> Result<u64, ProtoError> {
        let mut v = 0u64;
        let mut shift = 0;
        loop {
            let byte = self.u8()?;
            if shift >= 64 {
                return Err(err("varint overflow"));
            }
            v |= ((byte & 0x7F) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32, ProtoError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], ProtoError> {
        let n = self.varint()? as usize;
        if n > super::MAX_FRAME {
            return Err(err("length exceeds MAX_FRAME"));
        }
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String, ProtoError> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| err("invalid utf-8"))
    }

    pub fn i32_vec(&mut self) -> Result<Vec<i32>, ProtoError> {
        let n = self.varint()? as usize;
        if n * 4 > self.remaining() {
            return Err(err("i32 vec longer than buffer"));
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i32()?);
        }
        Ok(v)
    }

    pub fn expect_end(&self) -> Result<(), ProtoError> {
        if self.finished() {
            Ok(())
        } else {
            Err(err("trailing bytes"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut e = Encoder::new();
        e.u8(7).bool(true).u32(0xDEADBEEF).i32(-5).u64(u64::MAX).f64(1.5);
        let b = e.into_bytes();
        let mut d = Decoder::new(&b);
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert_eq!(d.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(d.i32().unwrap(), -5);
        assert_eq!(d.u64().unwrap(), u64::MAX);
        assert_eq!(d.f64().unwrap(), 1.5);
        d.expect_end().unwrap();
    }

    #[test]
    fn varint_edges() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut e = Encoder::new();
            e.varint(v);
            let b = e.into_bytes();
            assert_eq!(Decoder::new(&b).varint().unwrap(), v);
        }
    }

    #[test]
    fn bytes_and_strings() {
        let mut e = Encoder::new();
        e.bytes(b"").str("héllo").i32_slice(&[1, -2, 3]);
        let b = e.into_bytes();
        let mut d = Decoder::new(&b);
        assert_eq!(d.bytes().unwrap(), b"");
        assert_eq!(d.str().unwrap(), "héllo");
        assert_eq!(d.i32_vec().unwrap(), vec![1, -2, 3]);
    }

    #[test]
    fn short_buffer_errors() {
        let mut d = Decoder::new(&[0x96]); // unterminated varint
        assert!(d.varint().is_err());
        let mut d = Decoder::new(&[5, b'a']); // length 5, 1 byte present
        assert!(d.bytes().is_err());
        let mut d = Decoder::new(&[2]); // bad bool
        assert!(d.bool().is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut e = Encoder::new();
        e.u8(1).u8(2);
        let b = e.into_bytes();
        let mut d = Decoder::new(&b);
        d.u8().unwrap();
        assert!(d.expect_end().is_err());
    }

    #[test]
    fn hostile_length_rejected() {
        let mut e = Encoder::new();
        e.varint(u64::MAX); // absurd length claim
        let b = e.into_bytes();
        assert!(Decoder::new(&b).bytes().is_err());
    }
}
