//! Binary codec primitives: tagged, varint-lengthed, little-endian.

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError(pub String);

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "protocol decode error: {}", self.0)
    }
}
impl std::error::Error for ProtoError {}

fn err(msg: &str) -> ProtoError {
    ProtoError(msg.to_string())
}

/// Append-only encoder.
#[derive(Debug, Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.u8(v as u8)
    }

    /// LEB128 varint (used for all lengths and most integers).
    pub fn varint(&mut self, mut v: u64) -> &mut Self {
        loop {
            let byte = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return self;
            }
            self.buf.push(byte | 0x80);
        }
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn i32(&mut self, v: i32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn f64(&mut self, v: f64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.varint(v.len() as u64);
        self.buf.extend_from_slice(v);
        self
    }

    pub fn str(&mut self, v: &str) -> &mut Self {
        self.bytes(v.as_bytes())
    }

    pub fn i32_slice(&mut self, v: &[i32]) -> &mut Self {
        self.varint(v.len() as u64);
        for &x in v {
            self.i32(x);
        }
        self
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    /// Drop all encoded bytes but keep the allocation (buffer reuse).
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// Overwrite 4 already-encoded bytes at `at` with a u32-LE — how
    /// [`FrameWriter`] patches a frame's length slot after the body is
    /// encoded, so framing needs no second buffer.
    pub fn patch_u32(&mut self, at: usize, v: u32) {
        self.buf[at..at + 4].copy_from_slice(&v.to_le_bytes());
    }
}

// ---------------------------------------------------------------------
// v2 streaming framing (DESIGN.md §2.9)
// ---------------------------------------------------------------------

/// Read granularity for [`FrameDecoder::read_from`]; also the floor for
/// the decode buffer, so steady-state small frames never reallocate.
const DECODER_CHUNK: usize = 64 * 1024;

/// Incremental length-prefixed frame decoder over ONE reusable buffer.
///
/// Bytes arrive in arbitrary pieces (nonblocking socket reads, test
/// `push`es); [`FrameDecoder::next_frame`] yields each complete frame as
/// a borrowed slice of the buffer — no per-frame `Vec`, no blocking
/// `read_exact`. When the buffer drains it rewinds to offset zero with
/// its capacity retained (counted, surfaced as `codec.buf_reuses`);
/// a partial frame still in flight is compacted to the front only when
/// the tail runs out of room.
#[derive(Debug)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    start: usize,
    end: usize,
    max_frame: usize,
    reuses: u64,
}

impl FrameDecoder {
    pub fn new(max_frame: usize) -> Self {
        FrameDecoder { buf: Vec::new(), start: 0, end: 0, max_frame, reuses: 0 }
    }

    /// Bytes buffered but not yet yielded as frames.
    pub fn buffered(&self) -> usize {
        self.end - self.start
    }

    /// Times the drained buffer was rewound with its allocation kept
    /// (the no-allocation steady state). Resets the counter.
    pub fn take_reuses(&mut self) -> u64 {
        std::mem::take(&mut self.reuses)
    }

    /// Ensure at least `need` writable bytes past `end`: compact a
    /// partial frame to the front first, grow only if still short.
    fn make_room(&mut self, need: usize) {
        if self.buf.len() - self.end >= need {
            return;
        }
        if self.start > 0 {
            self.buf.copy_within(self.start..self.end, 0);
            self.end -= self.start;
            self.start = 0;
        }
        if self.buf.len() - self.end < need {
            let want = (self.end + need).max(DECODER_CHUNK);
            self.buf.resize(want, 0);
        }
    }

    /// Feed bytes that already arrived (tests, in-memory transports).
    pub fn push(&mut self, data: &[u8]) {
        self.make_room(data.len());
        self.buf[self.end..self.end + data.len()].copy_from_slice(data);
        self.end += data.len();
    }

    /// One `read` into the spare tail of the buffer. `Ok(0)` is EOF;
    /// `WouldBlock` passes through untouched (the reactor's signal to
    /// move on to the next connection).
    pub fn read_from<R: std::io::Read>(&mut self, r: &mut R) -> std::io::Result<usize> {
        self.make_room(DECODER_CHUNK);
        let n = r.read(&mut self.buf[self.end..])?;
        self.end += n;
        Ok(n)
    }

    /// The next complete frame, if one is fully buffered. `Ok(None)`
    /// means "need more bytes" — never an error, however the stream was
    /// torn so far. A length prefix above the cap is unrecoverable
    /// (framing is lost) and errors.
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>, ProtoError> {
        if self.start == self.end && self.start != 0 {
            // fully drained: rewind so the next bytes land at the front
            // of the SAME allocation
            self.start = 0;
            self.end = 0;
            self.reuses += 1;
        }
        if self.buffered() < 4 {
            return Ok(None);
        }
        let header: [u8; 4] = self.buf[self.start..self.start + 4].try_into().unwrap();
        let len = u32::from_le_bytes(header) as usize;
        if len > self.max_frame {
            return Err(err(&format!("frame length {len} exceeds cap {}", self.max_frame)));
        }
        if self.buffered() < 4 + len {
            return Ok(None);
        }
        let at = self.start + 4;
        self.start += 4 + len;
        Ok(Some(&self.buf[at..at + len]))
    }
}

/// Streaming frame writer over one reusable per-connection buffer, with
/// partial-write resumption.
///
/// [`FrameWriter::frame`] reserves the u32-LE length slot, lets the
/// caller encode the body straight into the buffer (payload bytes are
/// copied exactly once, from their owner into this buffer), then patches
/// the slot. [`FrameWriter::flush_to`] pushes as much as the socket will
/// take and remembers its offset, so a slow reader costs buffer space,
/// never a blocked thread.
#[derive(Debug, Default)]
pub struct FrameWriter {
    enc: Encoder,
    start: usize,
    reuses: u64,
}

impl FrameWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes encoded but not yet accepted by the socket.
    pub fn pending(&self) -> usize {
        self.enc.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.pending() == 0
    }

    /// Times the drained buffer was rewound with its allocation kept.
    /// Resets the counter.
    pub fn take_reuses(&mut self) -> u64 {
        std::mem::take(&mut self.reuses)
    }

    /// Append one length-prefixed frame; `fill` encodes the body.
    pub fn frame(&mut self, fill: impl FnOnce(&mut Encoder)) {
        let slot = self.enc.len();
        self.enc.u32(0);
        fill(&mut self.enc);
        let body = self.enc.len() - slot - 4;
        self.enc.patch_u32(slot, body as u32);
    }

    /// Push pending bytes until done or the peer's window fills.
    /// `Ok(true)`: everything flushed, buffer rewound for reuse.
    /// `Ok(false)`: `WouldBlock` — call again when the fd is writable.
    pub fn flush_to(&mut self, w: &mut impl std::io::Write) -> std::io::Result<bool> {
        while self.start < self.enc.len() {
            match w.write(&self.enc.as_slice()[self.start..]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ))
                }
                Ok(n) => self.start += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if self.start > 0 {
            self.enc.clear();
            self.start = 0;
            self.reuses += 1;
        }
        Ok(true)
    }
}

/// Cursor-based decoder.
#[derive(Debug)]
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Decoder { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn finished(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtoError> {
        if self.remaining() < n {
            return Err(err("short buffer"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, ProtoError> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool, ProtoError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(err(&format!("bad bool {v}"))),
        }
    }

    pub fn varint(&mut self) -> Result<u64, ProtoError> {
        let mut v = 0u64;
        let mut shift = 0;
        loop {
            let byte = self.u8()?;
            if shift >= 64 {
                return Err(err("varint overflow"));
            }
            v |= ((byte & 0x7F) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn u32(&mut self) -> Result<u32, ProtoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32, ProtoError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, ProtoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, ProtoError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn bytes(&mut self) -> Result<&'a [u8], ProtoError> {
        let n = self.varint()? as usize;
        if n > super::MAX_FRAME {
            return Err(err("length exceeds MAX_FRAME"));
        }
        self.take(n)
    }

    pub fn str(&mut self) -> Result<String, ProtoError> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).map_err(|_| err("invalid utf-8"))
    }

    pub fn i32_vec(&mut self) -> Result<Vec<i32>, ProtoError> {
        let n = self.varint()? as usize;
        if n * 4 > self.remaining() {
            return Err(err("i32 vec longer than buffer"));
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i32()?);
        }
        Ok(v)
    }

    pub fn expect_end(&self) -> Result<(), ProtoError> {
        if self.finished() {
            Ok(())
        } else {
            Err(err("trailing bytes"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut e = Encoder::new();
        e.u8(7).bool(true).u32(0xDEADBEEF).i32(-5).u64(u64::MAX).f64(1.5);
        let b = e.into_bytes();
        let mut d = Decoder::new(&b);
        assert_eq!(d.u8().unwrap(), 7);
        assert!(d.bool().unwrap());
        assert_eq!(d.u32().unwrap(), 0xDEADBEEF);
        assert_eq!(d.i32().unwrap(), -5);
        assert_eq!(d.u64().unwrap(), u64::MAX);
        assert_eq!(d.f64().unwrap(), 1.5);
        d.expect_end().unwrap();
    }

    #[test]
    fn varint_edges() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX] {
            let mut e = Encoder::new();
            e.varint(v);
            let b = e.into_bytes();
            assert_eq!(Decoder::new(&b).varint().unwrap(), v);
        }
    }

    #[test]
    fn bytes_and_strings() {
        let mut e = Encoder::new();
        e.bytes(b"").str("héllo").i32_slice(&[1, -2, 3]);
        let b = e.into_bytes();
        let mut d = Decoder::new(&b);
        assert_eq!(d.bytes().unwrap(), b"");
        assert_eq!(d.str().unwrap(), "héllo");
        assert_eq!(d.i32_vec().unwrap(), vec![1, -2, 3]);
    }

    #[test]
    fn short_buffer_errors() {
        let mut d = Decoder::new(&[0x96]); // unterminated varint
        assert!(d.varint().is_err());
        let mut d = Decoder::new(&[5, b'a']); // length 5, 1 byte present
        assert!(d.bytes().is_err());
        let mut d = Decoder::new(&[2]); // bad bool
        assert!(d.bool().is_err());
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut e = Encoder::new();
        e.u8(1).u8(2);
        let b = e.into_bytes();
        let mut d = Decoder::new(&b);
        d.u8().unwrap();
        assert!(d.expect_end().is_err());
    }

    #[test]
    fn hostile_length_rejected() {
        let mut e = Encoder::new();
        e.varint(u64::MAX); // absurd length claim
        let b = e.into_bytes();
        assert!(Decoder::new(&b).bytes().is_err());
    }

    fn framed(body: &[u8]) -> Vec<u8> {
        let mut out = (body.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(body);
        out
    }

    #[test]
    fn frame_decoder_single_and_pipelined() {
        let mut dec = FrameDecoder::new(1 << 20);
        let mut wire = framed(b"alpha");
        wire.extend_from_slice(&framed(b""));
        wire.extend_from_slice(&framed(b"gamma"));
        dec.push(&wire);
        assert_eq!(dec.next_frame().unwrap().unwrap(), b"alpha");
        assert_eq!(dec.next_frame().unwrap().unwrap(), b"");
        assert_eq!(dec.next_frame().unwrap().unwrap(), b"gamma");
        assert_eq!(dec.next_frame().unwrap(), None);
        assert_eq!(dec.buffered(), 0);
    }

    #[test]
    fn frame_decoder_byte_at_a_time() {
        let mut dec = FrameDecoder::new(1 << 20);
        let wire = framed(b"slow reader");
        for (i, b) in wire.iter().enumerate() {
            dec.push(&[*b]);
            let got = dec.next_frame().unwrap();
            if i + 1 < wire.len() {
                assert!(got.is_none(), "frame complete early at byte {i}");
            } else {
                assert_eq!(got.unwrap(), b"slow reader");
            }
        }
    }

    #[test]
    fn frame_decoder_reuses_buffer_when_drained() {
        let mut dec = FrameDecoder::new(1 << 20);
        for round in 0..10u8 {
            dec.push(&framed(&[round; 100]));
            assert_eq!(dec.next_frame().unwrap().unwrap(), &[round; 100][..]);
            assert_eq!(dec.next_frame().unwrap(), None);
        }
        // 10 drain/rewind cycles, minus the first (buffer starts empty
        // at offset zero, so round 1's rewind is the first counted)
        assert!(dec.take_reuses() >= 9, "drained buffer must be reused");
        assert_eq!(dec.take_reuses(), 0, "take_reuses resets");
    }

    #[test]
    fn frame_decoder_rejects_oversize_length() {
        let mut dec = FrameDecoder::new(1024);
        dec.push(&(4096u32).to_le_bytes());
        assert!(dec.next_frame().is_err());
    }

    #[test]
    fn frame_decoder_compacts_partial_frames() {
        // tiny cap forces compaction: after consuming one frame, the
        // next partial frame sits mid-buffer until make_room slides it
        let mut dec = FrameDecoder::new(1 << 20);
        let big = vec![7u8; 200 * 1024]; // bigger than DECODER_CHUNK
        let wire = framed(&big);
        dec.push(&framed(b"first"));
        dec.push(&wire[..10]);
        assert_eq!(dec.next_frame().unwrap().unwrap(), b"first");
        assert_eq!(dec.next_frame().unwrap(), None);
        dec.push(&wire[10..]);
        assert_eq!(dec.next_frame().unwrap().unwrap(), &big[..]);
        assert_eq!(dec.next_frame().unwrap(), None);
    }

    /// A writer that accepts a few bytes per call, then `WouldBlock`s
    /// until re-armed — a slow WAN reader in miniature.
    struct Throttle {
        accepted: Vec<u8>,
        window: usize,
    }

    impl std::io::Write for Throttle {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.window == 0 {
                return Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "full"));
            }
            let n = buf.len().min(self.window);
            self.accepted.extend_from_slice(&buf[..n]);
            self.window = 0;
            Ok(n)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn frame_writer_resumes_partial_writes() {
        let mut w = FrameWriter::new();
        w.frame(|e| {
            e.bytes(b"payload one");
        });
        w.frame(|e| {
            e.bytes(b"payload two");
        });
        let total = w.pending();
        let mut sink = Throttle { accepted: Vec::new(), window: 0 };
        let mut rounds = 0;
        loop {
            sink.window = 5;
            if w.flush_to(&mut sink).unwrap() {
                break;
            }
            rounds += 1;
            assert!(rounds < 1000, "flush must make progress");
        }
        assert!(rounds > 1, "throttle must have split the write");
        assert!(w.is_empty());
        assert_eq!(w.take_reuses(), 1);
        assert_eq!(sink.accepted.len(), total);
        // the accepted stream reassembles into the original frames
        let mut dec = FrameDecoder::new(1 << 20);
        dec.push(&sink.accepted);
        let f1 = dec.next_frame().unwrap().unwrap().to_vec();
        assert_eq!(Decoder::new(&f1).bytes().unwrap(), b"payload one");
        let f2 = dec.next_frame().unwrap().unwrap().to_vec();
        assert_eq!(Decoder::new(&f2).bytes().unwrap(), b"payload two");
        assert_eq!(dec.next_frame().unwrap(), None);
    }

    #[test]
    fn frame_writer_length_slot_patched() {
        let mut w = FrameWriter::new();
        w.frame(|e| {
            e.u8(1).u64(42);
        });
        let mut sink = Vec::new();
        assert!(w.flush_to(&mut sink).unwrap());
        assert_eq!(&sink[..4], &9u32.to_le_bytes());
        assert_eq!(sink.len(), 13);
    }
}
