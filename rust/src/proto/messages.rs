//! Protocol message types and their codec implementations.

use crate::chunkstore::Digest;
use crate::homefs::{Attr, NodeKind};
use crate::proto::codec::{Decoder, Encoder, ProtoError};
use crate::simnet::VirtualTime;

/// Encode a digest list as one length-prefixed blob of `32 * n` bytes.
fn encode_digest_list(e: &mut Encoder, digests: &[Digest]) {
    let mut blob = Vec::with_capacity(digests.len() * 32);
    for d in digests {
        blob.extend_from_slice(d);
    }
    e.bytes(&blob);
}

/// Decode a digest blob; anything not a multiple of 32 bytes is a torn
/// or tampered frame.
fn decode_digest_list(d: &mut Decoder) -> Result<Vec<Digest>, ProtoError> {
    let raw = d.bytes()?;
    if raw.len() % 32 != 0 {
        return Err(ProtoError(format!("digest blob of {} bytes not a multiple of 32", raw.len())));
    }
    Ok(raw
        .chunks_exact(32)
        .map(|c| {
            let mut a = [0u8; 32];
            a.copy_from_slice(c);
            a
        })
        .collect())
}

/// Attributes on the wire (mirrors `homefs::Attr`).
#[derive(Debug, Clone, PartialEq)]
pub struct WireAttr {
    pub kind: NodeKind,
    pub size: u64,
    pub mtime_ns: u64,
    pub mode: u32,
    pub version: u64,
}

impl WireAttr {
    pub fn from_attr(a: &Attr) -> Self {
        WireAttr { kind: a.kind, size: a.size, mtime_ns: a.mtime.0, mode: a.mode, version: a.version }
    }

    pub fn mtime(&self) -> VirtualTime {
        VirtualTime(self.mtime_ns)
    }

    fn encode(&self, e: &mut Encoder) {
        e.u8(match self.kind {
            NodeKind::File => 0,
            NodeKind::Dir => 1,
        });
        e.u64(self.size).u64(self.mtime_ns).u32(self.mode).u64(self.version);
    }

    fn decode(d: &mut Decoder) -> Result<Self, ProtoError> {
        let kind = match d.u8()? {
            0 => NodeKind::File,
            1 => NodeKind::Dir,
            v => return Err(ProtoError(format!("bad node kind {v}"))),
        };
        Ok(WireAttr { kind, size: d.u64()?, mtime_ns: d.u64()?, mode: d.u32()?, version: d.u64()? })
    }
}

/// One directory entry as the server reports it.
#[derive(Debug, Clone, PartialEq)]
pub struct DirEntry {
    pub name: String,
    pub attr: WireAttr,
}

/// A whole-file image as fetched from the server: content plus the version
/// it corresponds to and per-block digests for integrity/delta writeback.
#[derive(Debug, Clone, PartialEq)]
pub struct FileImage {
    pub path: String,
    pub version: u64,
    pub data: Vec<u8>,
    pub digests: Vec<i32>,
}

/// One block of file content in a partial fetch: its index in the file's
/// block grid, its bytes (short only for the file's last block), and the
/// server-side digest of exactly those bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockExtent {
    pub index: u32,
    pub data: Vec<u8>,
    pub digest: i32,
}

/// A partial file image: the blocks faulted in by one range fetch, all at
/// `version`. The whole-file [`FileImage`] is the degenerate case where
/// the extents cover every block.
#[derive(Debug, Clone, PartialEq)]
pub struct RangeImage {
    pub version: u64,
    pub extents: Vec<BlockExtent>,
}

impl RangeImage {
    /// Total content bytes carried by the extents.
    pub fn bytes(&self) -> u64 {
        self.extents.iter().map(|x| x.data.len() as u64).sum()
    }
}

/// Lock kinds (fcntl-style).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockKind {
    Shared,
    Exclusive,
}

/// Mutating operations recorded in the client's persisted meta-operation
/// queue and replayed to the server (paper §3.1). `WriteFull` carries the
/// aggregated shadow-file content; `WriteDelta` only digest-dirty blocks.
///
/// `WriteFull::base_version` is the home-space version the client's
/// content was derived from, or 0 when unknown/irrelevant. When it is
/// non-zero and the server's copy has moved past it with *different*
/// content (digest vectors differ), the server preserves its copy as a
/// `<path>.xufs-conflict-<client>-<seq>` file before applying the write
/// — last close wins, but the loser is never silently dropped
/// (DESIGN.md §2.5).
#[derive(Debug, Clone, PartialEq)]
pub enum MetaOp {
    Mkdir { path: String },
    Rmdir { path: String },
    Create { path: String },
    Unlink { path: String },
    Rename { from: String, to: String },
    Truncate { path: String, size: u64 },
    SetMode { path: String, mode: u32 },
    WriteFull { path: String, data: Vec<u8>, digests: Vec<i32>, base_version: u64 },
    WriteDelta {
        path: String,
        total_size: u64,
        base_version: u64,
        blocks: Vec<(u32, Vec<u8>)>,
        digests: Vec<i32>,
    },
    /// A `WriteFull` spilled by reference (DESIGN.md §2.8): the content
    /// is named by its ordered chunk digests instead of carried inline.
    /// Replication-internal — the primary's log converts applied
    /// `WriteFull`s to this form when the chunk substrate is on, and the
    /// secondary materializes it back into a `WriteFull` (fetching any
    /// chunks it is missing first via `Request::ChunkPush`). `digests`
    /// and `base_version` are the ORIGINAL block-digest vector and base
    /// version of the converted write, preserved verbatim so the
    /// secondary's conflict-detection logic sees byte-identical inputs.
    /// Clients never submit it; the apply path rejects it as invalid.
    WriteRef {
        path: String,
        size: u64,
        chunks: Vec<Digest>,
        digests: Vec<i32>,
        base_version: u64,
    },
}

impl MetaOp {
    /// The home-space path this op targets (rename reports its source).
    pub fn path(&self) -> &str {
        match self {
            MetaOp::Mkdir { path }
            | MetaOp::Rmdir { path }
            | MetaOp::Create { path }
            | MetaOp::Unlink { path }
            | MetaOp::Truncate { path, .. }
            | MetaOp::SetMode { path, .. }
            | MetaOp::WriteFull { path, .. }
            | MetaOp::WriteDelta { path, .. }
            | MetaOp::WriteRef { path, .. } => path,
            MetaOp::Rename { from, .. } => from,
        }
    }

    /// Payload bytes that must cross the WAN for this op (message body
    /// plus a fixed header allowance).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            MetaOp::WriteFull { data, .. } => data.len() as u64 + 64,
            MetaOp::WriteDelta { blocks, .. } => {
                blocks.iter().map(|(_, b)| b.len() as u64 + 8).sum::<u64>() + 64
            }
            MetaOp::WriteRef { chunks, digests, .. } => {
                chunks.len() as u64 * 32 + digests.len() as u64 * 4 + 64
            }
            _ => 64,
        }
    }

    pub fn encode_into(&self, e: &mut Encoder) {
        match self {
            MetaOp::Mkdir { path } => {
                e.u8(0).str(path);
            }
            MetaOp::Rmdir { path } => {
                e.u8(1).str(path);
            }
            MetaOp::Create { path } => {
                e.u8(2).str(path);
            }
            MetaOp::Unlink { path } => {
                e.u8(3).str(path);
            }
            MetaOp::Rename { from, to } => {
                e.u8(4).str(from).str(to);
            }
            MetaOp::Truncate { path, size } => {
                e.u8(5).str(path).u64(*size);
            }
            MetaOp::SetMode { path, mode } => {
                e.u8(6).str(path).u32(*mode);
            }
            MetaOp::WriteFull { path, data, digests, base_version } => {
                e.u8(7).str(path).bytes(data).i32_slice(digests).u64(*base_version);
            }
            MetaOp::WriteDelta { path, total_size, base_version, blocks, digests } => {
                e.u8(8).str(path).u64(*total_size).u64(*base_version);
                e.varint(blocks.len() as u64);
                for (idx, data) in blocks {
                    e.u32(*idx).bytes(data);
                }
                e.i32_slice(digests);
            }
            MetaOp::WriteRef { path, size, chunks, digests, base_version } => {
                e.u8(9).str(path).u64(*size);
                encode_digest_list(e, chunks);
                e.i32_slice(digests).u64(*base_version);
            }
        }
    }

    pub fn decode_from(d: &mut Decoder) -> Result<Self, ProtoError> {
        Ok(match d.u8()? {
            0 => MetaOp::Mkdir { path: d.str()? },
            1 => MetaOp::Rmdir { path: d.str()? },
            2 => MetaOp::Create { path: d.str()? },
            3 => MetaOp::Unlink { path: d.str()? },
            4 => MetaOp::Rename { from: d.str()?, to: d.str()? },
            5 => MetaOp::Truncate { path: d.str()?, size: d.u64()? },
            6 => MetaOp::SetMode { path: d.str()?, mode: d.u32()? },
            7 => MetaOp::WriteFull {
                path: d.str()?,
                data: d.bytes()?.to_vec(),
                digests: d.i32_vec()?,
                base_version: d.u64()?,
            },
            8 => {
                let path = d.str()?;
                let total_size = d.u64()?;
                let base_version = d.u64()?;
                let n = d.varint()? as usize;
                let mut blocks = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    let idx = d.u32()?;
                    blocks.push((idx, d.bytes()?.to_vec()));
                }
                MetaOp::WriteDelta { path, total_size, base_version, blocks, digests: d.i32_vec()? }
            }
            9 => MetaOp::WriteRef {
                path: d.str()?,
                size: d.u64()?,
                chunks: decode_digest_list(d)?,
                digests: d.i32_vec()?,
                base_version: d.u64()?,
            },
            t => return Err(ProtoError(format!("bad MetaOp tag {t}"))),
        })
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        self.encode_into(&mut e);
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        let mut d = Decoder::new(buf);
        let op = Self::decode_from(&mut d)?;
        d.expect_end()?;
        Ok(op)
    }
}

/// One record of the server's applied-op replication log (DESIGN.md
/// §2.7). The primary appends a record for every *genuine* application
/// outcome — successful client ops (with the resulting version), failed
/// client ops (so the per-(client,seq) failure sets replicate alongside
/// the idempotence watermarks), and home-side local edits — and a
/// [`crate::replica::Shipper`] streams them, HMAC-framed, to the
/// secondary in strict `ship_seq` order.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplRecord {
    /// Global position in the applied-op log, 1-based and gapless: the
    /// secondary applies `watermark + 1` or nothing.
    pub ship_seq: u64,
    /// Namespace shard the op routed to on the primary (per-shard
    /// replication watermarks are tracked against this).
    pub shard: u32,
    pub payload: ReplPayload,
}

/// What one replication record carries.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplPayload {
    /// A client meta-op that APPLIED on the primary. The secondary
    /// replays it through its normal apply path under the original
    /// `(client_id, seq)`, so the idempotence watermark advances
    /// identically and a post-failover replay of the same seq is
    /// answered as a duplicate, never re-applied.
    Op { client_id: u64, seq: u64, new_version: u64, op: MetaOp },
    /// A client meta-op that FAILED semantically on the primary. The
    /// secondary records the seq in its per-client failed set: a
    /// compound may have advanced the watermark past this seq, and
    /// answering its post-failover retry as a duplicate would falsely
    /// ack an op that never landed (DESIGN.md §2.5).
    Failed { client_id: u64, seq: u64, path: String },
    /// A home-side local edit (`local_write`/`local_unlink`) — not a
    /// client op, so it carries no seq and touches no watermark.
    Local { op: MetaOp },
}

impl ReplRecord {
    pub fn encode_into(&self, e: &mut Encoder) {
        e.u64(self.ship_seq).u32(self.shard);
        match &self.payload {
            ReplPayload::Op { client_id, seq, new_version, op } => {
                e.u8(0).u64(*client_id).u64(*seq).u64(*new_version);
                op.encode_into(e);
            }
            ReplPayload::Failed { client_id, seq, path } => {
                e.u8(1).u64(*client_id).u64(*seq).str(path);
            }
            ReplPayload::Local { op } => {
                e.u8(2);
                op.encode_into(e);
            }
        }
    }

    pub fn decode_from(d: &mut Decoder) -> Result<Self, ProtoError> {
        let ship_seq = d.u64()?;
        let shard = d.u32()?;
        let payload = match d.u8()? {
            0 => ReplPayload::Op {
                client_id: d.u64()?,
                seq: d.u64()?,
                new_version: d.u64()?,
                op: MetaOp::decode_from(d)?,
            },
            1 => ReplPayload::Failed { client_id: d.u64()?, seq: d.u64()?, path: d.str()? },
            2 => ReplPayload::Local { op: MetaOp::decode_from(d)? },
            t => return Err(ProtoError(format!("bad ReplPayload tag {t}"))),
        };
        Ok(ReplRecord { ship_seq, shard, payload })
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        self.encode_into(&mut e);
        e.into_bytes()
    }

    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        let mut d = Decoder::new(buf);
        let rec = Self::decode_from(&mut d)?;
        d.expect_end()?;
        Ok(rec)
    }
}

/// One operation inside a [`Request::Compound`] (DESIGN.md §2.3): either
/// a queued meta-op replay (idempotent via its client sequence number) or
/// a read-only stat. The server answers each with a full [`Response`], so
/// partial failure is visible per op and the client replays exactly the
/// ops that did not land.
#[derive(Debug, Clone, PartialEq)]
pub enum CompoundOp {
    /// Apply one queued meta-operation (same semantics as
    /// [`Request::Apply`]).
    Apply { seq: u64, op: MetaOp },
    /// Read attributes (same semantics as [`Request::Stat`]).
    Stat { path: String },
}

impl CompoundOp {
    fn encode_into(&self, e: &mut Encoder) {
        match self {
            CompoundOp::Apply { seq, op } => {
                e.u8(0).u64(*seq);
                op.encode_into(e);
            }
            CompoundOp::Stat { path } => {
                e.u8(1).str(path);
            }
        }
    }

    fn decode_from(d: &mut Decoder) -> Result<Self, ProtoError> {
        Ok(match d.u8()? {
            0 => CompoundOp::Apply { seq: d.u64()?, op: MetaOp::decode_from(d)? },
            1 => CompoundOp::Stat { path: d.str()? },
            t => return Err(ProtoError(format!("bad CompoundOp tag {t}"))),
        })
    }

    /// Payload bytes this op contributes to the compound frame.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            CompoundOp::Apply { op, .. } => op.wire_bytes() + 8,
            CompoundOp::Stat { .. } => 64,
        }
    }
}

fn lock_kind_tag(k: LockKind) -> u8 {
    match k {
        LockKind::Shared => 0,
        LockKind::Exclusive => 1,
    }
}

fn lock_kind_from(tag: u8) -> Result<LockKind, ProtoError> {
    match tag {
        0 => Ok(LockKind::Shared),
        1 => Ok(LockKind::Exclusive),
        v => Err(ProtoError(format!("bad lock kind {v}"))),
    }
}

/// Client->server requests.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Challenge-response step 1: ask for a challenge.
    AuthHello { key_id: String },
    /// Challenge-response step 2: HMAC(phrase, challenge).
    AuthProof { key_id: String, proof: Vec<u8> },
    Stat { path: String },
    ReadDir { path: String },
    /// Whole-file fetch; the transfer engine stripes >64 KiB payloads.
    /// `min_version` is the bounded-staleness floor (DESIGN.md §2.11):
    /// a read-serving secondary whose copy is older than the highest
    /// version this client has observed answers code 119 `TooStale`
    /// instead of serving a regression. 0 means no floor (primary reads
    /// always serve regardless — the primary IS the freshest copy).
    Fetch { path: String, min_version: u64 },
    /// Fetch metadata + per-block digests (first step of a real striped
    /// fetch over TCP: stripes then pull ranges with `FetchRange`).
    /// Carries the same bounded-staleness `min_version` floor as
    /// [`Request::Fetch`].
    FetchMeta { path: String, min_version: u64 },
    /// Fetch a byte range; fails with a stale error if the file's version
    /// no longer matches `expect_version` (torn-fetch protection).
    FetchRange { path: String, offset: u64, len: u64, expect_version: u64 },
    /// Apply one queued meta-operation (client-assigned sequence number
    /// makes replay idempotent).
    Apply { seq: u64, op: MetaOp },
    /// Register for change callbacks under a subtree.
    RegisterCallback { root: String, client_id: u64 },
    LockAcquire { path: String, kind: LockKind, owner: u64 },
    LockRenew { token: u64, owner: u64 },
    LockRelease { token: u64, owner: u64 },
    Ping,
    /// Compound RPC (DESIGN.md §2.3): N metadata ops in one WAN round
    /// trip. Answered by [`Response::CompoundReply`] with one per-op
    /// [`Response`] in order.
    Compound { ops: Vec<CompoundOp> },
    /// Log shipping (DESIGN.md §2.7): a batch of HMAC-framed
    /// [`ReplRecord`]s starting at ship-seq `from`, sent by the
    /// primary's shipper to the secondary. Answered by
    /// [`Response::ReplicaAck`] with the secondary's new global
    /// replication watermark; records at or below the watermark are
    /// skipped (idempotent re-ship after a lost ack), a gap is refused.
    /// `head` is the primary's log head (`repl_ship_seq`) at ship time —
    /// a read-serving secondary uses it to bound how far behind the
    /// primary it is allowed to drift before refusing reads
    /// (`replica.staleness_ops`, DESIGN.md §2.11).
    Replicate { from: u64, frames: Vec<u8>, head: u64 },
    /// Ask a replica (or the primary) for its replication watermark:
    /// `shard < shard_count` reads that shard's watermark, anything
    /// else (use `u32::MAX`) the global one.
    WatermarkQuery { shard: u32 },
    /// Explicit promotion step (DESIGN.md §2.7): the secondary becomes
    /// the primary and starts serving clients. Idempotent on an
    /// already-primary node; refused by a retired (fenced) one.
    Promote,
    /// Out-of-band chunk delivery (DESIGN.md §2.8): raw chunk payloads
    /// the secondary reported missing via [`Response::ReplicaNeed`].
    /// The receiver recomputes each digest on insert (content-addressed
    /// — a tampered chunk simply lands under a different digest and the
    /// needing record stays unsatisfied). Secondary-only, like
    /// `Replicate`.
    ChunkPush { chunks: Vec<Vec<u8>> },
    /// Take a CoW snapshot of the server's live namespace. Answered by
    /// [`Response::SnapshotCreated`] with the id readable through
    /// `@v<id>` paths. Primary-only; requires the chunk substrate.
    SnapshotCreate,
    /// Repair-from-replica (DESIGN.md §2.10): the `ReplicaNeed`/
    /// `ChunkPush` machinery in reverse — a primary that quarantined
    /// rotted chunks asks its secondary for their bytes. Answered by
    /// [`Response::ChunkFill`]; the requester digest-verifies every fill
    /// before re-installing it. Served by secondaries (and primaries,
    /// so a stale topology view still heals).
    ChunkFetch { digests: Vec<Digest> },
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        self.encode_into(&mut e);
        e.into_bytes()
    }

    /// Encode into an existing [`Encoder`] — the streaming transport
    /// ([`crate::proto::FrameWriter`]) appends straight into a reused
    /// per-connection buffer, so payload bytes are copied exactly once.
    pub fn encode_into(&self, e: &mut Encoder) {
        match self {
            Request::AuthHello { key_id } => {
                e.u8(0).str(key_id);
            }
            Request::AuthProof { key_id, proof } => {
                e.u8(1).str(key_id).bytes(proof);
            }
            Request::Stat { path } => {
                e.u8(2).str(path);
            }
            Request::ReadDir { path } => {
                e.u8(3).str(path);
            }
            Request::Fetch { path, min_version } => {
                e.u8(4).str(path).u64(*min_version);
            }
            Request::FetchMeta { path, min_version } => {
                e.u8(11).str(path).u64(*min_version);
            }
            Request::FetchRange { path, offset, len, expect_version } => {
                e.u8(12).str(path).u64(*offset).u64(*len).u64(*expect_version);
            }
            Request::Apply { seq, op } => {
                e.u8(5).u64(*seq);
                op.encode_into(e);
            }
            Request::RegisterCallback { root, client_id } => {
                e.u8(6).str(root).u64(*client_id);
            }
            Request::LockAcquire { path, kind, owner } => {
                e.u8(7).str(path).u8(lock_kind_tag(*kind)).u64(*owner);
            }
            Request::LockRenew { token, owner } => {
                e.u8(8).u64(*token).u64(*owner);
            }
            Request::LockRelease { token, owner } => {
                e.u8(9).u64(*token).u64(*owner);
            }
            Request::Ping => {
                e.u8(10);
            }
            Request::Compound { ops } => {
                e.u8(13).varint(ops.len() as u64);
                for op in ops {
                    op.encode_into(e);
                }
            }
            Request::Replicate { from, frames, head } => {
                e.u8(14).u64(*from).bytes(frames).u64(*head);
            }
            Request::WatermarkQuery { shard } => {
                e.u8(15).u32(*shard);
            }
            Request::Promote => {
                e.u8(16);
            }
            Request::ChunkPush { chunks } => {
                e.u8(17).varint(chunks.len() as u64);
                for c in chunks {
                    e.bytes(c);
                }
            }
            Request::SnapshotCreate => {
                e.u8(18);
            }
            Request::ChunkFetch { digests } => {
                e.u8(19);
                encode_digest_list(e, digests);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        let mut d = Decoder::new(buf);
        let req = match d.u8()? {
            0 => Request::AuthHello { key_id: d.str()? },
            1 => Request::AuthProof { key_id: d.str()?, proof: d.bytes()?.to_vec() },
            2 => Request::Stat { path: d.str()? },
            3 => Request::ReadDir { path: d.str()? },
            4 => Request::Fetch { path: d.str()?, min_version: d.u64()? },
            5 => Request::Apply { seq: d.u64()?, op: MetaOp::decode_from(&mut d)? },
            6 => Request::RegisterCallback { root: d.str()?, client_id: d.u64()? },
            7 => Request::LockAcquire {
                path: d.str()?,
                kind: lock_kind_from(d.u8()?)?,
                owner: d.u64()?,
            },
            8 => Request::LockRenew { token: d.u64()?, owner: d.u64()? },
            9 => Request::LockRelease { token: d.u64()?, owner: d.u64()? },
            10 => Request::Ping,
            11 => Request::FetchMeta { path: d.str()?, min_version: d.u64()? },
            12 => Request::FetchRange {
                path: d.str()?,
                offset: d.u64()?,
                len: d.u64()?,
                expect_version: d.u64()?,
            },
            13 => {
                let n = d.varint()? as usize;
                let mut ops = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    ops.push(CompoundOp::decode_from(&mut d)?);
                }
                Request::Compound { ops }
            }
            14 => Request::Replicate { from: d.u64()?, frames: d.bytes()?.to_vec(), head: d.u64()? },
            15 => Request::WatermarkQuery { shard: d.u32()? },
            16 => Request::Promote,
            17 => {
                let n = d.varint()? as usize;
                let mut chunks = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    chunks.push(d.bytes()?.to_vec());
                }
                Request::ChunkPush { chunks }
            }
            18 => Request::SnapshotCreate,
            19 => Request::ChunkFetch { digests: decode_digest_list(&mut d)? },
            t => return Err(ProtoError(format!("bad Request tag {t}"))),
        };
        d.expect_end()?;
        Ok(req)
    }

    /// Approximate wire size for the WAN model.
    pub fn wire_bytes(&self) -> u64 {
        self.encode().len() as u64 + 16
    }

    /// Encode a compound of queued meta-op replays straight from borrowed
    /// `(seq, op)` pairs — byte-identical to building
    /// `Request::Compound { ops: [CompoundOp::Apply…] }` and encoding it,
    /// without cloning the (possibly multi-MiB) payloads first.
    pub fn encode_compound_applies(ops: &[(u64, MetaOp)]) -> Vec<u8> {
        let mut e = Encoder::new();
        e.u8(13).varint(ops.len() as u64);
        for (seq, op) in ops {
            e.u8(0).u64(*seq);
            op.encode_into(&mut e);
        }
        e.into_bytes()
    }
}

/// Server->client responses.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Challenge { nonce: Vec<u8> },
    AuthOk { session: u64 },
    AuthFail,
    Attr { attr: WireAttr },
    Dir { entries: Vec<DirEntry> },
    File { image: FileImage },
    Applied { seq: u64, new_version: u64 },
    CallbackRegistered,
    LockGranted { token: u64, lease_ns: u64 },
    LockDenied { holder: u64 },
    Released,
    Pong,
    Err { code: u32, msg: String },
    /// Metadata + digests for a striped range fetch.
    FileMeta { version: u64, size: u64, digests: Vec<i32> },
    /// The blocks covering one fetched range at `version` — a partial
    /// [`FileImage`] carrying `(block_index, bytes, digest)` extents so
    /// the client can verify and install each block independently.
    FileBlocks { version: u64, extents: Vec<BlockExtent> },
    /// Per-op results of a [`Request::Compound`], in request order. Each
    /// entry is the [`Response`] the matching single-op request would
    /// have produced (`Applied`/`Attr`/`Err`), so partial failure is
    /// visible per op.
    CompoundReply { replies: Vec<Response> },
    /// The secondary's global replication watermark after ingesting a
    /// [`Request::Replicate`] batch (DESIGN.md §2.7).
    ReplicaAck { watermark: u64 },
    /// Answer to [`Request::WatermarkQuery`]: the queried shard (echoed)
    /// and its replication watermark.
    Watermark { shard: u32, watermark: u64 },
    /// Answer to [`Request::Promote`]: the node now serves as primary;
    /// `watermark` is the replication log position it took over at.
    Promoted { watermark: u64 },
    /// The secondary cannot ingest a [`Request::Replicate`] batch
    /// because some `WriteRef` records name chunks it does not hold
    /// (DESIGN.md §2.8). NOTHING of the batch was applied; the shipper
    /// pushes exactly these digests via [`Request::ChunkPush`] and
    /// re-sends the batch.
    ReplicaNeed { digests: Vec<Digest> },
    /// Answer to [`Request::ChunkPush`]: how many chunks are now
    /// resident (deduped pushes count too).
    ChunkAck { stored: u64 },
    /// Answer to [`Request::SnapshotCreate`]: the new snapshot's id.
    SnapshotCreated { id: u64 },
    /// Answer to [`Request::ChunkFetch`]: the raw bytes of every
    /// requested chunk the responder holds AND could digest-verify
    /// (rotted or missing chunks are simply omitted — the requester
    /// matches fills to requests by recomputing digests).
    ChunkFill { chunks: Vec<Vec<u8>> },
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        self.encode_into(&mut e);
        e.into_bytes()
    }

    /// Encode into an existing [`Encoder`] — the streaming transport
    /// ([`crate::proto::FrameWriter`]) appends straight into a reused
    /// per-connection buffer, so block/chunk payload bytes are copied
    /// exactly once (out of the server's store into the socket buffer).
    pub fn encode_into(&self, e: &mut Encoder) {
        match self {
            Response::Challenge { nonce } => {
                e.u8(0).bytes(nonce);
            }
            Response::AuthOk { session } => {
                e.u8(1).u64(*session);
            }
            Response::AuthFail => {
                e.u8(2);
            }
            Response::Attr { attr } => {
                e.u8(3);
                attr.encode(e);
            }
            Response::Dir { entries } => {
                e.u8(4).varint(entries.len() as u64);
                for ent in entries {
                    e.str(&ent.name);
                    ent.attr.encode(e);
                }
            }
            Response::File { image } => {
                e.u8(5).str(&image.path).u64(image.version).bytes(&image.data);
                e.i32_slice(&image.digests);
            }
            Response::Applied { seq, new_version } => {
                e.u8(6).u64(*seq).u64(*new_version);
            }
            Response::CallbackRegistered => {
                e.u8(7);
            }
            Response::LockGranted { token, lease_ns } => {
                e.u8(8).u64(*token).u64(*lease_ns);
            }
            Response::LockDenied { holder } => {
                e.u8(9).u64(*holder);
            }
            Response::Released => {
                e.u8(10);
            }
            Response::Pong => {
                e.u8(11);
            }
            Response::Err { code, msg } => {
                e.u8(12).u32(*code).str(msg);
            }
            Response::FileMeta { version, size, digests } => {
                e.u8(13).u64(*version).u64(*size).i32_slice(digests);
            }
            Response::FileBlocks { version, extents } => {
                e.u8(14).u64(*version).varint(extents.len() as u64);
                for x in extents {
                    e.u32(x.index).bytes(&x.data).i32(x.digest);
                }
            }
            Response::CompoundReply { replies } => {
                // each reply is length-prefixed so decode stays simple
                // and bounded even for nested error payloads
                e.u8(15).varint(replies.len() as u64);
                for r in replies {
                    e.bytes(&r.encode());
                }
            }
            Response::ReplicaAck { watermark } => {
                e.u8(16).u64(*watermark);
            }
            Response::Watermark { shard, watermark } => {
                e.u8(17).u32(*shard).u64(*watermark);
            }
            Response::Promoted { watermark } => {
                e.u8(18).u64(*watermark);
            }
            Response::ReplicaNeed { digests } => {
                e.u8(19);
                encode_digest_list(e, digests);
            }
            Response::ChunkAck { stored } => {
                e.u8(20).u64(*stored);
            }
            Response::SnapshotCreated { id } => {
                e.u8(21).u64(*id);
            }
            Response::ChunkFill { chunks } => {
                e.u8(22).varint(chunks.len() as u64);
                for c in chunks {
                    e.bytes(c);
                }
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        Self::decode_depth(buf, 0)
    }

    /// `depth` guards the only recursive spot (CompoundReply's inner
    /// replies): the server never nests compounds, so a nested reply is
    /// a protocol violation — rejecting it bounds decode stack depth
    /// against hostile frames.
    fn decode_depth(buf: &[u8], depth: u8) -> Result<Self, ProtoError> {
        let mut d = Decoder::new(buf);
        let resp = match d.u8()? {
            0 => Response::Challenge { nonce: d.bytes()?.to_vec() },
            1 => Response::AuthOk { session: d.u64()? },
            2 => Response::AuthFail,
            3 => Response::Attr { attr: WireAttr::decode(&mut d)? },
            4 => {
                let n = d.varint()? as usize;
                let mut entries = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    let name = d.str()?;
                    entries.push(DirEntry { name, attr: WireAttr::decode(&mut d)? });
                }
                Response::Dir { entries }
            }
            5 => Response::File {
                image: FileImage {
                    path: d.str()?,
                    version: d.u64()?,
                    data: d.bytes()?.to_vec(),
                    digests: d.i32_vec()?,
                },
            },
            6 => Response::Applied { seq: d.u64()?, new_version: d.u64()? },
            7 => Response::CallbackRegistered,
            8 => Response::LockGranted { token: d.u64()?, lease_ns: d.u64()? },
            9 => Response::LockDenied { holder: d.u64()? },
            10 => Response::Released,
            11 => Response::Pong,
            12 => Response::Err { code: d.u32()?, msg: d.str()? },
            13 => Response::FileMeta { version: d.u64()?, size: d.u64()?, digests: d.i32_vec()? },
            14 => {
                let version = d.u64()?;
                let n = d.varint()? as usize;
                let mut extents = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    extents.push(BlockExtent {
                        index: d.u32()?,
                        data: d.bytes()?.to_vec(),
                        digest: d.i32()?,
                    });
                }
                Response::FileBlocks { version, extents }
            }
            15 => {
                if depth > 0 {
                    return Err(ProtoError("nested CompoundReply".into()));
                }
                let n = d.varint()? as usize;
                let mut replies = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    replies.push(Response::decode_depth(d.bytes()?, depth + 1)?);
                }
                Response::CompoundReply { replies }
            }
            16 => Response::ReplicaAck { watermark: d.u64()? },
            17 => Response::Watermark { shard: d.u32()?, watermark: d.u64()? },
            18 => Response::Promoted { watermark: d.u64()? },
            19 => Response::ReplicaNeed { digests: decode_digest_list(&mut d)? },
            20 => Response::ChunkAck { stored: d.u64()? },
            21 => Response::SnapshotCreated { id: d.u64()? },
            22 => {
                let n = d.varint()? as usize;
                let mut chunks = Vec::with_capacity(n.min(1 << 20));
                for _ in 0..n {
                    chunks.push(d.bytes()?.to_vec());
                }
                Response::ChunkFill { chunks }
            }
            t => return Err(ProtoError(format!("bad Response tag {t}"))),
        };
        d.expect_end()?;
        Ok(resp)
    }

    /// Approximate wire size for the WAN model.
    pub fn wire_bytes(&self) -> u64 {
        self.encode().len() as u64 + 16
    }
}

/// Change notifications pushed over the callback channel (server->client).
#[derive(Debug, Clone, PartialEq)]
pub enum NotifyEvent {
    /// Path content/attrs changed at the home space; cached copy invalid.
    Invalidate { path: String, new_version: u64 },
    /// Path removed at the home space.
    Removed { path: String },
    /// Server restarting: client must re-register its callback.
    ServerRestart,
}

impl NotifyEvent {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Encoder::new();
        self.encode_into(&mut e);
        e.into_bytes()
    }

    /// Encode into an existing [`Encoder`] (reactor callback pump).
    pub fn encode_into(&self, e: &mut Encoder) {
        match self {
            NotifyEvent::Invalidate { path, new_version } => {
                e.u8(0).str(path).u64(*new_version);
            }
            NotifyEvent::Removed { path } => {
                e.u8(1).str(path);
            }
            NotifyEvent::ServerRestart => {
                e.u8(2);
            }
        }
    }

    pub fn decode(buf: &[u8]) -> Result<Self, ProtoError> {
        let mut d = Decoder::new(buf);
        let ev = match d.u8()? {
            0 => NotifyEvent::Invalidate { path: d.str()?, new_version: d.u64()? },
            1 => NotifyEvent::Removed { path: d.str()? },
            2 => NotifyEvent::ServerRestart,
            t => return Err(ProtoError(format!("bad NotifyEvent tag {t}"))),
        };
        d.expect_end()?;
        Ok(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn attr() -> WireAttr {
        WireAttr { kind: NodeKind::File, size: 1234, mtime_ns: 5_000_000, mode: 0o600, version: 7 }
    }

    #[test]
    fn request_roundtrip_all_variants() {
        let reqs = vec![
            Request::AuthHello { key_id: "k1".into() },
            Request::AuthProof { key_id: "k1".into(), proof: vec![1, 2, 3] },
            Request::Stat { path: "/a/b".into() },
            Request::ReadDir { path: "/a".into() },
            Request::Fetch { path: "/a/big.dat".into(), min_version: 0 },
            Request::Fetch { path: "/a/big.dat".into(), min_version: 42 },
            Request::Apply { seq: 9, op: MetaOp::Mkdir { path: "/x".into() } },
            Request::RegisterCallback { root: "/a".into(), client_id: 3 },
            Request::LockAcquire { path: "/f".into(), kind: LockKind::Exclusive, owner: 5 },
            Request::LockRenew { token: 11, owner: 5 },
            Request::LockRelease { token: 11, owner: 5 },
            Request::Ping,
            Request::FetchMeta { path: "/a/big.dat".into(), min_version: 0 },
            Request::FetchMeta { path: "/a/big.dat".into(), min_version: 9 },
            Request::FetchRange { path: "/a/big.dat".into(), offset: 65536, len: 65536, expect_version: 4 },
            Request::Compound { ops: vec![] },
            Request::Compound {
                ops: vec![
                    CompoundOp::Apply { seq: 1, op: MetaOp::Mkdir { path: "/d".into() } },
                    CompoundOp::Apply {
                        seq: 2,
                        op: MetaOp::WriteFull { path: "/f".into(), data: vec![9; 40], digests: vec![3], base_version: 0 },
                    },
                    CompoundOp::Stat { path: "/f".into() },
                ],
            },
            Request::Replicate { from: 7, frames: vec![0xAB; 48], head: 55 },
            Request::WatermarkQuery { shard: 3 },
            Request::WatermarkQuery { shard: u32::MAX },
            Request::Promote,
            Request::ChunkPush { chunks: vec![] },
            Request::ChunkPush { chunks: vec![vec![1; 64], vec![], vec![2; 7]] },
            Request::SnapshotCreate,
            Request::ChunkFetch { digests: vec![] },
            Request::ChunkFetch { digests: vec![[0x5A; 32], [0xC3; 32]] },
        ];
        for r in reqs {
            let b = r.encode();
            assert_eq!(Request::decode(&b).unwrap(), r, "{r:?}");
            assert!(r.wire_bytes() >= b.len() as u64);
        }
    }

    #[test]
    fn response_roundtrip_all_variants() {
        let resps = vec![
            Response::Challenge { nonce: vec![9; 32] },
            Response::AuthOk { session: 77 },
            Response::AuthFail,
            Response::Attr { attr: attr() },
            Response::Dir {
                entries: vec![
                    DirEntry { name: "f1".into(), attr: attr() },
                    DirEntry { name: "sub".into(), attr: WireAttr { kind: NodeKind::Dir, ..attr() } },
                ],
            },
            Response::File {
                image: FileImage {
                    path: "/a".into(),
                    version: 3,
                    data: vec![0xAB; 100],
                    digests: vec![1, -2],
                },
            },
            Response::Applied { seq: 4, new_version: 8 },
            Response::CallbackRegistered,
            Response::LockGranted { token: 6, lease_ns: 30_000_000_000 },
            Response::LockDenied { holder: 2 },
            Response::Released,
            Response::Pong,
            Response::Err { code: 2, msg: "no such file".into() },
            Response::FileMeta { version: 9, size: 1 << 20, digests: vec![3, -4, 5] },
            Response::FileBlocks { version: 9, extents: vec![] },
            Response::FileBlocks {
                version: 9,
                extents: vec![
                    BlockExtent { index: 3, data: vec![0x7F; 333], digest: -77 },
                    BlockExtent { index: 4, data: vec![0x11; 64], digest: 12 },
                ],
            },
            Response::CompoundReply { replies: vec![] },
            Response::CompoundReply {
                replies: vec![
                    Response::Applied { seq: 1, new_version: 2 },
                    Response::Err { code: 2, msg: "no such file".into() },
                    Response::Attr { attr: attr() },
                ],
            },
            Response::ReplicaAck { watermark: 41 },
            Response::Watermark { shard: 2, watermark: 17 },
            Response::Promoted { watermark: 99 },
            Response::ReplicaNeed { digests: vec![] },
            Response::ReplicaNeed { digests: vec![[0xAB; 32], [0x01; 32]] },
            Response::ChunkAck { stored: 12 },
            Response::SnapshotCreated { id: 42 },
            Response::ChunkFill { chunks: vec![] },
            Response::ChunkFill { chunks: vec![vec![9; 48], vec![], vec![7; 3]] },
        ];
        for r in resps {
            let b = r.encode();
            assert_eq!(Response::decode(&b).unwrap(), r, "{r:?}");
        }
    }

    #[test]
    fn metaop_roundtrip_all_variants() {
        let ops = vec![
            MetaOp::Mkdir { path: "/d".into() },
            MetaOp::Rmdir { path: "/d".into() },
            MetaOp::Create { path: "/f".into() },
            MetaOp::Unlink { path: "/f".into() },
            MetaOp::Rename { from: "/a".into(), to: "/b".into() },
            MetaOp::Truncate { path: "/f".into(), size: 42 },
            MetaOp::SetMode { path: "/f".into(), mode: 0o644 },
            MetaOp::WriteFull { path: "/f".into(), data: vec![7; 9], digests: vec![5], base_version: 7 },
            MetaOp::WriteDelta {
                path: "/f".into(),
                total_size: 200,
                base_version: 3,
                blocks: vec![(0, vec![1; 64]), (2, vec![2; 8])],
                digests: vec![10, 20, 30],
            },
            MetaOp::WriteRef {
                path: "/f".into(),
                size: 130,
                chunks: vec![[0x11; 32], [0x22; 32], [0x33; 32]],
                digests: vec![5, -6],
                base_version: 4,
            },
        ];
        for op in ops {
            let b = op.encode();
            assert_eq!(MetaOp::decode(&b).unwrap(), op, "{op:?}");
        }
    }

    #[test]
    fn write_ref_digest_blob_validated() {
        let op = MetaOp::WriteRef {
            path: "/f".into(),
            size: 64,
            chunks: vec![[7; 32]],
            digests: vec![1],
            base_version: 0,
        };
        let b = op.encode();
        assert_eq!(MetaOp::decode(&b).unwrap(), op);
        for cut in 0..b.len() {
            assert!(MetaOp::decode(&b[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        // a blob that is not a multiple of 32 bytes is torn, not padded
        let mut e = Encoder::new();
        e.u8(9).str("/f").u64(64).bytes(&[7u8; 31]).i32_slice(&[1]).u64(0);
        assert!(MetaOp::decode(&e.into_bytes()).is_err());
    }

    #[test]
    fn repl_record_roundtrip_all_variants() {
        let recs = vec![
            ReplRecord {
                ship_seq: 1,
                shard: 0,
                payload: ReplPayload::Op {
                    client_id: 3,
                    seq: 9,
                    new_version: 4,
                    op: MetaOp::WriteFull {
                        path: "/f".into(),
                        data: vec![1; 30],
                        digests: vec![7],
                        base_version: 2,
                    },
                },
            },
            ReplRecord {
                ship_seq: 2,
                shard: 5,
                payload: ReplPayload::Failed { client_id: 3, seq: 10, path: "/ghost".into() },
            },
            ReplRecord {
                ship_seq: 3,
                shard: 1,
                payload: ReplPayload::Local { op: MetaOp::Unlink { path: "/gone".into() } },
            },
        ];
        for rec in recs {
            let b = rec.encode();
            assert_eq!(ReplRecord::decode(&b).unwrap(), rec, "{rec:?}");
            // truncations error, never panic
            for cut in 0..b.len() {
                assert!(ReplRecord::decode(&b[..cut]).is_err(), "prefix of {cut} bytes accepted");
            }
        }
    }

    #[test]
    fn notify_roundtrip() {
        for ev in [
            NotifyEvent::Invalidate { path: "/f".into(), new_version: 9 },
            NotifyEvent::Removed { path: "/g".into() },
            NotifyEvent::ServerRestart,
        ] {
            let b = ev.encode();
            assert_eq!(NotifyEvent::decode(&b).unwrap(), ev);
        }
    }

    #[test]
    fn corrupted_messages_rejected() {
        let mut b = Request::Stat { path: "/a".into() }.encode();
        b[0] = 0xFF;
        assert!(Request::decode(&b).is_err());
        let b = Response::Pong.encode();
        assert!(Response::decode(&b[..0]).is_err());
        let mut b = Response::AuthOk { session: 1 }.encode();
        b.push(0); // trailing byte
        assert!(Response::decode(&b).is_err());
    }

    #[test]
    fn metaop_wire_bytes_accounting() {
        let full = MetaOp::WriteFull { path: "/f".into(), data: vec![0; 1000], digests: vec![], base_version: 0 };
        assert_eq!(full.wire_bytes(), 1064);
        let delta = MetaOp::WriteDelta {
            path: "/f".into(),
            total_size: 0,
            base_version: 0,
            blocks: vec![(0, vec![0; 100])],
            digests: vec![],
        };
        assert_eq!(delta.wire_bytes(), 172);
        assert_eq!(MetaOp::Mkdir { path: "/d".into() }.wire_bytes(), 64);
    }

    #[test]
    fn encode_compound_applies_matches_owned_encoding() {
        let ops = vec![
            (4u64, MetaOp::Mkdir { path: "/d".into() }),
            (5u64, MetaOp::WriteFull { path: "/f".into(), data: vec![9; 100], digests: vec![1, 2], base_version: 2 }),
        ];
        let owned = Request::Compound {
            ops: ops
                .iter()
                .map(|(seq, op)| CompoundOp::Apply { seq: *seq, op: op.clone() })
                .collect(),
        };
        assert_eq!(Request::encode_compound_applies(&ops), owned.encode());
    }

    #[test]
    fn compound_wire_bytes_accounting() {
        let apply = CompoundOp::Apply {
            seq: 1,
            op: MetaOp::WriteFull { path: "/f".into(), data: vec![0; 1000], digests: vec![], base_version: 0 },
        };
        assert_eq!(apply.wire_bytes(), 1072);
        assert_eq!(CompoundOp::Stat { path: "/f".into() }.wire_bytes(), 64);
    }

    #[test]
    fn corrupted_compound_rejected() {
        let mut b = Request::Compound {
            ops: vec![CompoundOp::Apply { seq: 1, op: MetaOp::Mkdir { path: "/d".into() } }],
        }
        .encode();
        b[2] = 0xFF; // bad CompoundOp tag
        assert!(Request::decode(&b).is_err());
        let mut b = Response::CompoundReply { replies: vec![Response::Pong] }.encode();
        b.truncate(b.len() - 1); // short inner reply
        assert!(Response::decode(&b).is_err());
    }

    #[test]
    fn nested_compound_reply_rejected_not_recursed() {
        // a hostile peer can nest CompoundReply a few bytes per level to
        // attack the decode stack; the codec refuses any nesting (the
        // server never produces it), bounding recursion at depth 1
        let mut frame = Response::Pong.encode();
        for _ in 0..2_000 {
            let mut e = Encoder::new();
            e.u8(15).varint(1).bytes(&frame);
            frame = e.into_bytes();
        }
        assert!(Response::decode(&frame).is_err(), "deep nest must error, not overflow");
        // one level of nesting is equally a protocol violation...
        let mut e = Encoder::new();
        e.u8(15).varint(1).bytes(&Response::CompoundReply { replies: vec![] }.encode());
        assert!(Response::decode(&e.into_bytes()).is_err());
        // ...while a flat reply still decodes
        let flat = Response::CompoundReply { replies: vec![Response::Pong] };
        assert_eq!(Response::decode(&flat.encode()).unwrap(), flat);
    }

    #[test]
    fn fetch_range_rejects_every_truncation() {
        // the paged data plane's request: every strict prefix of the
        // frame must decode to an error, never panic or mis-parse
        let b = Request::FetchRange {
            path: "/a/big.dat".into(),
            offset: 3 << 20,
            len: 1 << 20,
            expect_version: 42,
        }
        .encode();
        assert!(Request::decode(&b).is_ok());
        for cut in 0..b.len() {
            assert!(Request::decode(&b[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
    }

    #[test]
    fn file_blocks_rejects_truncation_and_corruption() {
        let resp = Response::FileBlocks {
            version: 7,
            extents: vec![
                BlockExtent { index: 0, data: vec![0xAA; 100], digest: 5 },
                BlockExtent { index: 1, data: vec![0xBB; 50], digest: -6 },
            ],
        };
        let b = resp.encode();
        assert_eq!(Response::decode(&b).unwrap(), resp);
        // every strict prefix is a decode error (truncated frame)
        for cut in 0..b.len() {
            assert!(Response::decode(&b[..cut]).is_err(), "prefix of {cut} bytes accepted");
        }
        // an absurd extent-count claim is rejected, not allocated
        let mut e = Encoder::new();
        e.u8(14).u64(7).varint(u64::MAX);
        assert!(Response::decode(&e.into_bytes()).is_err());
        // flipping the inner length prefix corrupts the frame
        let mut bad = b.clone();
        bad[9] = 0xFF; // extent count varint -> continuation byte
        assert!(Response::decode(&bad).is_err());
    }

    #[test]
    fn metaop_path_helper() {
        assert_eq!(MetaOp::Rename { from: "/a".into(), to: "/b".into() }.path(), "/a");
        assert_eq!(MetaOp::Unlink { path: "/x".into() }.path(), "/x");
    }
}
