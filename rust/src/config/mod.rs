//! Configuration system.
//!
//! Typed config structs for every subsystem plus a TOML-subset parser
//! (`[section]`, `key = value` with strings/ints/floats/bools) so
//! deployments are driven by a config file (`xufs.toml`) rather than code.
//! Defaults reproduce the paper's testbed calibration (DESIGN.md §5).

mod toml;

pub use toml::{TomlDoc, TomlError, TomlValue};

/// Bytes per stripe block (paper §3.3: minimum 64 KiB block size).
pub const STRIPE_BLOCK: u64 = 64 * 1024;

/// WAN link model parameters (DESIGN.md §5 calibration).
#[derive(Debug, Clone, PartialEq)]
pub struct WanConfig {
    /// Round-trip time between client site and home space, seconds.
    pub rtt_s: f64,
    /// Per-TCP-stream throughput cap, bytes/sec (window/RTT bound;
    /// 64 KiB window / 32 ms = 2 MiB/s — 2005-era default TCP tuning).
    pub per_stream_bps: f64,
    /// Aggregate link capacity, bytes/sec (TeraGrid: 30 Gbps).
    pub agg_bps: f64,
    /// Round trips consumed by connection setup + auth handshake.
    pub setup_rtts: f64,
    /// Extra RTTs lost to TCP slow-start ramp on a fresh connection.
    pub slow_start_rtts: f64,
}

impl Default for WanConfig {
    fn default() -> Self {
        WanConfig {
            rtt_s: 0.032,
            per_stream_bps: 2.0 * 1024.0 * 1024.0,
            agg_bps: 30.0e9 / 8.0,
            setup_rtts: 3.0,
            slow_start_rtts: 4.0,
        }
    }
}

/// Striped-transfer engine parameters (paper §3.3).
#[derive(Debug, Clone, PartialEq)]
pub struct StripeConfig {
    /// Maximum parallel TCP stripes per transfer (paper: 12).
    pub max_stripes: usize,
    /// Minimum bytes per stripe block (paper: 64 KiB).
    pub min_block: u64,
    /// Threshold above which transfers are striped (paper: 64 KiB).
    pub stripe_threshold: u64,
    /// Parallel pre-fetch threads for small files (paper: 12).
    pub prefetch_threads: usize,
    /// Pre-fetch files smaller than this on first chdir (paper: 64 KiB).
    pub prefetch_max_size: u64,
    /// Enable pre-fetching at all (ablation toggle).
    pub prefetch_enabled: bool,
    /// Ship only digest-dirty blocks on writeback (delta writeback; see
    /// DESIGN.md §3 — the runtime/PJRT-planned optimization).
    pub delta_writeback: bool,
}

impl Default for StripeConfig {
    fn default() -> Self {
        StripeConfig {
            max_stripes: 12,
            min_block: STRIPE_BLOCK,
            stripe_threshold: STRIPE_BLOCK,
            prefetch_threads: 12,
            prefetch_max_size: STRIPE_BLOCK,
            prefetch_enabled: true,
            delta_writeback: true,
        }
    }
}

/// How the data plane picks its stripe count (transport v2,
/// DESIGN.md §2.12).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StripesMode {
    /// The size-based static plan: `transfer::stripes_for` splits the
    /// payload into `[stripe]`-sized shares up to `max_stripes`.
    #[default]
    Planned,
    /// Force exactly this many stripes for every striped transfer
    /// (clamped to `[1, stripe.max_stripes]`).
    Fixed(usize),
    /// Adaptive: a per-mount `transfer::AutoTuner` grows/shrinks the
    /// count between extents from observed per-stream goodput.
    Auto,
}

/// Transport-v2 knobs (`[transfer]`, DESIGN.md §2.12). All three
/// features default off/static: the v1 data plane stays bit- and
/// timing-identical unless a deployment opts in.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferConfig {
    /// Stripe-count policy (`stripes = auto` or an integer; absent =
    /// the static size-based plan).
    pub stripes: StripesMode,
    /// Pipelined readahead: speculatively issue the next readahead
    /// extent before the application blocks on it.
    pub pipeline: bool,
    /// Maximum speculative fetches in flight per mount.
    pub pipeline_window: usize,
    /// Delta-compress `WriteDelta` block payloads (RLE + rolling-hash
    /// LZ; incompressible blocks ship in the legacy raw form).
    pub compress: bool,
}

impl Default for TransferConfig {
    fn default() -> Self {
        TransferConfig {
            stripes: StripesMode::Planned,
            pipeline: false,
            pipeline_window: 1,
            compress: false,
        }
    }
}

/// Client cache-space parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheConfig {
    /// Capacity of the cache space in bytes (TeraGrid work partitions are
    /// huge; default 1 TiB so eviction is rare, as the paper assumes).
    pub capacity: u64,
    /// Directories whose new files stay local and are never shipped home
    /// (paper's *localized directories*).
    pub localized_dirs: Vec<String>,
    /// Budget for resident cached content, in bytes. When exceeded, the
    /// cache evicts least-recently-used *clean* blocks (never dirty ones)
    /// until it fits; entries whose last block goes demote to `AttrOnly`.
    /// 0 = unbudgeted (the default — the paper assumes a huge work
    /// partition).
    pub budget_bytes: u64,
    /// Demand-paging readahead window in blocks: a `pread` fault pulls
    /// the missing blocks of the requested range plus this many blocks
    /// beyond it (32 blocks = 2 MiB at the default 64 KiB block).
    pub readahead_blocks: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity: 1 << 40,
            localized_dirs: Vec::new(),
            budget_bytes: 0,
            readahead_blocks: 32,
        }
    }
}

/// Lease manager parameters (paper §3.1: leases prevent orphaned locks).
#[derive(Debug, Clone, PartialEq)]
pub struct LeaseConfig {
    /// Lease duration granted by the server, seconds.
    pub duration_s: f64,
    /// Client renews after this fraction of the lease has elapsed.
    pub renew_fraction: f64,
}

impl Default for LeaseConfig {
    fn default() -> Self {
        LeaseConfig { duration_s: 30.0, renew_fraction: 0.5 }
    }
}

/// Fault-plane profile (DESIGN.md §2.5): per-interaction probabilities
/// and schedule bounds for the seeded `simnet::FaultPlan`. Disabled (all
/// clean) by default — the schedule explorer and chaos configs turn it
/// on. Probabilities are per WAN interaction.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Master switch; `false` makes every interaction clean.
    pub enabled: bool,
    /// Request lost before the server sees it.
    pub drop_request_p: f64,
    /// Server applies the request but the reply is lost (the
    /// idempotent-replay case).
    pub drop_reply_p: f64,
    /// Request delivered twice.
    pub duplicate_p: f64,
    /// Extra queueing delay before clean delivery.
    pub delay_p: f64,
    /// Upper bound on the injected delay, milliseconds.
    pub delay_max_ms: u32,
    /// Bulk transfer torn mid-flight (resume or `Interrupted`).
    pub interrupt_p: f64,
    /// A partition starts at this interaction.
    pub partition_p: f64,
    /// Partition length bound, in interactions.
    pub partition_max_steps: u32,
    /// Server process crashes at this interaction.
    pub server_crash_p: f64,
    /// Crashed server restarts within this many interactions.
    pub server_crash_max_steps: u32,
    /// The harness is asked to crash+recover a client.
    pub client_crash_p: f64,
    /// Given a server crash fired, the probability the schedule also
    /// decides to PROMOTE the secondary instead of waiting out the
    /// restart (replicated topologies only; the harness acts on the
    /// surfaced event). The crashed primary still restarts on schedule —
    /// fenced, so clients must fail over.
    pub promote_after_crash_p: f64,
    /// Silent bit rot: one byte of one persisted artifact (chunk store,
    /// cache files, or op log — the die also picks which) is flipped at
    /// this interaction. The harness acts on the surfaced
    /// `FaultEvent::CorruptByte`; the integrity plane (DESIGN.md §2.10)
    /// must detect it — invariant I5: never wrong data, never a panic.
    pub corrupt_p: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enabled: false,
            drop_request_p: 0.0,
            drop_reply_p: 0.0,
            duplicate_p: 0.0,
            delay_p: 0.0,
            delay_max_ms: 100,
            interrupt_p: 0.0,
            partition_p: 0.0,
            partition_max_steps: 16,
            server_crash_p: 0.0,
            server_crash_max_steps: 24,
            client_crash_p: 0.0,
            promote_after_crash_p: 0.0,
            corrupt_p: 0.0,
        }
    }
}

/// Home-server replication parameters (DESIGN.md §2.7). Off by default:
/// the paper's deployment is a lone user-space server restarted by
/// crontab; `[replica] enabled` stands up the warm secondary the
/// fault explorer and failover bench exercise.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaConfig {
    /// Master switch: record the applied-op log on the primary, stand up
    /// the secondary, and hand clients both endpoints.
    pub enabled: bool,
    /// Records per `Replicate` frame (one WAN round trip each).
    pub ship_batch: usize,
    /// Shipping target: the coordinator's replication tick drains the
    /// log whenever the secondary trails the primary by at least this
    /// many applied ops (quiesce/promote always drain fully). Smaller =
    /// tighter lag = less promote-time catch-up.
    pub max_lag_ops: u64,
    /// How many secondaries to stand up (DESIGN.md §2.11). The first is
    /// the promotion target; all of them ingest the same shipped log.
    pub secondaries: usize,
    /// Read fan-out switch: when on, secondaries serve read-only traffic
    /// (`Stat`/`ReadDir`/`Fetch`/`FetchMeta`/`FetchRange`) at their
    /// replication watermark and clients route reads to the
    /// lowest-RTT replica, falling back to the primary on `TooStale`.
    pub read_fanout: bool,
    /// Bounded-staleness window for serving secondaries: a replica whose
    /// replication watermark trails the primary's last-announced log
    /// head by more than this many applied ops refuses reads with code
    /// 119 `TooStale` until shipping catches it back up.
    pub staleness_ops: u64,
}

impl Default for ReplicaConfig {
    fn default() -> Self {
        ReplicaConfig {
            enabled: false,
            ship_batch: 64,
            max_lag_ops: 8,
            secondaries: 1,
            read_fanout: false,
            staleness_ops: 64,
        }
    }
}

/// Content-addressed chunk store parameters (DESIGN.md §2.8). Governs
/// the HOME servers only — client cache disks and baselines stay dense.
/// Enabled by default: the meta/data split is the substrate the dedup,
/// snapshot and replication-by-reference features all ride on.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkstoreConfig {
    /// Master switch: run home-server `FileStore`s over the
    /// content-addressed chunk store. `false` reproduces the dense
    /// PR ≤5 substrate (the ablation baseline).
    pub enabled: bool,
    /// Chunk size in KiB (default matches the 64 KiB stripe block, so a
    /// delta-writeback block maps onto exactly one chunk).
    pub chunk_kib: usize,
    /// Sweep dead chunks after this many applied mutations (deferred GC;
    /// dead bytes are retained — and resurrectable — between sweeps).
    pub gc_interval_ops: u64,
    /// Live snapshots retained per server; taking one beyond this evicts
    /// the oldest (releasing its chunk pins).
    pub snapshot_retention: usize,
}

impl Default for ChunkstoreConfig {
    fn default() -> Self {
        ChunkstoreConfig {
            enabled: true,
            chunk_kib: 64,
            gc_interval_ops: 128,
            snapshot_retention: 8,
        }
    }
}

/// Integrity-plane parameters (DESIGN.md §2.10).
#[derive(Debug, Clone, PartialEq)]
pub struct IntegrityConfig {
    /// Run one background scrub slice (digest-verify a bounded chunk of
    /// the chunk table, quarantine mismatches, and attempt repair from
    /// the replica) every this many applied server ops — the same
    /// cadence mechanism as `chunkstore.gc_interval_ops`. `0` disables
    /// the background scrubber; verified reads still refuse rot.
    pub scrub_interval_ops: u64,
    /// Chunks verified per scrub tick (bounds per-tick latency; a full
    /// store scrub amortizes across ticks via a wrapping cursor).
    pub scrub_batch: usize,
}

impl Default for IntegrityConfig {
    fn default() -> Self {
        IntegrityConfig { scrub_interval_ops: 64, scrub_batch: 32 }
    }
}

/// File-server concurrency parameters (DESIGN.md §2.6, §2.9).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Namespace shard count: per-path server state (digest cache, lock
    /// table, replay watermarks, callback fanout) splits into this many
    /// independently locked shards, routed by canonical-path hash.
    /// `1` reproduces the old single-lock server (the scale ablation
    /// baseline); the default 8 matches the paper's many-client claim.
    pub shards: usize,
    /// Reactor thread count; `0` means one per available core.
    pub reactor_threads: usize,
    /// Admission control: connections beyond this are refused with the
    /// typed busy code (117) instead of queueing unboundedly.
    pub max_connections: usize,
    /// Requests served per connection per drain round; pipelined frames
    /// beyond this are answered with the typed busy code (117).
    pub max_inflight_per_conn: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            shards: 8,
            reactor_threads: 0,
            max_connections: 1024,
            max_inflight_per_conn: 32,
        }
    }
}

/// Disk / parallel-FS models for each side (DESIGN.md §5).
#[derive(Debug, Clone, PartialEq)]
pub struct DiskConfig {
    /// Sequential bandwidth of the cache-space parallel FS, bytes/sec.
    pub cache_bps: f64,
    /// Per-operation cost of the cache-space FS, seconds.
    pub cache_op_s: f64,
    /// Sequential bandwidth of the home-space disk, bytes/sec.
    pub home_bps: f64,
    /// Per-operation cost of the home-space disk, seconds.
    pub home_op_s: f64,
    /// Client CPU digest/verification throughput, bytes/sec (2005-era
    /// checksum rate; charged on fetch verification and writeback
    /// planning).
    pub digest_cpu_bps: f64,
}

impl Default for DiskConfig {
    fn default() -> Self {
        DiskConfig {
            cache_bps: 400.0 * 1024.0 * 1024.0,
            cache_op_s: 0.002,
            home_bps: 200.0 * 1024.0 * 1024.0,
            home_op_s: 0.002,
            digest_cpu_bps: 300.0 * 1024.0 * 1024.0,
        }
    }
}

/// Everything the coordinator needs to stand up a deployment.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct XufsConfig {
    pub wan: WanConfig,
    pub stripe: StripeConfig,
    pub cache: CacheConfig,
    pub lease: LeaseConfig,
    pub disk: DiskConfig,
    pub fault: FaultConfig,
    pub server: ServerConfig,
    pub replica: ReplicaConfig,
    pub chunkstore: ChunkstoreConfig,
    pub integrity: IntegrityConfig,
    pub transfer: TransferConfig,
    /// Directory holding AOT HLO artifacts (empty => native digest engine).
    pub artifacts_dir: String,
    /// Deterministic seed for workloads / jitter.
    pub seed: u64,
}

impl XufsConfig {
    /// Parse a TOML-subset config file's contents over the defaults.
    /// Unknown keys are rejected (typo safety).
    pub fn from_toml(text: &str) -> Result<XufsConfig, TomlError> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = XufsConfig::default();
        for (section, key, value) in doc.entries() {
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            match full.as_str() {
                "wan.rtt_ms" => cfg.wan.rtt_s = value.as_f64()? / 1e3,
                "wan.per_stream_mibps" => cfg.wan.per_stream_bps = value.as_f64()? * 1024.0 * 1024.0,
                "wan.agg_gbps" => cfg.wan.agg_bps = value.as_f64()? * 1e9 / 8.0,
                "wan.setup_rtts" => cfg.wan.setup_rtts = value.as_f64()?,
                "wan.slow_start_rtts" => cfg.wan.slow_start_rtts = value.as_f64()?,
                "stripe.max_stripes" => cfg.stripe.max_stripes = value.as_usize()?,
                "stripe.min_block_kib" => cfg.stripe.min_block = value.as_u64()? * 1024,
                "stripe.stripe_threshold_kib" => cfg.stripe.stripe_threshold = value.as_u64()? * 1024,
                "stripe.prefetch_threads" => cfg.stripe.prefetch_threads = value.as_usize()?,
                "stripe.prefetch_max_size_kib" => cfg.stripe.prefetch_max_size = value.as_u64()? * 1024,
                "stripe.prefetch_enabled" => cfg.stripe.prefetch_enabled = value.as_bool()?,
                "stripe.delta_writeback" => cfg.stripe.delta_writeback = value.as_bool()?,
                "cache.capacity_gib" => cfg.cache.capacity = value.as_u64()? << 30,
                "cache.budget_bytes" => cfg.cache.budget_bytes = value.as_u64()?,
                "cache.readahead_blocks" => cfg.cache.readahead_blocks = value.as_u64()?,
                "cache.localized_dirs" => {
                    cfg.cache.localized_dirs =
                        value.as_str()?.split(':').filter(|s| !s.is_empty()).map(String::from).collect()
                }
                "lease.duration_s" => cfg.lease.duration_s = value.as_f64()?,
                "lease.renew_fraction" => cfg.lease.renew_fraction = value.as_f64()?,
                "disk.cache_mibps" => cfg.disk.cache_bps = value.as_f64()? * 1024.0 * 1024.0,
                "disk.cache_op_ms" => cfg.disk.cache_op_s = value.as_f64()? / 1e3,
                "disk.home_mibps" => cfg.disk.home_bps = value.as_f64()? * 1024.0 * 1024.0,
                "disk.home_op_ms" => cfg.disk.home_op_s = value.as_f64()? / 1e3,
                "disk.digest_cpu_mibps" => cfg.disk.digest_cpu_bps = value.as_f64()? * 1024.0 * 1024.0,
                "fault.enabled" => cfg.fault.enabled = value.as_bool()?,
                "fault.drop_request_p" => cfg.fault.drop_request_p = value.as_f64()?,
                "fault.drop_reply_p" => cfg.fault.drop_reply_p = value.as_f64()?,
                "fault.duplicate_p" => cfg.fault.duplicate_p = value.as_f64()?,
                "fault.delay_p" => cfg.fault.delay_p = value.as_f64()?,
                "fault.delay_max_ms" => cfg.fault.delay_max_ms = value.as_u64()? as u32,
                "fault.interrupt_p" => cfg.fault.interrupt_p = value.as_f64()?,
                "fault.partition_p" => cfg.fault.partition_p = value.as_f64()?,
                "fault.partition_max_steps" => cfg.fault.partition_max_steps = value.as_u64()? as u32,
                "fault.server_crash_p" => cfg.fault.server_crash_p = value.as_f64()?,
                "fault.server_crash_max_steps" => {
                    cfg.fault.server_crash_max_steps = value.as_u64()? as u32
                }
                "fault.client_crash_p" => cfg.fault.client_crash_p = value.as_f64()?,
                "fault.promote_after_crash_p" => {
                    cfg.fault.promote_after_crash_p = value.as_f64()?
                }
                "fault.corrupt_p" => cfg.fault.corrupt_p = value.as_f64()?,
                "server.shards" => cfg.server.shards = value.as_usize()?.max(1),
                "server.reactor" => {
                    return Err(TomlError::new(
                        0,
                        "`server.reactor` was removed: the thread-per-connection \
                         path is gone and the reactor core (DESIGN.md §2.9) always \
                         serves TCP — delete the key (tune `server.reactor_threads` \
                         instead)",
                    ));
                }
                "server.reactor_threads" => {
                    cfg.server.reactor_threads = value.as_usize()?
                }
                "server.max_connections" => {
                    cfg.server.max_connections = value.as_usize()?.max(1)
                }
                "server.max_inflight_per_conn" => {
                    cfg.server.max_inflight_per_conn = value.as_usize()?.max(1)
                }
                "replica.enabled" => cfg.replica.enabled = value.as_bool()?,
                "replica.ship_batch" => cfg.replica.ship_batch = value.as_usize()?.max(1),
                "replica.max_lag_ops" => cfg.replica.max_lag_ops = value.as_u64()?,
                "replica.secondaries" => {
                    cfg.replica.secondaries = value.as_usize()?.max(1)
                }
                "replica.read_fanout" => cfg.replica.read_fanout = value.as_bool()?,
                "replica.staleness_ops" => cfg.replica.staleness_ops = value.as_u64()?,
                "chunkstore.enabled" => cfg.chunkstore.enabled = value.as_bool()?,
                "chunkstore.chunk_kib" => {
                    cfg.chunkstore.chunk_kib = value.as_usize()?.max(1)
                }
                "chunkstore.gc_interval_ops" => {
                    cfg.chunkstore.gc_interval_ops = value.as_u64()?.max(1)
                }
                "chunkstore.snapshot_retention" => {
                    cfg.chunkstore.snapshot_retention = value.as_usize()?.max(1)
                }
                "integrity.scrub_interval_ops" => {
                    cfg.integrity.scrub_interval_ops = value.as_u64()?
                }
                "integrity.scrub_batch" => {
                    cfg.integrity.scrub_batch = value.as_usize()?.max(1)
                }
                "transfer.stripes" => {
                    cfg.transfer.stripes = match value {
                        TomlValue::Str(s) if s == "auto" => StripesMode::Auto,
                        TomlValue::Str(s) => {
                            return Err(TomlError::new(
                                0,
                                &format!(
                                    "transfer.stripes takes an integer or \"auto\", got \"{s}\""
                                ),
                            ));
                        }
                        // a fixed count of 0 stripes cannot move bytes: clamped
                        other => StripesMode::Fixed(other.as_usize()?.max(1)),
                    }
                }
                "transfer.pipeline" => cfg.transfer.pipeline = value.as_bool()?,
                "transfer.pipeline_window" => {
                    // a zero window would silently disable pipelining: clamped
                    cfg.transfer.pipeline_window = value.as_usize()?.max(1)
                }
                "transfer.compress" => cfg.transfer.compress = value.as_bool()?,
                "artifacts_dir" => cfg.artifacts_dir = value.as_str()?.to_string(),
                "seed" => cfg.seed = value.as_u64()?,
                other => {
                    return Err(TomlError::new(0, &format!("unknown config key `{other}`")));
                }
            }
        }
        Ok(cfg)
    }

    /// GPFS-WAN-era SCP model: single stream, cipher-rate bound.
    pub fn scp_cipher_bps() -> f64 {
        0.5 * 1024.0 * 1024.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_design_calibration() {
        let c = XufsConfig::default();
        assert_eq!(c.stripe.max_stripes, 12);
        assert_eq!(c.stripe.min_block, 64 * 1024);
        assert_eq!(c.stripe.prefetch_threads, 12);
        assert!((c.wan.rtt_s - 0.032).abs() < 1e-12);
        assert!((c.wan.per_stream_bps - 2.0 * 1024.0 * 1024.0).abs() < 1e-6);
    }

    #[test]
    fn parse_overrides() {
        let text = r#"
seed = 7
artifacts_dir = "artifacts"

[wan]
rtt_ms = 60
per_stream_mibps = 4.0

[stripe]
max_stripes = 8
prefetch_enabled = false

[cache]
localized_dirs = "/scratch/out:/scratch/tmp"
"#;
        let c = XufsConfig::from_toml(text).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.artifacts_dir, "artifacts");
        assert!((c.wan.rtt_s - 0.060).abs() < 1e-12);
        assert_eq!(c.stripe.max_stripes, 8);
        assert!(!c.stripe.prefetch_enabled);
        assert_eq!(c.cache.localized_dirs, vec!["/scratch/out", "/scratch/tmp"]);
        // untouched keys keep defaults
        assert!(c.stripe.delta_writeback);
        assert_eq!(c.cache.budget_bytes, 0);
        assert_eq!(c.cache.readahead_blocks, 32);
    }

    #[test]
    fn parse_paging_keys() {
        let text = "[cache]\nbudget_bytes = 1048576\nreadahead_blocks = 8\n";
        let c = XufsConfig::from_toml(text).unwrap();
        assert_eq!(c.cache.budget_bytes, 1 << 20);
        assert_eq!(c.cache.readahead_blocks, 8);
    }

    #[test]
    fn parse_server_keys() {
        let c = XufsConfig::from_toml("[server]\nshards = 4\n").unwrap();
        assert_eq!(c.server.shards, 4);
        // shards = 0 would deadlock routing; it clamps to the ablation value
        let c = XufsConfig::from_toml("[server]\nshards = 0\n").unwrap();
        assert_eq!(c.server.shards, 1);
        assert_eq!(XufsConfig::default().server.shards, 8);
    }

    #[test]
    fn parse_reactor_keys() {
        let text = "[server]\nreactor_threads = 2\n\
                    max_connections = 64\nmax_inflight_per_conn = 4\n";
        let c = XufsConfig::from_toml(text).unwrap();
        assert_eq!(c.server.reactor_threads, 2);
        assert_eq!(c.server.max_connections, 64);
        assert_eq!(c.server.max_inflight_per_conn, 4);
        // zero admission limits would refuse everything; they clamp to 1
        let c = XufsConfig::from_toml("[server]\nmax_connections = 0\n").unwrap();
        assert_eq!(c.server.max_connections, 1);
        let d = XufsConfig::default().server;
        assert_eq!(d.reactor_threads, 0, "0 = one per core");
        assert_eq!(d.max_connections, 1024);
        assert_eq!(d.max_inflight_per_conn, 32);
    }

    #[test]
    fn removed_reactor_key_is_a_hard_error_with_pointer() {
        // the legacy thread-per-connection path is gone; a config still
        // pinning it must fail loudly, not silently flip to the reactor
        for text in ["[server]\nreactor = false\n", "[server]\nreactor = true\n"] {
            let err = XufsConfig::from_toml(text).unwrap_err();
            let msg = format!("{err}");
            assert!(msg.contains("`server.reactor` was removed"), "unhelpful error: {msg}");
            assert!(msg.contains("reactor_threads"), "no pointer to the replacement: {msg}");
        }
    }

    #[test]
    fn parse_fault_keys() {
        let text = "[fault]\nenabled = true\ndrop_reply_p = 0.1\npartition_max_steps = 9\n";
        let c = XufsConfig::from_toml(text).unwrap();
        assert!(c.fault.enabled);
        assert!((c.fault.drop_reply_p - 0.1).abs() < 1e-12);
        assert_eq!(c.fault.partition_max_steps, 9);
        // untouched fault knobs keep their (inert) defaults
        assert_eq!(c.fault.drop_request_p, 0.0);
        assert!(!XufsConfig::default().fault.enabled, "faults must be opt-in");
        // bit-rot injection rides the fault section like the other dice
        let c = XufsConfig::from_toml("[fault]\ncorrupt_p = 0.02\n").unwrap();
        assert!((c.fault.corrupt_p - 0.02).abs() < 1e-12);
        assert_eq!(XufsConfig::default().fault.corrupt_p, 0.0);
    }

    #[test]
    fn parse_integrity_keys() {
        let text = "[integrity]\nscrub_interval_ops = 16\nscrub_batch = 8\n";
        let c = XufsConfig::from_toml(text).unwrap();
        assert_eq!(c.integrity.scrub_interval_ops, 16);
        assert_eq!(c.integrity.scrub_batch, 8);
        // 0 disables the background scrubber (reads still verify)…
        let c = XufsConfig::from_toml("[integrity]\nscrub_interval_ops = 0\n").unwrap();
        assert_eq!(c.integrity.scrub_interval_ops, 0);
        // …but an empty scrub slice would be a silent no-op: clamped
        let c = XufsConfig::from_toml("[integrity]\nscrub_batch = 0\n").unwrap();
        assert_eq!(c.integrity.scrub_batch, 1);
        let d = XufsConfig::default().integrity;
        assert_eq!(d.scrub_interval_ops, 64);
        assert_eq!(d.scrub_batch, 32);
    }

    #[test]
    fn parse_replica_keys() {
        let text = "[replica]\nenabled = true\nship_batch = 16\nmax_lag_ops = 4\n\
                    secondaries = 3\nread_fanout = true\nstaleness_ops = 12\n";
        let c = XufsConfig::from_toml(text).unwrap();
        assert!(c.replica.enabled);
        assert_eq!(c.replica.ship_batch, 16);
        assert_eq!(c.replica.max_lag_ops, 4);
        assert_eq!(c.replica.secondaries, 3);
        assert!(c.replica.read_fanout);
        assert_eq!(c.replica.staleness_ops, 12);
        // replication must be opt-in (the applied-op log costs memory)
        let d = XufsConfig::default();
        assert!(!d.replica.enabled);
        assert_eq!(d.replica.ship_batch, 64);
        // read fan-out is likewise opt-in; one warm standby by default
        assert_eq!(d.replica.secondaries, 1);
        assert!(!d.replica.read_fanout);
        assert_eq!(d.replica.staleness_ops, 64);
        // a zero batch would never make shipping progress: clamped
        let c = XufsConfig::from_toml("[replica]\nship_batch = 0\n").unwrap();
        assert_eq!(c.replica.ship_batch, 1);
        // a replica topology needs at least one secondary: clamped
        let c = XufsConfig::from_toml("[replica]\nsecondaries = 0\n").unwrap();
        assert_eq!(c.replica.secondaries, 1);
        // the promote dice ride the fault section
        let c = XufsConfig::from_toml("[fault]\npromote_after_crash_p = 0.5\n").unwrap();
        assert!((c.fault.promote_after_crash_p - 0.5).abs() < 1e-12);
        assert_eq!(d.fault.promote_after_crash_p, 0.0);
    }

    #[test]
    fn parse_chunkstore_keys() {
        let text =
            "[chunkstore]\nenabled = false\nchunk_kib = 16\ngc_interval_ops = 32\nsnapshot_retention = 3\n";
        let c = XufsConfig::from_toml(text).unwrap();
        assert!(!c.chunkstore.enabled);
        assert_eq!(c.chunkstore.chunk_kib, 16);
        assert_eq!(c.chunkstore.gc_interval_ops, 32);
        assert_eq!(c.chunkstore.snapshot_retention, 3);
        // the split is the default substrate; zero-valued knobs clamp
        let d = XufsConfig::default();
        assert!(d.chunkstore.enabled);
        assert_eq!(d.chunkstore.chunk_kib, 64);
        let c = XufsConfig::from_toml("[chunkstore]\nchunk_kib = 0\ngc_interval_ops = 0\n").unwrap();
        assert_eq!(c.chunkstore.chunk_kib, 1);
        assert_eq!(c.chunkstore.gc_interval_ops, 1);
    }

    #[test]
    fn parse_transfer_keys() {
        let text = "[transfer]\nstripes = \"auto\"\npipeline = true\n\
                    pipeline_window = 3\ncompress = true\n";
        let c = XufsConfig::from_toml(text).unwrap();
        assert_eq!(c.transfer.stripes, StripesMode::Auto);
        assert!(c.transfer.pipeline);
        assert_eq!(c.transfer.pipeline_window, 3);
        assert!(c.transfer.compress);
        // static integer counts are still honored (clamped away from 0)
        let c = XufsConfig::from_toml("[transfer]\nstripes = 6\n").unwrap();
        assert_eq!(c.transfer.stripes, StripesMode::Fixed(6));
        let c = XufsConfig::from_toml("[transfer]\nstripes = 0\n").unwrap();
        assert_eq!(c.transfer.stripes, StripesMode::Fixed(1));
        let c = XufsConfig::from_toml("[transfer]\npipeline_window = 0\n").unwrap();
        assert_eq!(c.transfer.pipeline_window, 1);
        // any other string is a typo, not a silent fallback
        let err = XufsConfig::from_toml("[transfer]\nstripes = \"adaptive\"\n").unwrap_err();
        assert!(format!("{err}").contains("\"auto\""));
        // transport v2 is opt-in: the v1 data plane is the default
        let d = XufsConfig::default().transfer;
        assert_eq!(d.stripes, StripesMode::Planned);
        assert!(!d.pipeline && !d.compress);
        assert_eq!(d.pipeline_window, 1);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(XufsConfig::from_toml("[wan]\nrtt = 5\n").is_err());
        assert!(XufsConfig::from_toml("nonsense = 1\n").is_err());
    }

    #[test]
    fn type_errors_rejected() {
        assert!(XufsConfig::from_toml("[stripe]\nmax_stripes = \"twelve\"\n").is_err());
        assert!(XufsConfig::from_toml("[stripe]\nprefetch_enabled = 3\n").is_err());
    }
}
