//! TOML-subset parser: `[section]` headers and `key = value` pairs with
//! string / integer / float / boolean values, `#` comments, blank lines.
//! Sufficient for `xufs.toml`; arrays/tables-of-tables are out of scope.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str, TomlError> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(TomlError::new(0, &format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64, TomlError> {
        match self {
            TomlValue::Int(i) => Ok(*i as f64),
            TomlValue::Float(f) => Ok(*f),
            other => Err(TomlError::new(0, &format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64, TomlError> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            other => Err(TomlError::new(0, &format!("expected non-negative integer, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize, TomlError> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Result<bool, TomlError> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(TomlError::new(0, &format!("expected bool, got {other:?}"))),
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl TomlError {
    pub fn new(line: usize, msg: &str) -> Self {
        TomlError { line, msg: msg.to_string() }
    }
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error (line {}): {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: ordered `(section, key, value)` triples.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    entries: Vec<(String, String, TomlValue)>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| TomlError::new(lineno, "unterminated section header"))?;
                if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.') {
                    return Err(TomlError::new(lineno, "bad section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| TomlError::new(lineno, "expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return Err(TomlError::new(lineno, "bad key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            doc.entries.push((section.clone(), key.to_string(), value));
        }
        Ok(doc)
    }

    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &TomlValue)> {
        self.entries.iter().map(|(s, k, v)| (s.as_str(), k.as_str(), v))
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries
            .iter()
            .rev() // last assignment wins
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue, TomlError> {
    if text.is_empty() {
        return Err(TomlError::new(lineno, "missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| TomlError::new(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(TomlError::new(lineno, "embedded quote in string"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(TomlError::new(lineno, &format!("unparseable value `{text}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let doc = TomlDoc::parse(
            "top = 1\n[a]\nx = 2.5\ny = \"s\"\nz = true\n[b.c]\nw = -3 # comment\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("a", "x"), Some(&TomlValue::Float(2.5)));
        assert_eq!(doc.get("a", "y"), Some(&TomlValue::Str("s".into())));
        assert_eq!(doc.get("a", "z"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.get("b.c", "w"), Some(&TomlValue::Int(-3)));
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = TomlDoc::parse("# whole line\n\nk = \"a#b\" # trailing\n").unwrap();
        assert_eq!(doc.get("", "k"), Some(&TomlValue::Str("a#b".into())));
    }

    #[test]
    fn last_assignment_wins() {
        let doc = TomlDoc::parse("k = 1\nk = 2\n").unwrap();
        assert_eq!(doc.get("", "k"), Some(&TomlValue::Int(2)));
    }

    #[test]
    fn errors() {
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("novalue =\n").is_err());
        assert!(TomlDoc::parse("bad key = 1\n").is_err());
        assert!(TomlDoc::parse("k = \"unterminated\n").is_err());
        assert!(TomlDoc::parse("k = what\n").is_err());
    }

    #[test]
    fn value_conversions() {
        assert_eq!(TomlValue::Int(5).as_f64().unwrap(), 5.0);
        assert_eq!(TomlValue::Int(5).as_u64().unwrap(), 5);
        assert!(TomlValue::Int(-5).as_u64().is_err());
        assert!(TomlValue::Str("x".into()).as_f64().is_err());
        assert!(TomlValue::Bool(true).as_bool().unwrap());
    }
}
