//! `xufs` — the leader binary: serve a home space over TCP, run the
//! paper's benchmarks, regenerate the census, or self-test a deployment.
//!
//! ```text
//! xufs selftest                      quick end-to-end smoke (sim world)
//! xufs bench <exp> [--quick]         table1|fig2|fig3|fig4|fig5|table2|failover|dedup|fanout|transport|ablations|all
//! xufs census [--seed N]             regenerate Table 1
//! xufs serve [--config xufs.toml]    real TCP file server (demo home space)
//! xufs config                        print the default config as TOML keys
//! ```

use std::sync::{Arc, Mutex};

use xufs::auth::{Authenticator, KeyPair};
use xufs::bench;
use xufs::client::{ServerLink, Vfs};
use xufs::config::XufsConfig;
use xufs::coordinator::net::TcpServer;
use xufs::coordinator::SimWorld;
use xufs::homefs::FileStore;
use xufs::metrics::Metrics;
use xufs::runtime::DigestEngine;
use xufs::server::FileServer;
use xufs::simnet::VirtualTime;
use xufs::util::Rng;
use xufs::vdisk::DiskModel;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flag = |name: &str| args.iter().any(|a| a == name);
    let opt = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };

    let cfg = match opt("--config") {
        Some(path) => match std::fs::read_to_string(&path) {
            Ok(text) => match XufsConfig::from_toml(&text) {
                Ok(mut c) => {
                    if c.artifacts_dir.is_empty() {
                        c.artifacts_dir = "artifacts".into();
                    }
                    c
                }
                Err(e) => {
                    eprintln!("bad config {path}: {e}");
                    std::process::exit(2);
                }
            },
            Err(e) => {
                eprintln!("cannot read {path}: {e}");
                std::process::exit(2);
            }
        },
        None => XufsConfig { artifacts_dir: "artifacts".into(), ..Default::default() },
    };

    match cmd {
        "selftest" => selftest(cfg),
        "bench" => run_bench(cfg, args.get(1).map(String::as_str).unwrap_or("all"), flag("--quick")),
        "census" => {
            let seed = opt("--seed").and_then(|s| s.parse().ok()).unwrap_or(1);
            bench::run_table1(seed).print();
        }
        "serve" => serve(cfg),
        "perf" => perf(cfg),
        "config" => print_config(),
        "metrics-md" => print_metrics_md(),
        _ => {
            println!("{HELP}");
        }
    }
}

const HELP: &str = "\
xufs — wide-area distributed file system (XUFS reproduction)

USAGE:
  xufs selftest                      end-to-end smoke test (sim world)
  xufs bench <exp> [--quick]         table1|fig2|fig3|fig4|fig5|table2|failover|dedup|fanout|transport|ablations|all
  xufs census [--seed N]             regenerate the Table 1 census
  xufs serve [--config xufs.toml]    run the TCP file server (demo home)
  xufs perf                          hot-path microbenchmarks (wall-clock)
  xufs config                        print accepted config keys
  xufs metrics-md                    print METRICS.md (regenerate the doc)
";

/// `METRICS.md` generator: the doc at the repo root is exactly this
/// output (a test in `metrics` keeps them in sync).
fn print_metrics_md() {
    print!("{}", xufs::metrics::names::metrics_md());
}

fn selftest(cfg: XufsConfig) {
    let mut world = SimWorld::new(cfg);
    println!(
        "digest engine: {}",
        if world.engine.is_pjrt() { "PJRT artifacts" } else { "native" }
    );
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
        s.home_mut().write("/home/u/hello.txt", b"selftest content", VirtualTime::ZERO).unwrap();
    });
    let mut c = world.mount("/home/u").expect("mount");
    assert_eq!(c.scan_file("/home/u/hello.txt", 4096).unwrap(), 16);
    c.write_file("/home/u/out.txt", b"written back", 4096).unwrap();
    assert!(world.home(|s| s.home().exists("/home/u/out.txt")));
    world.home(|s| s.local_write("/home/u/hello.txt", b"changed", VirtualTime::from_secs(5.0)).unwrap());
    assert_eq!(c.scan_file("/home/u/hello.txt", 4096).unwrap(), 7);
    c.link_mut().set_network(false);
    assert!(c.scan_file("/home/u/hello.txt", 4096).is_ok());
    c.link_mut().set_network(true);
    c.link_mut().reconnect().unwrap();
    c.fsync().unwrap();
    println!("selftest OK  (metrics: {})", c.metrics().to_json());
}

fn run_bench(cfg: XufsConfig, which: &str, quick: bool) {
    match which {
        "table1" => bench::run_table1(cfg.seed.max(1)).print(),
        "fig2" | "fig3" => {
            let (w, r) = bench::run_fig2_fig3(&cfg, quick);
            if which == "fig2" {
                w.print()
            } else {
                r.print()
            }
        }
        "fig4" => bench::run_fig4(&cfg, 5).print(),
        "failover" => bench::run_failover(&cfg).print(),
        "dedup" => bench::run_dedup(&cfg).print(),
        "fanout" => bench::run_read_fanout(&cfg).print(),
        "transport" => bench::run_transport(&cfg).print(),
        "fig5" | "table2" => {
            let gib = if quick { 256 << 20 } else { 1u64 << 30 };
            let (f, t) = bench::run_fig5_table2(&cfg, 5, gib);
            if which == "fig5" {
                f.print()
            } else {
                t.print()
            }
        }
        "ablations" => {
            let gib = if quick { 128u64 << 20 } else { 1 << 30 };
            bench::run_ablation_stripes(&cfg, gib).print();
            bench::run_ablation_prefetch(&cfg).print();
            bench::run_ablation_delta(&cfg, if quick { 16 } else { 64 }).print();
            bench::run_ablation_consistency(&cfg, 3).print();
            bench::run_ablation_writeback(&cfg).print();
            bench::run_ablation_compound(&cfg).print();
            bench::run_ablation_paging(&cfg, gib).print();
        }
        "all" => {
            bench::run_table1(cfg.seed.max(1)).print();
            let (w, r) = bench::run_fig2_fig3(&cfg, quick);
            w.print();
            r.print();
            bench::run_fig4(&cfg, 5).print();
            let gib = if quick { 256 << 20 } else { 1u64 << 30 };
            let (f, t) = bench::run_fig5_table2(&cfg, 5, gib);
            f.print();
            t.print();
        }
        other => {
            eprintln!("unknown experiment `{other}`");
            println!("{HELP}");
            std::process::exit(2);
        }
    }
}

fn serve(cfg: XufsConfig) {
    let metrics = Metrics::new();
    let engine = Arc::new(
        DigestEngine::from_artifacts(&cfg.artifacts_dir, metrics.clone())
            .unwrap_or_else(|_| DigestEngine::native(metrics.clone())),
    );
    let mut rng = Rng::new(cfg.seed ^ 0x5345_5256);
    let pair = KeyPair::generate(&mut rng, VirtualTime::ZERO, 12.0 * 3600.0);
    let mut home = FileStore::default();
    home.mkdir_p("/home/demo", VirtualTime::ZERO).unwrap();
    home.write("/home/demo/README", b"served by xufs\n", VirtualTime::ZERO).unwrap();
    let server = Arc::new(FileServer::new(
        home,
        DiskModel::new(cfg.disk.home_bps, cfg.disk.home_op_s),
        engine,
        cfg.stripe.min_block as usize,
        cfg.lease.duration_s,
        cfg.server.shards,
        metrics,
        cfg.chunkstore.clone(),
    ));
    let auth = Arc::new(Mutex::new(Authenticator::new(pair.clone(), cfg.seed)));
    let tcp = TcpServer::spawn(server, auth, Metrics::new()).expect("bind");
    println!("xufs file server on {}", tcp.addr);
    println!("key id : {}", pair.key_id);
    println!(
        "phrase : {}",
        pair.phrase.iter().map(|b| format!("{b:02x}")).collect::<String>()
    );
    println!("(ctrl-c to stop)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Microbenchmarks of the L3 hot paths in REAL wall-clock time (the sim
/// clock is analytic; what costs real CPU is digesting, copying and queue
/// persistence). Used by the EXPERIMENTS.md §Perf before/after log.
fn perf(cfg: XufsConfig) {
    use std::time::Instant;
    use xufs::client::Vfs as _;
    let mb = |bytes: u64, secs: f64| bytes as f64 / (1024.0 * 1024.0) / secs.max(1e-12);
    let size: u64 = 256 << 20;
    let mut rng = Rng::new(7);
    let mut data = vec![0u8; size as usize];
    rng.fill_bytes(&mut data);

    // native digest throughput
    let native = DigestEngine::native(Metrics::new());
    let w = Instant::now();
    let d = native.digests(&data, 65536);
    let t_native = w.elapsed().as_secs_f64();
    println!("digest/native  : {:7.0} MiB/s  ({} blocks in {:.3}s)", mb(size, t_native), d.len(), t_native);

    // pjrt digest throughput (if artifacts are present)
    if let Ok(pjrt) = DigestEngine::from_artifacts(&cfg.artifacts_dir, Metrics::new()) {
        if pjrt.is_pjrt() {
            let w = Instant::now();
            let d2 = pjrt.digests_via_pjrt(&data, 65536).unwrap();
            let t = w.elapsed().as_secs_f64();
            assert_eq!(d, d2);
            println!("digest/pjrt    : {:7.0} MiB/s  (bit-identical to native)", mb(size, t));
        }
    }

    // delta plan (digest + dirty + stripe) throughput
    let w = Instant::now();
    let plan = native.plan(&data, &d, 65536, 12);
    let t_plan = w.elapsed().as_secs_f64();
    println!("plan/native    : {:7.0} MiB/s  ({} dirty)", mb(size, t_plan), plan.dirty_blocks());

    // end-to-end client write path (open+write+close+flush), wall time
    let mut world = SimWorld::new(cfg.clone());
    world.home(|s| {
        s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
    });
    let mut c = world.mount("/home/u").expect("mount");
    let w = Instant::now();
    c.write_file("/home/u/big.dat", &data, 1 << 20).unwrap();
    let t_write = w.elapsed().as_secs_f64();
    println!("write path     : {:7.0} MiB/s wall  (sim {:.1}s)", mb(size, t_write), c.now().as_secs());

    // end-to-end cold fetch path (server digest + transfer + verify + install)
    let mut world2 = SimWorld::new(cfg);
    world2.home(|s| {
        s.home_mut().mkdir_p("/home/u", VirtualTime::ZERO).unwrap();
        s.home_mut().write("/home/u/big.dat", &data, VirtualTime::ZERO).unwrap();
    });
    let mut c2 = world2.mount("/home/u").expect("mount");
    let w = Instant::now();
    c2.scan_file("/home/u/big.dat", 1 << 20).unwrap();
    let t_fetch = w.elapsed().as_secs_f64();
    println!("fetch path     : {:7.0} MiB/s wall", mb(size, t_fetch));

    // warm read path
    let w = Instant::now();
    c2.scan_file("/home/u/big.dat", 1 << 20).unwrap();
    let t_warm = w.elapsed().as_secs_f64();
    println!("warm read path : {:7.0} MiB/s wall", mb(size, t_warm));
}

fn print_config() {
    println!(
        "# xufs.toml — all keys optional; defaults reproduce the paper's testbed
seed = 0
artifacts_dir = \"artifacts\"

[wan]
rtt_ms = 32
per_stream_mibps = 2.0
agg_gbps = 30
setup_rtts = 3
slow_start_rtts = 4

[stripe]
max_stripes = 12
min_block_kib = 64
stripe_threshold_kib = 64
prefetch_threads = 12
prefetch_max_size_kib = 64
prefetch_enabled = true
delta_writeback = true

[transfer]
# stripes: \"auto\" = adaptive striping (goodput EWMA tuner), an integer
# forces that many stripes, omitted = the size-based static plan
# stripes = \"auto\"
pipeline = false
pipeline_window = 1
compress = false

[cache]
capacity_gib = 1024
localized_dirs = \"/home/u/scratch:/home/u/runs\"
budget_bytes = 0
readahead_blocks = 32

[lease]
duration_s = 30
renew_fraction = 0.5

[disk]
cache_mibps = 400
cache_op_ms = 2
home_mibps = 200
home_op_ms = 2
digest_cpu_mibps = 300

[server]
shards = 8
reactor_threads = 0
max_connections = 1024
max_inflight_per_conn = 32

[replica]
enabled = false
ship_batch = 64
max_lag_ops = 8
secondaries = 1
read_fanout = false
staleness_ops = 8

[chunkstore]
enabled = true
chunk_kib = 64
gc_interval_ops = 128
snapshot_retention = 8

[integrity]
scrub_interval_ops = 64
scrub_batch = 32

[fault]
enabled = false
drop_request_p = 0.0
drop_reply_p = 0.0
duplicate_p = 0.0
delay_p = 0.0
delay_max_ms = 100
interrupt_p = 0.0
partition_p = 0.0
partition_max_steps = 16
server_crash_p = 0.0
server_crash_max_steps = 24
client_crash_p = 0.0
promote_after_crash_p = 0.0
corrupt_p = 0.0"
    );
}
