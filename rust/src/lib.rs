//! # XUFS — a wide-area distributed file system for HPC infrastructures
//!
//! Production-quality reproduction of *“A Distributed File System for a
//! Wide-Area High Performance Computing Infrastructure”* (E. Walker, 2010):
//! private distributed name spaces with whole-file on-disk caching, a
//! persisted meta-operation queue, callback cache consistency, lock
//! leases, striped WAN transfers and parallel small-file pre-fetching —
//! plus the GPFS-WAN / NFS / SCP / TGCP baselines and the paper's full
//! evaluation harness.
//!
//! See `DESIGN.md` (repo root) for the architecture. Layer map:
//!
//! * **L3 (this crate)** — coordinator: client, server, cache, transfer,
//!   consistency, recovery, baselines, benches.
//! * **L2/L1 (python/, build-time only)** — JAX transfer-plan graph and
//!   Pallas digest kernels, AOT-lowered to `artifacts/*.hlo.txt` and
//!   executed by [`runtime`] via PJRT (behind the `pjrt` cargo feature;
//!   the default build uses the bit-identical native engine).
//!
//! The client surface is the **Vfs v2** contract (DESIGN.md §2):
//! buffer-based positional I/O (`pread`/`pwrite`) with sequential
//! defaults, validated [`client::OpenFlags`], and compound metadata
//! batching — the meta-op queue flushes as one `Request::Compound` WAN
//! round trip instead of one round trip per op.
//!
//! The server side is a **namespace-sharded concurrent core**
//! (DESIGN.md §2.6): [`server::FileServer::handle`] takes `&self`, so
//! callers dispatch with no global lock — requests serialize only on
//! the shard their canonical path hashes to, and bulk block
//! reads/digesting run outside shard locks. Over real sockets it is
//! fronted by a **readiness-driven reactor** (DESIGN.md §2.9): a
//! `poll(2)` thread pool, per-connection streaming codec buffers,
//! explicit backpressure and typed-busy admission control — no thread
//! per connection. `cargo bench --bench scale` measures both —
//! sharding over the `shards = 1` ablation, and the reactor's flat
//! throughput at up to 1024 live connections (`BENCH_scale.json`).

pub mod auth;
pub mod baselines;
pub mod bench;
pub mod cache;
pub mod callback;
pub mod chunkstore;
pub mod client;
pub mod config;
pub mod coordinator;
pub mod homefs;
pub mod lease;
pub mod metaq;
pub mod metrics;
pub mod proto;
pub mod replica;
pub mod runtime;
pub mod server;
pub mod simnet;
pub mod transfer;
pub mod util;
pub mod vdisk;
pub mod workload;
