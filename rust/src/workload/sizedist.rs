//! File-size population model calibrated to Table 1 of the paper: the
//! cumulative size distribution of the 143,190 files (864.4 GB) in the
//! TACC TeraGrid cluster's parallel-FS scratch space.
//!
//! The generator samples a piecewise bucket mixture whose per-bucket file
//! counts are exact (by construction) and whose per-bucket byte totals
//! match the paper in expectation (a power-shaped within-bucket sampler
//! tuned to the bucket mean). `census` recomputes the paper's cumulative
//! rows from a generated population so Table 1 can be regenerated and the
//! benches can assert the population has the paper's byte/file skew
//! (>1 MiB files: 9% of files, 98.49% of bytes).

use crate::homefs::{FileStore, FsResult};
use crate::simnet::VirtualTime;
use crate::util::Rng;

const MIB: f64 = 1024.0 * 1024.0;
const GIB_DECIMAL: f64 = 1e9; // the paper reports decimal gigabytes

/// One bucket of the calibrated mixture: (lo_bytes, hi_bytes, files,
/// total_gigabytes) — derived by differencing Table 1's cumulative rows.
const BUCKETS: [(f64, f64, u64, f64); 9] = [
    (500.0 * MIB, 2600.0 * MIB, 130, 302.471),
    (400.0 * MIB, 500.0 * MIB, 74, 33.474),
    (300.0 * MIB, 400.0 * MIB, 67, 23.195),
    (200.0 * MIB, 300.0 * MIB, 1142, 263.997),
    (100.0 * MIB, 200.0 * MIB, 1110, 156.474),
    (1.0 * MIB, 100.0 * MIB, 10333, 71.736),
    (0.5 * MIB, 1.0 * MIB, 3221, 2.408),
    (0.25 * MIB, 0.5 * MIB, 14885, 5.829),
    (64.0, 0.25 * MIB, 112228, 4.801),
];

/// Paper's Table 1: (cut point label, bytes, cumulative files, cumulative
/// gigabytes, file %, byte %).
pub const PAPER_TABLE1: [(&str, f64, u64, f64); 8] = [
    ("> 500M", 500.0 * MIB, 130, 302.471),
    ("> 400M", 400.0 * MIB, 204, 335.945),
    ("> 300M", 300.0 * MIB, 271, 359.140),
    ("> 200M", 200.0 * MIB, 1413, 623.137),
    ("> 100M", 100.0 * MIB, 2523, 779.611),
    ("> 1M", 1.0 * MIB, 12856, 851.347),
    ("> 0.5M", 0.5 * MIB, 16077, 853.755),
    ("> 0.25M", 0.25 * MIB, 30962, 859.584),
];

pub const PAPER_TOTAL_FILES: u64 = 143_190;
pub const PAPER_TOTAL_GB: f64 = 864.385;

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct SizeDistParams {
    /// Scale factor on file counts (1.0 = the full 143k-file census;
    /// benches use smaller scales for the populate step).
    pub scale: f64,
}

impl Default for SizeDistParams {
    fn default() -> Self {
        SizeDistParams { scale: 1.0 }
    }
}

/// Sample file sizes from the calibrated mixture.
pub fn generate_sizes(params: &SizeDistParams, seed: u64) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    let mut sizes = Vec::new();
    for &(lo, hi, files, gb) in &BUCKETS {
        let n = ((files as f64) * params.scale).round().max(if params.scale > 0.0 { 1.0 } else { 0.0 }) as u64;
        if n == 0 {
            continue;
        }
        let mean = (gb * GIB_DECIMAL) / files as f64;
        // size = lo + (hi-lo) * u^k with E[size] = lo + (hi-lo)/(k+1):
        // k chosen so the bucket mean matches the paper
        let k = ((hi - lo) / (mean - lo).max(1.0) - 1.0).max(0.02);
        for _ in 0..n {
            let u = rng.f64();
            // strictly above the bucket floor so cumulative cut-point
            // counts (`size > cut`) stay exact after u64 truncation
            let size = (lo + 1.0) + (hi - lo - 1.0) * u.powf(k);
            sizes.push(size.max(1.0) as u64);
        }
    }
    rng.shuffle(&mut sizes);
    sizes
}

/// A census row: files and bytes above a cut point.
#[derive(Debug, Clone, PartialEq)]
pub struct CensusRow {
    pub label: String,
    pub cut_bytes: f64,
    pub files: u64,
    pub file_pct: f64,
    pub gigabytes: f64,
    pub byte_pct: f64,
}

/// The recomputed Table 1.
#[derive(Debug, Clone)]
pub struct Census {
    pub rows: Vec<CensusRow>,
    pub total_files: u64,
    pub total_gb: f64,
}

/// Recompute the paper's cumulative table from a population.
pub fn census(sizes: &[u64]) -> Census {
    let total_files = sizes.len() as u64;
    let total_bytes: f64 = sizes.iter().map(|&s| s as f64).sum();
    let rows = PAPER_TABLE1
        .iter()
        .map(|(label, cut, _, _)| {
            let files = sizes.iter().filter(|&&s| s as f64 > *cut).count() as u64;
            let bytes: f64 = sizes.iter().filter(|&&s| s as f64 > *cut).map(|&s| s as f64).sum();
            CensusRow {
                label: label.to_string(),
                cut_bytes: *cut,
                files,
                file_pct: 100.0 * files as f64 / total_files.max(1) as f64,
                gigabytes: bytes / GIB_DECIMAL,
                byte_pct: 100.0 * bytes / total_bytes.max(1.0),
            }
        })
        .collect();
    Census { rows, total_files, total_gb: total_bytes / GIB_DECIMAL }
}

/// Materialize a population into a file store under `root` (used by the
/// e2e example's scratch space). Contents are zero-filled for speed; set
/// `fill` for pseudorandom bytes.
pub fn populate(
    fs: &mut FileStore,
    root: &str,
    sizes: &[u64],
    fill: bool,
    seed: u64,
) -> FsResult<()> {
    let mut rng = Rng::new(seed);
    let now = VirtualTime::ZERO;
    fs.mkdir_p(root, now)?;
    for (i, &size) in sizes.iter().enumerate() {
        let dir = format!("{root}/job{:03}", i % 97);
        fs.mkdir_p(&dir, now)?;
        let mut data = vec![0u8; size as usize];
        if fill {
            rng.fill_bytes(&mut data);
        }
        fs.write(&format!("{dir}/out{i:06}.dat"), &data, now)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_sum_to_paper_totals() {
        let files: u64 = BUCKETS.iter().map(|b| b.2).sum();
        let gb: f64 = BUCKETS.iter().map(|b| b.3).sum();
        assert_eq!(files, PAPER_TOTAL_FILES);
        assert!((gb - PAPER_TOTAL_GB).abs() < 0.01, "{gb}");
    }

    #[test]
    fn full_scale_census_matches_paper_rows() {
        let sizes = generate_sizes(&SizeDistParams::default(), 1);
        assert_eq!(sizes.len() as u64, PAPER_TOTAL_FILES);
        let c = census(&sizes);
        for (row, (label, _, files, gb)) in c.rows.iter().zip(PAPER_TABLE1.iter()) {
            assert_eq!(&row.label, label);
            // counts exact by construction
            assert_eq!(row.files, *files, "{label}");
            // bytes within 12% per cumulative row (sampling noise)
            let rel = (row.gigabytes - gb).abs() / gb;
            assert!(rel < 0.12, "{label}: got {} GB want {} GB", row.gigabytes, gb);
        }
        // headline skew: >1 MiB files are ~9% of files, >97% of bytes
        let m1 = &c.rows[5];
        assert!((m1.file_pct - 9.0).abs() < 1.0, "{}", m1.file_pct);
        assert!(m1.byte_pct > 97.0, "{}", m1.byte_pct);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = SizeDistParams { scale: 0.01 };
        assert_eq!(generate_sizes(&p, 9), generate_sizes(&p, 9));
        assert_ne!(generate_sizes(&p, 9), generate_sizes(&p, 10));
    }

    #[test]
    fn scaled_population() {
        let sizes = generate_sizes(&SizeDistParams { scale: 0.001 }, 3);
        // every bucket contributes at least one file at tiny scales
        assert!(sizes.len() >= 9);
        let c = census(&sizes);
        assert!(c.total_gb > 0.0);
    }

    #[test]
    fn populate_writes_files() {
        let mut fs = FileStore::default();
        let sizes = vec![100, 2000, 50_000];
        populate(&mut fs, "/scratch", &sizes, false, 1).unwrap();
        let walked = fs.walk("/scratch").unwrap();
        let files: Vec<_> = walked.iter().filter(|(p, _)| p.ends_with(".dat")).collect();
        assert_eq!(files.len(), 3);
        let total: u64 = files.iter().map(|(_, a)| a.size).sum();
        assert_eq!(total, 52_100);
    }
}
