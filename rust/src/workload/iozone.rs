//! IOzone-style sequential read/write throughput (paper §4.1).
//!
//! "We ran the benchmark for a range of file sizes from 1 MB to 1 GB, and
//! we also included the time of the close operation in all our
//! measurements to include the cost of cache flushes."

use crate::client::{OpenFlags, Vfs};
use crate::homefs::FsError;
use crate::util::stats::mib_per_sec;
use crate::util::Rng;

/// One IOzone measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct IozoneResult {
    pub file_bytes: u64,
    pub secs: f64,
    pub mib_per_sec: f64,
}

/// IOzone record size (the default 64 KiB transfer unit... IOzone uses a
/// range; we use 1 MiB records like the paper-era runs on large files).
pub const RECORD: usize = 1 << 20;

/// Sequential write of `bytes` (open O_CREAT|O_TRUNC, write records,
/// close). The close is INCLUDED — it carries the cache-flush cost.
pub fn write_test<V: Vfs>(vfs: &mut V, path: &str, bytes: u64, seed: u64) -> Result<IozoneResult, FsError> {
    let mut rng = Rng::new(seed);
    let mut record = vec![0u8; RECORD.min(bytes as usize).max(1)];
    rng.fill_bytes(&mut record);
    let t0 = vfs.now();
    let fd = vfs.open(path, OpenFlags::wronly_create())?;
    let mut written = 0u64;
    while written < bytes {
        let n = ((bytes - written) as usize).min(record.len());
        vfs.write(fd, &record[..n])?;
        written += n as u64;
    }
    vfs.close(fd)?;
    let secs = vfs.now().saturating_sub(t0).as_secs();
    Ok(IozoneResult { file_bytes: bytes, secs, mib_per_sec: mib_per_sec(bytes, secs) })
}

/// Sequential read of the whole file (open, read records, close). The
/// record buffer is caller-side and reused — the v2 `Vfs` contract means
/// no per-read allocation anywhere on this path.
pub fn read_test<V: Vfs>(vfs: &mut V, path: &str) -> Result<IozoneResult, FsError> {
    let mut record = vec![0u8; RECORD];
    let t0 = vfs.now();
    let fd = vfs.open(path, OpenFlags::rdonly())?;
    let mut total = 0u64;
    loop {
        let n = vfs.read(fd, &mut record)?;
        if n == 0 {
            break;
        }
        total += n as u64;
    }
    vfs.close(fd)?;
    let secs = vfs.now().saturating_sub(t0).as_secs();
    Ok(IozoneResult { file_bytes: total, secs, mib_per_sec: mib_per_sec(total, secs) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::LocalFs;
    use crate::homefs::FileStore;
    use crate::simnet::SimClock;
    use crate::vdisk::DiskModel;
    use std::sync::Arc;

    fn local() -> LocalFs {
        LocalFs::new(
            FileStore::default(),
            DiskModel::new(400.0 * 1024.0 * 1024.0, 0.002),
            Arc::new(SimClock::new()),
        )
    }

    #[test]
    fn write_then_read_throughput() {
        let mut l = local();
        let w = write_test(&mut l, "/f.dat", 16 << 20, 1).unwrap();
        assert_eq!(w.file_bytes, 16 << 20);
        assert!(w.secs > 0.0);
        // 400 MiB/s disk minus op costs
        assert!(w.mib_per_sec > 200.0 && w.mib_per_sec < 400.0, "{}", w.mib_per_sec);
        let r = read_test(&mut l, "/f.dat").unwrap();
        assert_eq!(r.file_bytes, 16 << 20);
        assert!(r.mib_per_sec > 200.0);
    }

    #[test]
    fn partial_record_tail() {
        let mut l = local();
        let w = write_test(&mut l, "/odd.dat", (1 << 20) + 12345, 2).unwrap();
        assert_eq!(w.file_bytes, (1 << 20) + 12345);
        let r = read_test(&mut l, "/odd.dat").unwrap();
        assert_eq!(r.file_bytes, (1 << 20) + 12345);
    }
}
