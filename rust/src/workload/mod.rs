//! Workload generators for the paper's evaluation (§2.3, §4): the IOzone
//! micro-benchmark, the source-tree build, the 1 GiB `wc -l` scan, and the
//! TACC scratch-space file-population census of Table 1. All drivers are
//! generic over [`Vfs`](crate::client::Vfs) so the same workload runs unchanged on XUFS,
//! GPFS-WAN, NFS and local-FS clients.

pub mod buildtree;
pub mod iozone;
pub mod largefile;
pub mod sizedist;

pub use buildtree::{generate_tree, BuildSpec, BuildStats};
pub use iozone::{read_test, write_test, IozoneResult};
pub use largefile::wc_l;
pub use sizedist::{census, generate_sizes, populate, Census, SizeDistParams, PAPER_TABLE1};
