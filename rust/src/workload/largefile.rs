//! Large-file access workload (paper §4.3): `wc -l` on a 1 GiB file — an
//! open, a sequential scan counting newlines, a close.

use crate::client::{OpenFlags, Vfs};
use crate::homefs::FsError;

/// Run `wc -l` on `path`: returns (line count, elapsed seconds). One
/// reused `chunk`-byte buffer — no allocation per read (v2 `Vfs`).
pub fn wc_l<V: Vfs>(vfs: &mut V, path: &str, chunk: usize) -> Result<(u64, f64), FsError> {
    let mut buf = vec![0u8; chunk.max(1)];
    let t0 = vfs.now();
    let fd = vfs.open(path, OpenFlags::rdonly())?;
    let mut lines = 0u64;
    loop {
        let n = vfs.read(fd, &mut buf)?;
        if n == 0 {
            break;
        }
        lines += buf[..n].iter().filter(|&&b| b == b'\n').count() as u64;
    }
    vfs.close(fd)?;
    Ok((lines, vfs.now().saturating_sub(t0).as_secs()))
}

/// Generate `bytes` of text with roughly `line_len`-byte lines.
pub fn text_content(bytes: usize, line_len: usize, seed: u64) -> Vec<u8> {
    let mut rng = crate::util::Rng::new(seed);
    let mut out = Vec::with_capacity(bytes);
    while out.len() < bytes {
        let n = (line_len / 2 + rng.below(line_len as u64) as usize).min(bytes - out.len());
        for _ in 0..n.saturating_sub(1) {
            out.push(b'a' + (rng.below(26) as u8));
        }
        out.push(b'\n');
    }
    out.truncate(bytes);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::LocalFs;
    use crate::homefs::FileStore;
    use crate::simnet::SimClock;
    use crate::vdisk::DiskModel;
    use std::sync::Arc;

    #[test]
    fn counts_lines() {
        let mut l = LocalFs::new(
            FileStore::default(),
            DiskModel::new(400.0e6, 0.001),
            Arc::new(SimClock::new()),
        );
        l.write_file("/t.txt", b"a\nbb\nccc\n", 64).unwrap();
        let (lines, secs) = wc_l(&mut l, "/t.txt", 4).unwrap();
        assert_eq!(lines, 3);
        assert!(secs > 0.0);
    }

    #[test]
    fn text_content_shape() {
        let t = text_content(100_000, 80, 7);
        assert_eq!(t.len(), 100_000);
        let lines = t.iter().filter(|&&b| b == b'\n').count();
        assert!((800..2500).contains(&lines), "{lines}");
    }
}
