//! Source-code build workload (paper §4.2).
//!
//! "We built a source code tree, containing 24 files of approximately
//! 12000 lines of C source code distributed over 5 sub-directories. A
//! majority of the files in this scenario were less than 64 KB in size.
//! In our measurements we include the time to change to the source code
//! tree directory and perform a clean make."
//!
//! The "compiler" charges a fixed CPU cost per source line — identical
//! across file systems, so measured differences are pure FS overhead
//! (exactly what Fig. 4 isolates).

use crate::client::{OpenFlags, Vfs};
use crate::homefs::{FileStore, FsError};
use crate::simnet::VirtualTime;
use crate::util::Rng;

/// Shape of the generated tree (defaults = the paper's tree).
#[derive(Debug, Clone)]
pub struct BuildSpec {
    pub files: usize,
    pub subdirs: usize,
    pub total_lines: usize,
    /// Average bytes per line of C (comment-ish density).
    pub bytes_per_line: usize,
    /// Compiler CPU seconds per 1000 lines (identical for all systems).
    pub compile_s_per_kloc: f64,
}

impl Default for BuildSpec {
    fn default() -> Self {
        BuildSpec { files: 24, subdirs: 5, total_lines: 12_000, bytes_per_line: 34, compile_s_per_kloc: 0.08 }
    }
}

/// Outcome of one clean `make`.
#[derive(Debug, Clone, PartialEq)]
pub struct BuildStats {
    pub secs: f64,
    pub sources_compiled: usize,
    pub objects_written: usize,
}

/// Generate the source tree into a home-space [`FileStore`] under `root`.
/// Line counts are jittered per file but sum to ~`total_lines`; most files
/// end up below 64 KiB, like the paper's tree.
pub fn generate_tree(fs: &mut FileStore, root: &str, spec: &BuildSpec, seed: u64) -> Result<(), FsError> {
    let mut rng = Rng::new(seed);
    let now = VirtualTime::ZERO;
    fs.mkdir_p(root, now)?;
    // a Makefile and a shared header at the top
    fs.write(&format!("{root}/Makefile"), make_makefile(spec).as_bytes(), now)?;
    fs.write(&format!("{root}/common.h"), c_header(&mut rng, 120).as_bytes(), now)?;
    let per_file = spec.total_lines / spec.files;
    for i in 0..spec.files {
        let dir = format!("{root}/mod{}", i % spec.subdirs);
        fs.mkdir_p(&dir, now)?;
        let lines = (per_file as f64 * (0.5 + rng.f64())) as usize;
        let body = c_source(&mut rng, i, lines, spec.bytes_per_line);
        fs.write(&format!("{dir}/file{i:02}.c"), body.as_bytes(), now)?;
        if i % 3 == 0 {
            fs.write(&format!("{dir}/file{i:02}.h"), c_header(&mut rng, 40).as_bytes(), now)?;
        }
    }
    Ok(())
}

fn make_makefile(spec: &BuildSpec) -> String {
    format!("# generated build tree: {} files / {} dirs\nall: a.out\n", spec.files, spec.subdirs)
}

fn c_header(rng: &mut Rng, lines: usize) -> String {
    let mut s = String::from("#pragma once\n");
    for i in 0..lines {
        s.push_str(&format!("extern int sym_{}_{};\n", i, rng.alnum(6)));
    }
    s
}

fn c_source(rng: &mut Rng, idx: usize, lines: usize, bytes_per_line: usize) -> String {
    let mut s = format!("#include \"../common.h\"\n/* module {idx} */\n");
    let pad = bytes_per_line.saturating_sub(24);
    for i in 0..lines {
        s.push_str(&format!("int f_{idx}_{i}(int x) {{ return x + {}; /*{}*/ }}\n", i, rng.alnum(pad)));
    }
    s
}

/// A clean `make`: chdir into the tree, stat+read every source and header
/// in every subdir, charge compile CPU per line, write one `.o` per
/// source, then link `a.out` from all objects. Returns wall time (and the
/// compile CPU, which is identical across systems, is included — as in
/// the paper's `make` timings).
pub fn build<V: Vfs>(vfs: &mut V, root: &str, spec: &BuildSpec) -> Result<BuildStats, FsError> {
    let t0 = vfs.now();
    vfs.chdir(root)?;
    // make stats the Makefile + walks the tree
    vfs.stat(&format!("{root}/Makefile"))?;
    let header = format!("{root}/common.h");
    let mut objects: Vec<String> = Vec::new();
    let mut compiled = 0usize;
    let entries = vfs.readdir(root)?;
    let mut cpu_s = 0.0f64;
    for (name, attr) in entries {
        if attr.kind != crate::homefs::NodeKind::Dir {
            continue;
        }
        let dir = format!("{root}/{name}");
        vfs.chdir(&dir)?;
        for (fname, fattr) in vfs.readdir(&dir)? {
            if !fname.ends_with(".c") {
                continue;
            }
            let src = format!("{dir}/{fname}");
            // compiler: stat + read source, read shared header, read any
            // sibling header, emit object
            vfs.stat(&src)?;
            let fd = vfs.open(&src, OpenFlags::rdonly())?;
            let mut record = vec![0u8; 64 * 1024];
            let mut bytes = 0u64;
            let mut lines = 0usize;
            loop {
                let n = vfs.read(fd, &mut record)?;
                if n == 0 {
                    break;
                }
                lines += record[..n].iter().filter(|&&b| b == b'\n').count();
                bytes += n as u64;
            }
            vfs.close(fd)?;
            let _ = vfs.scan_file(&header, 64 * 1024)?;
            let sibling = src.replace(".c", ".h");
            if vfs.stat(&sibling).is_ok() {
                let _ = vfs.scan_file(&sibling, 64 * 1024)?;
            }
            cpu_s += (lines as f64 / 1000.0) * spec.compile_s_per_kloc;
            // object ~ 1.5x source bytes
            let obj = src.replace(".c", ".o");
            let obj_bytes = vec![0xE1u8; (bytes as usize * 3) / 2];
            vfs.write_file(&obj, &obj_bytes, 64 * 1024)?;
            objects.push(obj);
            compiled += 1;
            let _ = fattr;
        }
    }
    // link step: read all objects, write a.out
    let mut total = 0u64;
    for obj in &objects {
        total += vfs.scan_file(obj, 64 * 1024)?;
    }
    vfs.write_file(&format!("{root}/a.out"), &vec![0x7Fu8; total as usize / 2], 1 << 20)?;
    // charge the (system-independent) compile CPU once at the end
    charge_cpu(vfs, cpu_s);
    Ok(BuildStats {
        secs: vfs.now().saturating_sub(t0).as_secs(),
        sources_compiled: compiled,
        objects_written: objects.len(),
    })
}

/// `make clean`: remove objects and the binary so the next run is clean.
pub fn clean<V: Vfs>(vfs: &mut V, root: &str) -> Result<(), FsError> {
    let entries = vfs.readdir(root)?;
    for (name, attr) in entries {
        if attr.kind == crate::homefs::NodeKind::Dir {
            let dir = format!("{root}/{name}");
            for (fname, _) in vfs.readdir(&dir)? {
                if fname.ends_with(".o") {
                    vfs.unlink(&format!("{dir}/{fname}"))?;
                }
            }
        } else if name == "a.out" {
            vfs.unlink(&format!("{root}/{name}"))?;
        }
    }
    Ok(())
}

fn charge_cpu<V: Vfs>(vfs: &mut V, secs: f64) {
    // compile CPU passes on the same clock FS ops advance — identical for
    // every system, so Fig. 4 differences stay pure FS overhead
    vfs.think(secs);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::LocalFs;
    use crate::simnet::SimClock;
    use crate::vdisk::DiskModel;
    use std::sync::Arc;

    #[test]
    fn tree_matches_paper_shape() {
        let mut fs = FileStore::default();
        let spec = BuildSpec::default();
        generate_tree(&mut fs, "/src", &spec, 42).unwrap();
        let files = fs.walk("/src").unwrap();
        let c_files: Vec<_> = files.iter().filter(|(p, _)| p.ends_with(".c")).collect();
        assert_eq!(c_files.len(), 24);
        let dirs: Vec<_> = files
            .iter()
            .filter(|(_, a)| a.kind == crate::homefs::NodeKind::Dir)
            .collect();
        assert_eq!(dirs.len(), 5);
        // most files below 64 KiB (paper: "a majority ... less than 64 KB")
        let small = c_files.iter().filter(|(_, a)| a.size < 64 * 1024).count();
        assert!(small * 2 > c_files.len(), "{small}/{}", c_files.len());
        // total lines in the ballpark of 12k
        let total_lines: usize = c_files
            .iter()
            .map(|(p, _)| fs.read(p).unwrap().iter().filter(|&&b| b == b'\n').count())
            .sum();
        assert!((8_000..16_000).contains(&total_lines), "{total_lines}");
    }

    #[test]
    fn build_compiles_everything_and_links() {
        let mut fs = FileStore::default();
        let spec = BuildSpec::default();
        generate_tree(&mut fs, "/src", &spec, 42).unwrap();
        let mut l = LocalFs::new(fs, DiskModel::new(400.0e6, 0.002), Arc::new(SimClock::new()));
        let stats = build(&mut l, "/src", &spec).unwrap();
        assert_eq!(stats.sources_compiled, 24);
        assert_eq!(stats.objects_written, 24);
        assert!(stats.secs > 0.0);
        assert!(l.fs.exists("/src/a.out"));
        // clean removes objects
        clean(&mut l, "/src").unwrap();
        assert!(!l.fs.exists("/src/a.out"));
        assert!(l.fs.walk("/src").unwrap().iter().all(|(p, _)| !p.ends_with(".o")));
        // rebuild works after clean
        let stats2 = build(&mut l, "/src", &spec).unwrap();
        assert_eq!(stats2.sources_compiled, 24);
    }
}
